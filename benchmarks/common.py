"""Shared benchmark substrate: datasets + cached index builds.

Sizes are tuned for the single-core CPU container (REPRO_BENCH_N scales
them).  The expensive base-graph construction (NSG / Vamana) and the block
assignment are cached per dataset regime, so the alpha/beta sweeps (which
only re-run the linear-time BAMG refinement) stay cheap.
"""
from __future__ import annotations

import functools
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.bamg import build_bamg_from  # noqa: E402
from repro.core.block_assign import bnf_blocks  # noqa: E402
from repro.core.engine import (BAMGIndex, BAMGParams, DiskANNIndex,  # noqa: E402
                               DiskANNParams, StarlingIndex, StarlingParams,
                               _pick_pq_m)
from repro.core.graph_build import build_nsg, build_vamana  # noqa: E402
from repro.core.navgraph import build_navgraph  # noqa: E402
from repro.core.pq import train_pq  # noqa: E402
from repro.core.storage import DecoupledStorage, max_capacity_for  # noqa: E402
from repro.data.synthetic import PAPER_REGIMES, make_vector_dataset  # noqa: E402

BENCH_N = int(os.environ.get("REPRO_BENCH_N", "4000"))
BENCH_NQ = int(os.environ.get("REPRO_BENCH_NQ", "30"))
R = 24
L_BUILD = 48


@functools.lru_cache(maxsize=None)
def dataset(regime: str):
    cfg = PAPER_REGIMES[regime]
    return make_vector_dataset(regime, BENCH_N, cfg["d"], BENCH_NQ,
                               k_gt=100, n_clusters=cfg["n_clusters"], seed=0)


@functools.lru_cache(maxsize=None)
def base_graphs(regime: str):
    """(nsg_adj, nsg_entry, blocks, vamana_adj, vamana_entry, codec, codes,
    build timings) -- cached across benchmarks."""
    ds = dataset(regime)
    x = ds.base
    t0 = time.time()
    nsg_adj, nsg_entry = build_nsg(x, r=R, l_build=L_BUILD, knn_k=R)
    t_nsg = time.time() - t0
    cap = max_capacity_for(R)
    t0 = time.time()
    blocks = bnf_blocks(nsg_adj, cap, seed=0)
    t_bnf = time.time() - t0
    t0 = time.time()
    vam_adj, vam_entry = build_vamana(x, r=R, l_build=L_BUILD)
    t_vam = time.time() - t0
    t0 = time.time()
    codec = train_pq(x, m=_pick_pq_m(x.shape[1]), seed=0)
    codes = codec.encode(x)
    t_pq = time.time() - t0
    return dict(nsg=(nsg_adj, nsg_entry), blocks=blocks, cap=cap,
                vamana=(vam_adj, vam_entry), codec=codec, codes=codes,
                t=dict(nsg=t_nsg, bnf=t_bnf, vamana=t_vam, pq=t_pq))


def bamg_index(regime: str, alpha: int = 3, beta: float = 1.05,
               use_nav: bool = True, use_prune: bool = True) -> BAMGIndex:
    """BAMG from the cached base NSG (linear-time refinement only)."""
    ds = dataset(regime)
    b = base_graphs(regime)
    nsg_adj, entry = b["nsg"]
    if use_prune:
        graph = build_bamg_from(ds.base, nsg_adj, entry, b["blocks"],
                                b["cap"], alpha=alpha, beta=beta,
                                max_degree=R)
    else:
        from repro.core.bamg import BAMGGraph
        from repro.core.block_assign import block_members
        graph = BAMGGraph(adj=nsg_adj, blocks=np.asarray(b["blocks"], np.int32),
                          members=block_members(b["blocks"], b["cap"]),
                          entry=entry, capacity=b["cap"], alpha=alpha,
                          beta=beta)
    store = DecoupledStorage(ds.base, graph.adj, graph.blocks, graph.members)
    nav = build_navgraph(ds.base, graph, alpha=alpha, beta=beta,
                         gamma=128, capacity=b["cap"]) if use_nav else None
    params = BAMGParams(alpha=alpha, beta=beta, r=R, use_nav=use_nav,
                        use_bmrng_prune=use_prune)
    return BAMGIndex(ds.base, graph, b["codec"], b["codes"], store, nav,
                     params)


@functools.lru_cache(maxsize=None)
def starling_index(regime: str) -> StarlingIndex:
    ds = dataset(regime)
    return StarlingIndex.build(ds.base, StarlingParams(r=R, l_build=L_BUILD))


@functools.lru_cache(maxsize=None)
def diskann_index(regime: str) -> DiskANNIndex:
    ds = dataset(regime)
    return DiskANNIndex.build(ds.base, DiskANNParams(r=R, l_build=L_BUILD))


@functools.lru_cache(maxsize=None)
def default_bamg(regime: str) -> BAMGIndex:
    return bamg_index(regime)


def sweep(idx, regime: str, ls=(12, 24, 48, 96), k: int = 10, **kw):
    """[(l, recall, nio, qps, graph_reads, vector_reads)] over pool sizes."""
    ds = dataset(regime)
    out = []
    for l in ls:
        st = idx.search_batch(ds.queries, k=k, l=l, gt=ds.gt, **kw)
        out.append((l, st.recall, st.mean_nio, st.qps,
                    st.mean_graph_reads, st.mean_vector_reads))
    return out


# every emit() lands here too, so run.py --json can dump the whole suite
# run as one machine-readable artifact (list of {name, value, derived})
ROWS: list[dict] = []


def emit(name: str, value, derived: str = "") -> None:
    """CSV row in the harness convention: name,us_per_call,derived."""
    print(f"{name},{value},{derived}")
    ROWS.append({"name": name, "value": value, "derived": derived})


def env_metadata() -> dict:
    """Environment snapshot stored alongside --json rows: enough to tell
    two artifact files apart (host class, library versions, the REPRO_*
    knobs that scale the suites, and the git revision when available)."""
    import platform
    import subprocess

    import jax

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(__file__), timeout=10).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    return {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
        "jax": jax.__version__,
        "numpy": np.__version__,
        "jax_backend": jax.default_backend(),
        "git_sha": sha,
        "env": {k: v for k, v in sorted(os.environ.items())
                if k.startswith("REPRO_")},
    }
