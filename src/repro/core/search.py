"""Disk-resident ANN search: Algorithm 1 (DiskANN / Starling) and
Algorithm 4 (BAMG block-first), on the I/O simulator.

All pool ordering uses in-memory PQ estimated distances (delta-hat); exact
distances come only from raw vectors fetched from disk, exactly as in the
paper.  Every block fetch is counted by the storage layer.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .io_sim import READ_FAILED
from .pq import PQCodec
from .storage import CoupledStorage, DecoupledStorage


@dataclasses.dataclass
class SearchResult:
    ids: np.ndarray          # (k,) VIDs
    dists: np.ndarray        # (k,) exact squared distances
    nio: int                 # total block reads for this query
    graph_reads: int
    vector_reads: int
    n_dist: int              # exact distance computations
    n_pq: int                # PQ estimated distance computations
    hops: int                # pool pops (search path length)
    cache_hits: int = 0      # block-cache hits (reads that cost no I/O)
    service_us: float = 0.0  # pipelined I/O service time (qd-overlapped)
    serial_us: float = 0.0   # same demand misses read strictly serially
    # degraded-result contract (fault injection): `degraded` is True iff at
    # least one block this query needed could not be delivered, i.e. the
    # returned top-k may be missing candidates the clean run would have
    # seen.  All other fields stay exact for the reads that did happen.
    degraded: bool = False
    failed_reads: int = 0    # undeliverable blocks skipped by this query
    retries: int = 0         # extra read attempts (transient faults)
    hedges: int = 0          # duplicate reads raced against stragglers
    checksum_failures: int = 0  # torn payloads caught and retried


class _Pool:
    """Fixed-capacity candidate pool sorted ascending by estimated distance."""

    __slots__ = ("cap", "ids", "d", "checked")

    def __init__(self, cap: int):
        self.cap = cap
        self.ids: list[int] = []
        self.d: list[float] = []
        self.checked: list[bool] = []

    def worst(self) -> float:
        return self.d[-1] if len(self.d) >= self.cap else np.inf

    def insert(self, vid: int, dist: float) -> bool:
        if len(self.d) >= self.cap and dist >= self.d[-1]:
            return False
        if vid in self.ids:  # pools are small (l <= few hundred)
            return False
        import bisect
        i = bisect.bisect_right(self.d, dist)
        self.ids.insert(i, vid)
        self.d.insert(i, dist)
        self.checked.insert(i, False)
        if len(self.d) > self.cap:
            self.ids.pop()
            self.d.pop()
            self.checked.pop()
        return True

    def first_unchecked(self) -> int:
        for i, c in enumerate(self.checked):
            if not c:
                return i
        return -1


def _sqd(a: np.ndarray, b: np.ndarray) -> float:
    v = a - b
    return float(np.dot(v, v))


# ---------------------------------------------------------------------------
# Algorithm 1 -- search on a coupled (DiskANN / Starling) layout
# ---------------------------------------------------------------------------
def search_coupled(
    store: CoupledStorage,
    codec_codes: np.ndarray,          # (n, M) uint8 PQ codes (in memory)
    adc_table: np.ndarray,            # (M, K) query ADC table (in memory)
    q: np.ndarray,
    entry: int | Sequence[int],
    k: int,
    l: int,
    block_level: bool = False,        # False = DiskANN, True = Starling
    max_hops: int | None = None,
    batch_submit: int | None = None,  # prefetch width (timing only)
    drop_cache: bool = True,          # False = warm cross-query cache
    exclude: set[int] | frozenset[int] | None = None,  # tombstoned VIDs
) -> SearchResult:
    """Tombstones (`exclude`, streaming freshness): excluded VIDs stay fully
    navigable -- they enter the pool and are beam-expanded like any other
    node so connectivity through deleted points survives -- but they never
    enter the exact-result set, so they cannot appear in the returned top-k.
    """
    store.reset(drop_cache=drop_cache)
    excl = exclude if exclude is not None else ()
    m_sub = adc_table.shape[0]
    n_pq = 0
    n_dist = 0

    def pq_dist(vids: np.ndarray) -> np.ndarray:
        nonlocal n_pq
        n_pq += len(vids)
        c = codec_codes[vids].astype(np.int64)
        return adc_table[np.arange(m_sub)[None, :], c].sum(1)

    pool = _Pool(l)
    entries = [entry] if np.isscalar(entry) else list(entry)
    ed = pq_dist(np.asarray(entries, np.int64))
    for v, dv in zip(entries, ed.tolist()):
        pool.insert(int(v), dv)

    results: dict[int, float] = {}
    hops = 0
    failed_blocks = 0
    while True:
        i = pool.first_unchecked()
        if i < 0 or (max_hops is not None and hops >= max_hops):
            break
        v = pool.ids[i]
        pool.checked[i] = True
        hops += 1
        pf: list[int] = []
        if batch_submit is not None and batch_submit > 1:
            pf = _prefetch_hints(pool, i, batch_submit - 1,
                                 lambda u: store.block_of(u),
                                 exclude={store.block_of(v)})
        rec = store.read_node_block(v, prefetch=pf)
        if rec is READ_FAILED:
            # degraded mode: the candidate's block is unreadable -- skip it
            # (it stays checked) and keep expanding the rest of the pool
            failed_blocks += 1
            continue
        if block_level:
            # Starling: evaluate every node of the fetched block (free once
            # the block is resident): exact distances for residents, and
            # PQ-insert each resident + its neighbors into the pool.
            mask = rec.vids >= 0
            vids = rec.vids[mask]
            for s, vv in enumerate(vids.tolist()):
                if vv not in results and vv not in excl:
                    results[vv] = _sqd(rec.vecs[mask][s], q)
                    n_dist += 1
            nbrs = rec.nbrs[mask]
            cand = np.unique(nbrs[nbrs >= 0])
            cand = np.concatenate([vids.astype(np.int64), cand.astype(np.int64)])
        else:
            s = store.slot_in_block(v)
            if v not in results and v not in excl:
                results[v] = _sqd(rec.vecs[s], q)
                n_dist += 1
            nn = rec.nbrs[s]
            cand = nn[nn >= 0].astype(np.int64)
        if len(cand):
            cand = np.unique(cand)
            dd = pq_dist(cand)
            w = pool.worst()
            for u, du in zip(cand.tolist(), dd.tolist()):
                if du < w:
                    pool.insert(int(u), du)
                    w = pool.worst()

    ids = np.fromiter(results.keys(), np.int64, len(results))
    ds = np.fromiter(results.values(), np.float64, len(results))
    o = np.argsort(ds, kind="stable")[:k]
    st = store.device.stats
    sch = store.scheduler
    return SearchResult(
        ids=ids[o], dists=ds[o], nio=st.nio, graph_reads=st.graph_reads,
        vector_reads=st.vector_reads, n_dist=n_dist, n_pq=n_pq, hops=hops,
        cache_hits=st.cache_hits, service_us=sch.service_us,
        serial_us=sch.serial_us, degraded=failed_blocks > 0,
        failed_reads=failed_blocks, retries=st.retries, hedges=st.hedges,
        checksum_failures=st.checksum_failures)


def _prefetch_hints(pool: "_Pool", popped_i: int, width: int,
                    block_of, exclude: set) -> list[int]:
    """Blocks of the next `width` unchecked pool candidates (after the one
    just popped) -- speculative hints for the same batched submission.

    Timing-domain only: the scheduler never lets these touch the cache or
    the NIO counters, so the search trajectory is bit-identical to the
    per-read path.
    """
    hints: list[int] = []
    seen = set(exclude)
    for j in range(len(pool.ids)):
        if len(hints) >= width:
            break
        if j == popped_i or pool.checked[j]:
            continue
        b = block_of(pool.ids[j])
        if b not in seen:
            seen.add(b)
            hints.append(b)
    return hints


# ---------------------------------------------------------------------------
# Algorithm 4 -- block-first search on the BAMG decoupled layout
# ---------------------------------------------------------------------------
def search_bamg(
    store: DecoupledStorage,
    codec_codes: np.ndarray,
    adc_table: np.ndarray,
    q: np.ndarray,
    entries: Sequence[int],
    k: int,
    l: int,
    alpha: int,
    rerank: int | None = None,
    rerank_margin: float | None = None,
    max_hops: int | None = None,
    batch_submit: int | None = None,
    drop_cache: bool = True,
    exclude: set[int] | frozenset[int] | None = None,
) -> SearchResult:
    """Algorithm 4: pool by PQ distance; each pop loads one graph block and
    runs a bounded (depth alpha) intra-block BFS; final phase loads raw
    vectors of the pool and re-ranks exactly.

    `rerank_margin` (beyond-paper, §Perf): early-stop the refinement scan --
    candidates are read in ascending PQ order, and once k exact distances
    are known, stop when the next PQ estimate exceeds margin * (current k-th
    exact distance).  None = paper-faithful (read all l candidates).

    `batch_submit` (beyond-paper, pipelined I/O): each pool pop submits the
    demand graph block together with the blocks of the next
    ``batch_submit - 1`` unchecked candidates as one batched submission
    (speculative, timing-domain only), and the re-rank phase submits all its
    vector-block reads at once.  Results, NIO, and cache behavior are
    bit-identical to the per-read path; only the modeled service time
    changes (see io_sim.IOScheduler).  `drop_cache=False` keeps the block
    cache warm across queries (`warm_cache` serving mode).

    Degraded-result contract (fault injection): blocks that cannot be
    delivered after retries are skipped -- the beam keeps walking, the
    re-rank drops the affected candidates, and the result carries
    ``degraded=True`` with ``failed_reads`` counting the skips.  The query
    never crashes on an unreadable block.

    Tombstones (`exclude`, streaming freshness): excluded VIDs stay fully
    navigable -- the beam walks through them so the monotonic-path property
    survives deletes -- but they are dropped before the refinement phase:
    their vectors are never read and they never enter the exact top-k.
    """
    store.reset(drop_cache=drop_cache)
    excl = exclude if exclude is not None else ()
    m_sub = adc_table.shape[0]
    n_pq = 0
    n_dist = 0

    def pq_dist(vids: np.ndarray) -> np.ndarray:
        nonlocal n_pq
        n_pq += len(vids)
        c = codec_codes[vids].astype(np.int64)
        return adc_table[np.arange(m_sub)[None, :], c].sum(1)

    pool = _Pool(l)
    ed = pq_dist(np.asarray(list(entries), np.int64))
    for v, dv in zip(entries, ed.tolist()):
        pool.insert(int(v), dv)

    explored: set[int] = set()     # nodes already BFS-expanded (per query)
    hops = 0
    failed_blocks = 0
    while True:
        i = pool.first_unchecked()
        if i < 0 or (max_hops is not None and hops >= max_hops):
            break
        v = pool.ids[i]
        pool.checked[i] = True
        if v in explored:
            continue
        hops += 1
        oid_v = int(store.vid2oid[v])
        gb = store.gblock_of_oid(oid_v)
        pf: list[int] = []
        if batch_submit is not None and batch_submit > 1:
            pf = _prefetch_hints(
                pool, i, batch_submit - 1,
                lambda u: store.gblock_of_oid(int(store.vid2oid[u])),
                exclude={gb})
        blk = store.read_graph_block(gb, prefetch=pf)
        if blk is READ_FAILED:
            # degraded mode: skip the unreadable block, keep walking from
            # the remaining pool candidates (v stays checked)
            failed_blocks += 1
            explored.add(v)
            continue
        _search_within_block(store, blk, gb, v, pool, pq_dist, explored, alpha)

    # refinement: load raw vectors for pool candidates, exact re-rank.
    # Under fault injection a candidate whose vector block is unreadable is
    # dropped (None from the storage layer) -- partial top-k, never a crash.
    # Tombstoned candidates are masked here: no vector read, no result slot.
    live_ids = [vv for vv in pool.ids if vv not in excl]
    live_d = [dv for vv, dv in zip(pool.ids, pool.d) if vv not in excl]
    n_rerank = len(live_ids) if rerank is None else min(rerank, len(live_ids))
    exact: dict[int, float] = {}
    failed_vecs = 0
    if rerank_margin is None:
        # paper-faithful: all candidates, read in OID order for contiguity;
        # in batched mode the whole read set goes down as one submission
        cand = sorted(live_ids[:n_rerank], key=lambda vv: int(store.vid2oid[vv]))
        vecs = store.read_vectors([int(store.vid2oid[vv]) for vv in cand],
                                  batched=batch_submit is not None)
        for vv, vec in zip(cand, vecs):
            if vec is None:
                failed_vecs += 1
                continue
            exact[vv] = _sqd(vec, q)
            n_dist += 1
    else:
        # beyond-paper early stop: ascending PQ order + adaptive cutoff
        import heapq
        worst_k: list[float] = []  # max-heap (negated) of best k exact dists
        for vv, dpq in zip(live_ids[:n_rerank], live_d[:n_rerank]):
            if len(worst_k) >= k and dpq > rerank_margin * (-worst_k[0]):
                break
            vec = store.read_vector(int(store.vid2oid[vv]))
            if vec is None:
                failed_vecs += 1
                continue
            dex = _sqd(vec, q)
            exact[vv] = dex
            n_dist += 1
            if len(worst_k) < k:
                heapq.heappush(worst_k, -dex)
            elif dex < -worst_k[0]:
                heapq.heapreplace(worst_k, -dex)
    ids = np.fromiter(exact.keys(), np.int64, len(exact))
    ds = np.fromiter(exact.values(), np.float64, len(exact))
    o = np.argsort(ds, kind="stable")[:k]
    gs = store.graph_dev.stats
    vs = store.vector_dev.stats
    sch = store.scheduler
    n_failed = failed_blocks + failed_vecs
    return SearchResult(
        ids=ids[o], dists=ds[o], nio=gs.nio + vs.nio, graph_reads=gs.graph_reads,
        vector_reads=vs.vector_reads, n_dist=n_dist, n_pq=n_pq, hops=hops,
        cache_hits=gs.cache_hits + vs.cache_hits,
        service_us=sch.service_us, serial_us=sch.serial_us,
        degraded=n_failed > 0, failed_reads=n_failed,
        retries=gs.retries + vs.retries, hedges=gs.hedges + vs.hedges,
        checksum_failures=gs.checksum_failures + vs.checksum_failures)


def _search_within_block(store, blk, gb, v, pool, pq_dist, explored, alpha):
    """Bounded intra-block BFS (Alg. 4 lines 9-20) over the resident block.

    Frontier expansion is depth-limited by alpha; every touched node's
    neighbors are PQ-inserted into the pool; only intra-block neighbors that
    improve on the best-seen estimate are expanded further.
    """
    c = store.capacity
    oid_lookup = {int(o): s for s, o in enumerate(blk.oids.tolist()) if o >= 0}
    slot_v = int(store.vid2oid[v]) - gb * c
    dmin = float(pq_dist(np.asarray([v], np.int64))[0])
    frontier = [slot_v]
    explored.add(v)
    depth = 0
    while frontier and depth < alpha:
        nxt: list[int] = []
        for s in frontier:
            nn = blk.nbrs[s]
            nn = nn[nn >= 0]
            if len(nn) == 0:
                continue
            nbr_vids = store.oid2vid[nn].astype(np.int64)
            dd = pq_dist(nbr_vids)
            w = pool.worst()
            for u_oid, u_vid, du in zip(nn.tolist(), nbr_vids.tolist(), dd.tolist()):
                if du < w:
                    if pool.insert(int(u_vid), float(du)):
                        w = pool.worst()
                ub = u_oid // c
                if ub == gb and u_vid not in explored and du < dmin:
                    dmin = du
                    nxt.append(oid_lookup[u_oid])
                    explored.add(int(u_vid))
                    # mark resident nodes as checked in the pool: their block
                    # is already in memory, no further I/O needed for them
                    if int(u_vid) in pool.ids:
                        pool.checked[pool.ids.index(int(u_vid))] = True
        frontier = nxt
        depth += 1
