"""Paper Fig. 11: ablations -- BAMG vs w/o nav graph vs w/o BMRNG prune."""
from . import common


def run(regime: str = "sift-like") -> None:
    full = common.default_bamg(regime)
    sw = common.sweep(full, regime, ls=(48,))
    common.emit(f"fig11_abl.{regime}.full", round(sw[0][2], 2),
                f"recall={sw[0][1]:.3f};qps={sw[0][3]:.0f}")
    # w/o NG: random entries
    sw = common.sweep(full, regime, ls=(48,), random_entry=True)
    common.emit(f"fig11_abl.{regime}.wo_ng", round(sw[0][2], 2),
                f"recall={sw[0][1]:.3f};qps={sw[0][3]:.0f}")
    # w/o BMRNG pruning
    nop = common.bamg_index(regime, use_prune=False)
    sw = common.sweep(nop, regime, ls=(48,))
    common.emit(f"fig11_abl.{regime}.wo_bmrng", round(sw[0][2], 2),
                f"recall={sw[0][1]:.3f};qps={sw[0][3]:.0f}")
    # beyond-paper: early-stop rerank
    sw = common.sweep(full, regime, ls=(48,), rerank_margin=1.3)
    common.emit(f"fig11_abl.{regime}.early_stop_rerank", round(sw[0][2], 2),
                f"recall={sw[0][1]:.3f};qps={sw[0][3]:.0f}")


if __name__ == "__main__":
    run()
