"""Real SO(3) representation machinery for the equivariant GNNs
(NequIP / MACE): real spherical harmonics, real Wigner-D matrices, and real
Clebsch-Gordan coefficients for l <= 2.

CG coefficients are derived *numerically* (at import time, in numpy) by
solving the equivariance constraint

    C . (D_l1(R) (x) D_l2(R)) = D_l3(R) . C        for all R in SO(3)

as a null-space problem over a batch of random rotations.  Real Wigner-D
matrices themselves are obtained by evaluating the (explicit, closed-form)
real spherical harmonics on rotated unit vectors and solving a small least
squares system.  This avoids complex-basis phase pitfalls entirely, and the
construction is *self-validating*: tests/test_gnn.py checks equivariance of
full model outputs under random rotations.
"""
from __future__ import annotations

import functools

import numpy as np

L_MAX = 2
DIMS = {0: 1, 1: 3, 2: 5}


def real_sph_harm_np(l: int, xyz: np.ndarray) -> np.ndarray:
    """Real spherical harmonics (orthonormal on S^2), xyz (..., 3) unit.
    Returns (..., 2l+1) in m = -l..l order."""
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    c0 = 0.5 * np.sqrt(1.0 / np.pi)
    if l == 0:
        return np.full(xyz.shape[:-1] + (1,), c0)
    if l == 1:
        c1 = np.sqrt(3.0 / (4 * np.pi))
        return np.stack([c1 * y, c1 * z, c1 * x], axis=-1)
    if l == 2:
        c = np.sqrt(15.0 / (4 * np.pi))
        c20 = np.sqrt(5.0 / (16 * np.pi))
        return np.stack([
            c * x * y,
            c * y * z,
            c20 * (3 * z * z - 1.0),
            c * x * z,
            0.5 * c * (x * x - y * y),
        ], axis=-1)
    raise NotImplementedError(l)


def _random_rotations(n: int, seed: int = 0) -> np.ndarray:
    """(n, 3, 3) uniform-ish random rotation matrices via QR."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, 3, 3))
    qs = []
    for i in range(n):
        q, r = np.linalg.qr(a[i])
        q = q * np.sign(np.diag(r))[None, :]
        if np.linalg.det(q) < 0:
            q[:, 0] = -q[:, 0]
        qs.append(q)
    return np.stack(qs)


def wigner_d_real_np(l: int, rot: np.ndarray, seed: int = 1) -> np.ndarray:
    """Real Wigner-D for rotation `rot` (3,3): Y_l(R v) = D_l(R) Y_l(v).

    Solved by least squares over random unit vectors."""
    if l == 0:
        return np.ones((1, 1))
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(4 * (2 * l + 1), 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    a = real_sph_harm_np(l, v)                 # (n, 2l+1)
    b = real_sph_harm_np(l, v @ rot.T)         # (n, 2l+1)
    # D such that b = a @ D^T  =>  D^T = lstsq(a, b)
    dt, *_ = np.linalg.lstsq(a, b, rcond=None)
    return dt.T


@functools.lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray | None:
    """Real CG tensor C (2l3+1, 2l1+1, 2l2+1), None if (l1,l2,l3) forbidden.

    Normalized so that sum C^2 = 2l3+1 (componentwise orthonormal rows)."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return None
    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    rots = _random_rotations(12, seed=42)
    rows = []
    for r in rots:
        dd1 = wigner_d_real_np(l1, r)
        dd2 = wigner_d_real_np(l2, r)
        dd3 = wigner_d_real_np(l3, r)
        # constraint: D3 C - C (D1 (x) D2) = 0, C flattened (d3*d1*d2,)
        k12 = np.kron(dd1, dd2)                       # (d1*d2, d1*d2)
        m = np.kron(dd3, np.eye(d1 * d2)) - np.kron(np.eye(d3), k12.T)
        rows.append(m)
    m = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(m)
    null = vt[s.size - np.sum(s < 1e-8):] if np.sum(s < 1e-8) else vt[-1:]
    if null.shape[0] == 0 or s[-1] > 1e-8:
        return None
    c = null[-1].reshape(d3, d1, d2)
    c = c / np.linalg.norm(c) * np.sqrt(d3)
    # sign convention: make the first significant entry positive
    flat = c.reshape(-1)
    idx = np.argmax(np.abs(flat) > 1e-6)
    if flat[idx] < 0:
        c = -c
    return c


def allowed_paths(l_max: int = L_MAX):
    """All (l1, l2, l3) with a valid CG, l's <= l_max."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(l_max + 1):
                if abs(l1 - l2) <= l3 <= l1 + l2:
                    out.append((l1, l2, l3))
    return out


def sph_harm_jax(l: int, xyz):
    """jnp version of real_sph_harm (same formulas)."""
    import jax.numpy as jnp
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    c0 = 0.5 * np.sqrt(1.0 / np.pi)
    if l == 0:
        return jnp.full(xyz.shape[:-1] + (1,), c0, xyz.dtype)
    if l == 1:
        c1 = np.sqrt(3.0 / (4 * np.pi))
        return jnp.stack([c1 * y, c1 * z, c1 * x], axis=-1)
    if l == 2:
        c = np.sqrt(15.0 / (4 * np.pi))
        c20 = np.sqrt(5.0 / (16 * np.pi))
        return jnp.stack([
            c * x * y, c * y * z, c20 * (3 * z * z - 1.0), c * x * z,
            0.5 * c * (x * x - y * y)], axis=-1)
    raise NotImplementedError(l)
