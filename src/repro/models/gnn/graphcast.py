"""GraphCast-style encoder-processor-decoder mesh GNN [arXiv:2212.12794].

The assigned config (16 processor layers, d_hidden=512, sum aggregation,
n_vars=227) runs on whatever graph the shape cell provides (the benchmark
shapes are generic graphs; the icosahedral mesh refinement belongs to the
weather pipeline, which is out of scope -- the *architecture* is the
encoder + 16 interaction-network processor blocks + decoder).

Each processor block is a standard interaction network:
  e' = e + MLP([e, x_src, x_dst])          (edge update)
  x' = x + MLP([x, sum_{e into v} e'])     (node update, sum aggregation)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import (edge_mask, gather_src_dst, init_mlp, mlp_apply,
                     scatter_to_nodes)


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227           # output variables per node
    d_feat: int = 227           # input features per node (grid vars)
    d_edge: int = 8
    aggregator: str = "sum"
    mlp_layers: int = 2
    dtype: str = "float32"      # activation dtype ("bfloat16" for big cells)
    edge_chunks: int = 1        # scan edges in chunks (memory lever for
                                # 10^7..10^8-edge full-batch cells)


def init_params(cfg: GraphCastConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 4 + 2 * cfg.n_layers)
    h = cfg.d_hidden
    params = {
        "enc_node": init_mlp(ks[0], [cfg.d_feat, h, h]),
        "enc_edge": init_mlp(ks[1], [cfg.d_edge, h, h]),
        "dec_node": init_mlp(ks[2], [h, h, cfg.n_vars]),
        "layers": {
            "edge_mlp": _stack([init_mlp(ks[4 + 2 * i], [3 * h, h, h])
                                for i in range(cfg.n_layers)]),
            "node_mlp": _stack([init_mlp(ks[5 + 2 * i], [2 * h, h, h])
                                for i in range(cfg.n_layers)]),
        },
    }
    return params


def _stack(mlps: list) -> dict:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *mlps)


@jax.custom_vjp
def _residual_barrier(xs):
    """`optimization_barrier` as an identity with a trivial VJP.

    The raw primitive has no differentiation rule on jax <= 0.4.x, which
    breaks `jax.grad` through the checkpointed layer scan; the barrier only
    needs to pin the saved residuals, so its cotangent is the identity.
    """
    return jax.lax.optimization_barrier(xs)


def _residual_barrier_fwd(xs):
    return jax.lax.optimization_barrier(xs), None


def _residual_barrier_bwd(_, cts):
    return (cts,)


_residual_barrier.defvjp(_residual_barrier_fwd, _residual_barrier_bwd)


def forward(params, cfg: GraphCastConfig, batch,
            constrain_fn=None) -> jnp.ndarray:
    """batch: node_feat (N, d_feat), edge_src/dst (E,), edge_feat (E, d_edge).
    Returns per-node predictions (N, n_vars).

    constrain_fn(arr, kind) applies sharding constraints ("edge_chunked"
    keeps the reshaped (nc, ec, h) tensors edge-sharded on dim 1 -- without
    it GSPMD can pick a catastrophic resharding for the chunk scan)."""
    cst = constrain_fn or (lambda a, kind: a)
    n = batch["node_feat"].shape[0]
    dt = jnp.dtype(cfg.dtype)
    mask = edge_mask(batch["edge_src"])
    x = cst(mlp_apply(params["enc_node"], batch["node_feat"].astype(dt)),
            "nodes")
    e = cst(mlp_apply(params["enc_edge"], batch["edge_feat"].astype(dt)),
            "edges")

    src, dst = batch["edge_src"], batch["edge_dst"]
    nc = cfg.edge_chunks
    e_total = src.shape[0]
    assert e_total % nc == 0, (e_total, nc)
    ec = e_total // nc

    def body(carry, lp):
        x, e = carry
        # pin the (sharded) carry as the saved residual: without the
        # barrier GSPMD substitutes the *replicated* x_rep into the scan's
        # per-layer save stack (measured: 16 x 2.4M x 512 replicated saves,
        # 112 GiB, on ogb_products)
        x, e = _residual_barrier((x, e))
        if nc == 1:
            xs, xd = gather_src_dst(x, src, dst)
            e = e + mlp_apply(lp["edge_mlp"], jnp.concatenate([e, xs, xd], -1))
            agg = cst(scatter_to_nodes(e, dst, n, mask, agg=cfg.aggregator),
                      "nodes")
        else:
            # edge-chunked update with all node<->edge traffic hoisted out
            # of the chunk loop: x is gathered into *edge-sharded* xs/xd
            # tensors once per layer (replicated operand -> local gather),
            # so forward has ONE all-gather of x and backward emits ONE
            # scatter+psum for dx per layer.  Leaving the gathers inside
            # the (checkpointed) chunk scan instead psums the x cotangent
            # per chunk: measured 9.2 TB -> 2.0 TB -> 0.16 TB collective
            # bytes/device on ogb_products across these two steps.
            x_rep = cst(x, "nodes_replicated")
            xs_all, xd_all = gather_src_dst(x_rep, src, dst)
            xs_all = cst(xs_all, "edges")
            xd_all = cst(xd_all, "edges")

            def chunk(_, inp):
                e_c, xs, xd = inp
                e_new = e_c + mlp_apply(lp["edge_mlp"],
                                        jnp.concatenate([e_c, xs, xd], -1))
                return None, cst(e_new, "edge_chunk")

            h = e.shape[-1]
            _, e = jax.lax.scan(
                jax.checkpoint(chunk), None,
                (cst(e.reshape(nc, ec, h), "edge_chunked"),
                 cst(xs_all.reshape(nc, ec, h), "edge_chunked"),
                 cst(xd_all.reshape(nc, ec, h), "edge_chunked")))
            e = cst(e.reshape(e_total, h), "edges")
            agg = cst(scatter_to_nodes(e, dst, n, mask, agg=cfg.aggregator),
                      "nodes")
        x = cst(x + mlp_apply(lp["node_mlp"],
                              jnp.concatenate([x, agg], -1)), "nodes")
        return (x, e), None

    (x, e), _ = jax.lax.scan(jax.checkpoint(body), (x, e), params["layers"])
    return mlp_apply(params["dec_node"], x)


def loss_fn(params, cfg: GraphCastConfig, batch) -> jnp.ndarray:
    """MSE regression against per-node targets (B-step forecast proxy)."""
    pred = forward(params, cfg, batch)
    tgt = batch["targets"]
    return jnp.mean((pred.astype(jnp.float32) - tgt.astype(jnp.float32)) ** 2)
