"""Fixed-shape batched BAMG search engine (TPU-native, jit-compiled).

The host engine (`repro.core.engine.BAMGIndex`) walks the graph one query
at a time through Python, which is exact for I/O accounting but serializes
every per-query overhead.  This engine processes the *whole batch per
step* with only fixed-shape array ops, so one compilation serves the
lifetime of the server:

- **ADC tables** `(B, M, K)` are built for the whole batch at once, and
  entry selection scores them against the entry-candidate codes with the
  `repro.kernels.pq_adc` kernel (query-sensitive entries, DiskANN++-style:
  each query starts from its own best candidates, not a global medoid).
- **Candidate pool** is a pair of `(B, L)` id/dist arrays (plus a `(B, L)`
  expanded mask), kept sorted ascending by estimated distance.  Inserts are
  a vectorized insert-sort: concatenate `(B, L + R)`, stable-sort by id to
  drop duplicates (the incumbent pool entry wins, preserving its expanded
  flag), then stable-sort by distance and truncate to L.  No Python pool.
  The merge primitive lives in `repro.build.pool.pool_merge`; the
  batched construction frontier (`repro.build.frontier`) uses the same
  (B, L) pool shape with a leaner seen-mask-based merge.
- **Beam expansion** runs a fixed number of iterations (`max_hops`); each
  iteration pops the best unexpanded candidate of every row, gathers its
  padded adjacency row `(B, R)`, and ADC-scores the gathered neighbor codes
  `(B, R, M)` against the per-row tables.  Rows whose pool is exhausted
  no-op via masking (`-1` neighbors score `+inf` and never enter the pool).
  Under a `fused*` backend the whole loop instead runs as one VMEM-resident
  Pallas program (`repro.kernels.beam_fused`: frontier select, one-hot
  adjacency/code gathers, inlined rowwise ADC, and a sort-free ranked pool
  merge per hop) -- bit-identical pool ids by construction, no per-hop
  HBM round-trip.  `fused_stream*` keeps the corpus in HBM and streams it
  through double-buffered DMA slabs, so one engine serves shards larger
  than VMEM (bit-identical to the resident fused path); `backend="auto"`
  picks resident vs streaming on TPU via the `beam_fused.vmem_bytes`
  estimator.  The unfused path stays as the oracle, its per-stage
  kernels (`pq_adc`, `pq_adc_rowwise`) dispatched on the same backend knob.
- **Exact re-rank** gathers the raw vectors of each row's top `rerank` pool
  entries and merges through `repro.kernels.l2_topk.l2_topk_rowwise`.

Fixed-shape contract: one compilation per distinct `(B, D)` query shape and
`(k,)`; L, R, max_hops, rerank, and the entry-candidate count are baked at
engine construction.  Differences vs the host engine: no I/O simulation
(pure device compute), and beam expansion replaces the intra-block
alpha-BFS -- both explore the same monotonic graph, so results agree under
an exhaustive configuration (see tests/test_serve_engine.py).
"""
from __future__ import annotations

import copy
import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.build.pool import pool_merge as _pool_merge
from repro.core.pq import adc_tables as _adc_tables
from repro.kernels import beam_fused
from repro.kernels.beam_fused.ops import beam_hops
from repro.kernels.l2_topk.ops import l2_topk_rowwise
from repro.kernels.pq_adc.ops import pq_adc, pq_adc_rowwise

# backend -> the beam_hops backend the fused hop loop dispatches on
_FUSED_INNER = {"fused": "auto", "fused_pallas": "pallas",
                "fused_interpret": "interpret", "fused_ref": "ref",
                "fused_stream": "stream",
                "fused_stream_interpret": "stream_interpret"}
# the streaming modes only exist for the hop loop; per-stage kernels
# (pq_adc entry scoring) fall back to the matching resident backend
_STAGE_INNER = {"stream": "pallas", "stream_interpret": "interpret"}


def resolve_backend(backend: str, *, n: int, r: int, m: int, k: int = 256,
                    l: int = 64, max_hops: int = 32, tile_b: int = 8,
                    n_chunk: int = 2048, platform: Optional[str] = None,
                    budget: Optional[int] = None) -> str:
    """Resolve `EngineConfig.backend="auto"` to a concrete backend.

    On CPU/GPU: the unfused jnp path ("ref") -- zero behavior change for
    hosts without a TPU.  On TPU: the fused hop loop, VMEM-resident
    ("fused") when `beam_fused.vmem_bytes` fits the budget, HBM-streaming
    ("fused_stream") when the shard is too large to be VMEM-resident.
    Non-"auto" values pass through untouched.  Every value this returns
    is either "ref" or a `_FUSED_INNER` key, so auto can never fall
    through to an unresolvable backend (pinned by
    tests/test_serve_engine.py).
    """
    if backend != "auto":
        return backend
    if platform is None:
        platform = jax.default_backend()
    if platform != "tpu":
        return "ref"
    fits = beam_fused.fits_vmem(n, r, m=m, k=k, l=l, max_hops=max_hops,
                                tile_b=tile_b, n_chunk=n_chunk, budget=budget)
    return "fused" if fits else "fused_stream"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    l: int = 64               # candidate pool capacity per query
    max_hops: int = 32        # fixed beam-expansion iterations
    n_entry: int = 4          # entry seeds per query
    rerank: Optional[int] = None   # pool prefix reranked exactly (None = l)
    n_entry_cands: int = 256  # entry candidate pool scored by pq_adc
    # kernel backend, reaching entry scoring AND the hop loop:
    #   "auto"             on TPU the fused kernel -- VMEM-resident when
    #                      `beam_fused.vmem_bytes` fits the budget,
    #                      HBM-streaming ("fused_stream") when the shard
    #                      is larger than VMEM (see `resolve_backend`);
    #                      unfused jnp ("ref") on CPU
    #   "pallas"/"interpret"/"ref"   unfused hop loop; per-stage kernels
    #                      (pq_adc entry scoring, pq_adc_rowwise neighbor
    #                      scoring) on the named pq_adc backend
    #   "fused"            one Pallas program for the whole hop loop
    #                      (repro.kernels.beam_fused; auto inner backend)
    #   "fused_pallas"/"fused_interpret"/"fused_ref"   fused loop pinned
    #                      to one beam_hops backend (parity/CI)
    #   "fused_stream"/"fused_stream_interpret"   the HBM-streaming fused
    #                      loop (double-buffered DMA corpus slabs;
    #                      bit-identical pools to the resident fused path)
    backend: str = "auto"


@functools.partial(jax.jit, static_argnames=("k", "l", "max_hops", "n_entry",
                                             "rerank", "backend"))
def batched_search(x, adj, codes, codebooks, entry_cands, entry_codes,
                   queries, tomb, k: int, l: int, max_hops: int, n_entry: int,
                   rerank: int, backend: str):
    """One fixed-shape search step for a whole query batch.

    x (N, D) f32; adj (N, R) int32 VID neighbors, -1 pad; codes (N, M);
    codebooks (M, K, dsub); entry_cands (E,) int32 VIDs with their codes
    (E, M); queries (B, D); tomb (N,) bool tombstone mask (streaming
    freshness -- tombstoned VIDs stay navigable in the beam but are masked
    at the exact re-rank, so they can never reach the returned top-k; the
    mask is a traced argument, so flipping tombstones never recompiles).
    Returns (ids (B, k) int32 with -1 pad, dists (B, k) f32 ascending,
    hops_used (B,) int32).
    """
    b = queries.shape[0]
    queries = queries.astype(jnp.float32)
    backend = resolve_backend(backend, n=adj.shape[0], r=adj.shape[1],
                              m=codes.shape[1], k=codebooks.shape[1],
                              l=l, max_hops=max_hops)
    fused = backend in _FUSED_INNER
    inner = _FUSED_INNER.get(backend, backend)
    stage = _STAGE_INNER.get(inner, inner)
    tables = _adc_tables(queries, codebooks)               # (B, M, K)

    # --- query-sensitive entry selection: pq_adc over the candidate pool
    ed = pq_adc(tables, entry_codes, backend=stage)        # (B, E)
    seed_neg, seed_idx = jax.lax.top_k(-ed, n_entry)
    seed_ids = entry_cands[seed_idx].astype(jnp.int32)     # (B, n_entry)

    pool_ids = jnp.full((b, l), -1, jnp.int32)
    pool_d = jnp.full((b, l), jnp.inf, jnp.float32)
    pool_exp = jnp.zeros((b, l), bool)
    pool_ids, pool_d, pool_exp = _pool_merge(
        pool_ids, pool_d, pool_exp, seed_ids, -seed_neg, l)

    rows = jnp.arange(b)
    codes_i = codes.astype(jnp.int32)

    if fused:
        # --- one VMEM-resident program for the whole hop loop
        pool_ids, pool_d, pool_exp, hops, *_ = beam_hops(
            adj, pool_ids, pool_d, pool_exp, max_hops,
            tables=tables, codes=codes_i, backend=inner)
    else:
        def step(state, _):
            pool_ids, pool_d, pool_exp, hops = state
            frontier_d = jnp.where(pool_exp | (pool_ids < 0), jnp.inf, pool_d)
            j = jnp.argmin(frontier_d, axis=1)             # (B,)
            has = jnp.isfinite(frontier_d[rows, j])
            v = jnp.where(has, pool_ids[rows, j], 0)
            pool_exp = pool_exp.at[rows, j].set(pool_exp[rows, j] | has)
            nbrs = jnp.where(has[:, None], adj[v], -1)     # (B, R)
            nd = pq_adc_rowwise(tables, codes_i[jnp.clip(nbrs, 0)],
                                backend=inner)
            nd = jnp.where(nbrs >= 0, nd, jnp.inf)
            pool_ids, pool_d, pool_exp = _pool_merge(
                pool_ids, pool_d, pool_exp, nbrs, nd, l)
            return (pool_ids, pool_d, pool_exp, hops + has), None

        (pool_ids, pool_d, pool_exp, hops), _ = jax.lax.scan(
            step, (pool_ids, pool_d, pool_exp, jnp.zeros(b, jnp.int32)),
            None, length=max_hops)

    # --- exact re-rank of each row's pool prefix (tombstones masked here:
    # the fused hop loop never sees the mask, so this covers every backend)
    cand = pool_ids[:, :rerank]                            # (B, C)
    vecs = x[jnp.clip(cand, 0)]                            # (B, C, D)
    valid = (cand >= 0) & ~tomb[jnp.clip(cand, 0)]
    dists, ridx = l2_topk_rowwise(queries, vecs, k, valid=valid)
    ids = jnp.take_along_axis(cand, ridx, axis=1)
    ids = jnp.where(jnp.isfinite(dists), ids, -1)
    return ids, dists, hops


class BatchedANNEngine:
    """Batched fixed-shape searcher over one BAMG sub-index.

    Construct via `from_index(BAMGIndex)` (uses `BAMGIndex.batch_arrays()`)
    or directly from the array dict.  `search_batch` accepts/returns numpy;
    the device round-trip and compilation cache are keyed on (B, D, k).
    """

    # arrays moved between mesh devices by place()/replicate()
    _ARRAY_ATTRS = ("x", "adj", "codes", "codebooks", "entry_cands",
                    "entry_codes", "tomb")

    def __init__(self, arrays: dict, config: Optional[EngineConfig] = None):
        self.config = config = config if config is not None else EngineConfig()
        self.n, self.d = arrays["x"].shape
        cands = np.asarray(arrays["entry_cands"], np.int64)
        self.x = jnp.asarray(arrays["x"], jnp.float32)
        self.adj = jnp.asarray(arrays["adj"], jnp.int32)
        self.codes = jnp.asarray(arrays["codes"])
        self.codebooks = jnp.asarray(arrays["codebooks"], jnp.float32)
        self.entry_cands = jnp.asarray(cands, jnp.int32)
        self.entry_codes = jnp.asarray(arrays["codes"][cands])
        self.tomb = jnp.zeros(self.n, bool)    # tombstone mask (freshness)
        l = min(config.l, self.n)
        self._l = l
        self._rerank = min(config.rerank if config.rerank is not None else l, l)
        self._n_entry = min(config.n_entry, len(cands))
        self._fault: Optional[Exception] = None

    @classmethod
    def from_index(cls, idx, config: Optional[EngineConfig] = None):
        config = config if config is not None else EngineConfig()
        return cls(idx.batch_arrays(n_entry_cands=config.n_entry_cands),
                   config)

    @property
    def rerank_capacity(self) -> int:
        """Largest k this engine can serve (pool prefix reranked exactly)."""
        return self._rerank

    def effective_rerank(self, l: Optional[int] = None) -> int:
        """Rerank capacity under an optional per-call pool override `l`."""
        if l is None:
            return self._rerank
        return min(self._rerank, max(1, min(int(l), self.n)))

    def place(self, device) -> "BatchedANNEngine":
        """device_put this engine's arrays onto `device`, in place.

        Identity is preserved so fault hooks (`inject_fault`) and the
        sharded front-end keep pointing at the served engine."""
        for a in self._ARRAY_ATTRS:
            setattr(self, a, jax.device_put(getattr(self, a), device))
        return self

    def replicate(self, device) -> "BatchedANNEngine":
        """A copy of this engine with its arrays device_put onto `device`.

        Used for the extra replicas of a shard's replica group; fault
        state is not shared with the original."""
        new = copy.copy(self)
        new._fault = None
        return new.place(device)

    @property
    def healthy(self) -> bool:
        return self._fault is None

    def inject_fault(self, exc: Optional[Exception] = None) -> None:
        """Fault hook: every subsequent `search_batch` raises (dead shard)
        until `heal()` -- lets the sharded front-end's degraded-mode path be
        exercised without a real device failure."""
        self._fault = exc if exc is not None else RuntimeError(
            "injected engine fault")

    def heal(self) -> None:
        self._fault = None

    def set_tombstones(self, vids) -> None:
        """Replace the engine's tombstone mask (streaming freshness).

        `vids` is an iterable of VIDs to mask; out-of-range ids are
        ignored.  The mask is a traced jit argument, so this never
        triggers recompilation -- deletes take effect on the next call.
        """
        mask = np.zeros(self.n, bool)
        ids = np.asarray(list(vids), np.int64)
        if len(ids):
            ids = ids[(ids >= 0) & (ids < self.n)]
            mask[ids] = True
        self.tomb = jnp.asarray(mask)

    def search_batch(self, queries: np.ndarray, k: int, *,
                     l: Optional[int] = None, max_hops: Optional[int] = None,
                     exclude=None):
        """queries (B, D) -> (ids (B, k) int64 with -1 pad, dists (B, k)).

        `l` / `max_hops` optionally shrink the pool / hop budget for this
        call (adaptive beam width under a latency SLO -- see
        `repro.serve.runtime.scheduler`).  Both are static jit arguments,
        so each distinct override compiles once and is cached like any
        other shape; defaults reproduce the configured beam exactly.

        `exclude` masks additional VIDs for this call only (on top of any
        standing `set_tombstones` mask): excluded ids stay navigable but
        never appear in the returned top-k.  Accepts an iterable of VIDs
        or a (N,) bool mask.
        """
        if self._fault is not None:
            raise self._fault
        q = jnp.asarray(np.atleast_2d(queries), jnp.float32)
        if q.shape[1] != self.d:
            raise ValueError(f"query dim {q.shape[1]} != corpus dim {self.d}")
        l_eff = self._l if l is None else max(1, min(int(l), self.n))
        rerank = self.effective_rerank(l)
        hops = (self.config.max_hops if max_hops is None
                else max(1, int(max_hops)))
        if k > rerank:
            raise ValueError(
                f"k={k} exceeds the rerank capacity {rerank}; raise "
                f"EngineConfig.l/rerank (fixed at engine construction) or "
                f"the per-call l override")
        tomb = self.tomb
        if exclude is not None:
            if not isinstance(exclude, np.ndarray):
                exclude = sorted(exclude)       # sets/frozensets/iterables
            extra = np.asarray(exclude)
            if extra.dtype != bool:
                mask = np.zeros(self.n, bool)
                ids = extra.astype(np.int64).ravel()
                if len(ids):
                    ids = ids[(ids >= 0) & (ids < self.n)]
                    mask[ids] = True
                extra = mask
            tomb = tomb | jnp.asarray(extra)
        ids, dists, _ = batched_search(
            self.x, self.adj, self.codes, self.codebooks, self.entry_cands,
            self.entry_codes, q, tomb, k=k, l=l_eff,
            max_hops=hops, n_entry=self._n_entry,
            rerank=rerank, backend=self.config.backend)
        return np.asarray(ids, np.int64), np.asarray(dists)

    def memory_bytes(self) -> int:
        return sum(int(a.size) * a.dtype.itemsize
                   for a in (self.x, self.adj, self.codes, self.codebooks))
