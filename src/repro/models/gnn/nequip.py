"""NequIP: E(3)-equivariant interatomic potential [arXiv:2101.03164].

Assigned config: 5 layers, 32 channels, l_max=2, n_rbf=8, cutoff=5 A.

Features are dicts of irreps {l: (N, 2l+1, C)}.  One interaction layer:
  for each CG path (l1 in features) x (l2 of edge harmonic) -> l3:
      msg^(l3) += R_path(rbf(|r|)) * CG[l3,l1,l2] . (V_src^(l1) (x) Y^(l2)(r))
  aggregate msg to nodes (segment sum), then per-l linear self-interaction
  + gated nonlinearity (scalars: silu; l>0: sigmoid(scalar gate) * tensor).

The CG tensors come from so3.real_cg (numerically derived, equivariance
property-tested).  Readout: scalar channel MLP -> per-atom energy; total
energy = sum; loss = MSE on energies (forces omitted -- config-compatible
autodiff forces are exposed via `forces_fn`).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .common import bessel_rbf, edge_mask, edge_vectors, init_mlp, mlp_apply
from .so3 import DIMS, real_cg, sph_harm_jax


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    channels: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 8
    radial_hidden: int = 64


def _paths(l_max: int):
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(l_max + 1):
                if real_cg(l1, l2, l3) is not None:
                    out.append((l1, l2, l3))
    return out


def init_params(cfg: NequIPConfig, key: jax.Array) -> dict:
    paths = _paths(cfg.l_max)
    n_layer_keys = 2 + len(paths)
    ks = jax.random.split(key, 3 + cfg.n_layers * n_layer_keys)
    c = cfg.channels
    params = {"embed": jax.random.normal(ks[0], (cfg.n_species, c)) * 0.5,
              "readout": init_mlp(ks[1], [c, c, 1]), "layers": []}
    ki = 3
    for _ in range(cfg.n_layers):
        lp = {"radial": {}, "self": {}}
        for pi, (l1, l2, l3) in enumerate(paths):
            lp["radial"][f"{l1}{l2}{l3}"] = init_mlp(
                ks[ki + pi], [cfg.n_rbf, cfg.radial_hidden, c])
        for l in range(cfg.l_max + 1):
            lp["self"][str(l)] = (jax.random.normal(
                ks[ki + len(paths)], (c, c)) / np.sqrt(c))
        lp["gate"] = init_mlp(ks[ki + len(paths) + 1], [c, cfg.l_max + 1])
        params["layers"].append(lp)
        ki += n_layer_keys
    return params


def forward_energy(params, cfg: NequIPConfig, batch,
                   gather_fn=None, scatter_fn=None) -> jnp.ndarray:
    """batch: species (N,) int32, pos (N, 3), edge_src/dst (E,).
    Returns per-graph energy: graph_ids (N,) -> (n_graphs,).

    gather_fn(table_2d, idx): distributed row gather for the per-edge
    source-feature lookup (ring_gather at ogb scale -- replicating the
    (N, 25C) feature gathers costs 131 GiB/device otherwise)."""
    take = gather_fn or (lambda t, i: t[jnp.clip(i, 0, t.shape[0] - 1)])

    def _default_scat(vals, ix, rows):
        dump2 = jnp.where(ix >= 0, ix, rows)
        return jax.ops.segment_sum(vals, dump2, num_segments=rows + 1)[:rows]
    scat = scatter_fn or _default_scat
    species = batch["species"]
    pos = batch["pos"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = species.shape[0]
    mask = edge_mask(src)
    unit, r = edge_vectors(pos, src, dst)
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff) * mask[:, None]
    ylm = {l: sph_harm_jax(l, unit) for l in range(cfg.l_max + 1)}

    feats = {0: params["embed"][jnp.clip(species, 0, cfg.n_species - 1)][:, None, :]}
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((n, DIMS[l], cfg.channels))

    paths = _paths(cfg.l_max)
    s_clip = jnp.clip(src, 0, n - 1)
    dump = jnp.where(mask, dst, n)

    for lp in params["layers"]:
        msgs = {l: jnp.zeros((n, DIMS[l], cfg.channels))
                for l in range(cfg.l_max + 1)}
        for (l1, l2, l3) in paths:
            cg = jnp.asarray(real_cg(l1, l2, l3), jnp.float32)
            w = mlp_apply(lp["radial"][f"{l1}{l2}{l3}"], rbf)   # (E, C)
            f2d = feats[l1].reshape(n, -1)
            v = take(f2d, s_clip).reshape(
                s_clip.shape[0], *feats[l1].shape[1:])          # (E, 2l1+1, C)
            m = jnp.einsum("kij,eic,ej,ec->ekc", cg, v, ylm[l2], w)
            m = jnp.where(mask[:, None, None], m, 0.0)
            km = m.shape[1]
            agg = scat(m.reshape(m.shape[0], -1),
                       jnp.where(mask, dst, -1), n)
            msgs[l3] = msgs[l3] + agg.reshape(n, km, cfg.channels)
        # self-interaction + gate
        gates = jax.nn.sigmoid(mlp_apply(lp["gate"], feats[0][:, 0, :]))
        new = {}
        for l in range(cfg.l_max + 1):
            h = feats[l] + msgs[l]
            h = jnp.einsum("nic,cd->nid", h, lp["self"][str(l)])
            if l == 0:
                new[l] = jax.nn.silu(h)
            else:
                new[l] = h * gates[:, None, l:l + 1]
        feats = new

    e_atom = mlp_apply(params["readout"], feats[0][:, 0, :])[:, 0]  # (N,)
    gid = batch.get("graph_ids")
    if gid is None:
        return jnp.sum(e_atom, keepdims=True)
    # n_graphs must be static under jit: taken from the energy target shape
    ngraph = batch["energy"].shape[0]
    return jax.ops.segment_sum(e_atom, gid, num_segments=ngraph)


def loss_fn(params, cfg: NequIPConfig, batch, gather_fn=None,
            scatter_fn=None) -> jnp.ndarray:
    e = forward_energy(params, cfg, batch, gather_fn=gather_fn,
                       scatter_fn=scatter_fn)
    return jnp.mean((e - batch["energy"].astype(jnp.float32)) ** 2)


def forces_fn(params, cfg: NequIPConfig, batch) -> jnp.ndarray:
    """F = -dE/dpos (autodiff through the equivariant network)."""
    def etot(pos):
        return jnp.sum(forward_energy(params, cfg, {**batch, "pos": pos}))
    return -jax.grad(etot)(batch["pos"])
