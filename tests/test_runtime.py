"""Distributed serving runtime: instruction-stream parity + scheduler
invariants + placement/mesh satellites.

The refactor contract (ISSUE 8): the compiled SCATTER/RUN/GATHER/MERGE
program must return *bit-identical* (ids, dists) to the pre-refactor
`ShardedFrontend` scatter-gather loop -- reimplemented here verbatim as
`_legacy_scatter_gather`, the independent oracle -- on clean fleets and
with shards down.  The scheduler must never invert deadlines when forming
micro-batches, and SLO-shrunk beams must still return valid top-k.
"""
import dataclasses
import inspect

import numpy as np
import pytest

from repro.core.engine import BAMGParams
from repro.serve import (BatchedANNEngine, BeamTier, EngineConfig,
                         Scheduler, SchedulerConfig, ServeRuntime,
                         ShardedFrontend, make_requests)
from repro.serve.frontend import _merge_topk, _pad_cols
from repro.serve.runtime import (Opcode, Request, RequestQueue,
                                 compile_program)

K = 10
_CFG = EngineConfig(l=48, max_hops=24, backend="ref")


def _legacy_scatter_gather(engines, luts, queries, k, skip=()):
    """The pre-runtime ShardedFrontend loop, kept verbatim as the oracle."""
    queries = np.atleast_2d(queries)
    b = len(queries)
    all_ids, all_d = [], []
    for s, (lut, eng) in enumerate(zip(luts, engines)):
        if s in skip:
            continue
        ks = min(k, eng.rerank_capacity)
        ids_s, d_s = eng.search_batch(queries, ks)
        if ks < k:
            ids_s = np.concatenate(
                [ids_s, np.full((b, k - ks), -1, ids_s.dtype)], axis=1)
            d_s = np.concatenate(
                [d_s, np.full((b, k - ks), np.inf, d_s.dtype)], axis=1)
        all_ids.append(lut[ids_s])
        all_d.append(d_s)
    if all_ids:
        ids = np.concatenate(all_ids, axis=1)
        d = np.concatenate(all_d, axis=1)
    else:
        ids = np.full((b, k), -1, np.int64)
        d = np.full((b, k), np.inf, np.float64)
    gd, gi = _merge_topk(d, k)
    ids = _pad_cols(ids, k, -1)
    gids = np.take_along_axis(ids, gi, axis=1)
    return np.where(np.isfinite(gd), gids, -1), gd


@pytest.fixture(scope="module")
def fleet(small_corpus):
    fe = ShardedFrontend.build(small_corpus.base, n_shards=3,
                               params=BAMGParams(r=16, l_build=32, seed=0),
                               config=_CFG)
    return small_corpus, fe


# ---------------------------------------------------------------------------
# instruction stream
# ---------------------------------------------------------------------------
def test_program_structure():
    prog = compile_program(3)
    ops = [ins.op for ins in prog]
    assert ops == [Opcode.SCATTER,
                   Opcode.RUN, Opcode.GATHER,
                   Opcode.RUN, Opcode.GATHER,
                   Opcode.RUN, Opcode.GATHER,
                   Opcode.MERGE]
    assert [ins.shard for ins in prog[1:-1]] == [0, 0, 1, 1, 2, 2]
    with pytest.raises(ValueError):
        compile_program(0)


def test_runtime_bit_identical_clean(fleet):
    ds, fe = fleet
    ids, dists = fe.search_batch(ds.queries, K)
    oids, od = _legacy_scatter_gather(fe.engines, fe._lut, ds.queries, K)
    np.testing.assert_array_equal(ids, oids)
    np.testing.assert_array_equal(dists, od)


def test_runtime_bit_identical_one_shard_down(fleet):
    """Dead shard (fault hook) -> masked RUN; answers bit-identical to the
    legacy loop skipping that shard."""
    ds, fe = fleet
    clean_ids, _ = fe.search_batch(ds.queries, K)
    fe.engines[1].inject_fault()
    try:
        ids, dists, st = fe.search_batch(ds.queries, K, with_status=True)
        assert st.degraded.all() and st.shards_down == (1,)
        fe.engines[1].heal()   # oracle must call the (healed) engine
        oids, od = _legacy_scatter_gather(fe.engines, fe._lut, ds.queries, K,
                                          skip={1})
        np.testing.assert_array_equal(ids, oids)
        np.testing.assert_array_equal(dists, od)
    finally:
        fe.engines[1].heal()
        fe.mark_up(1)
    rids, _ = fe.search_batch(ds.queries, K)
    np.testing.assert_array_equal(rids, clean_ids)


def test_masked_shard_engine_not_called(fleet):
    """A marked-down shard is skipped by instruction masking -- its engine
    is never invoked (no try/except control flow on the skip path)."""
    ds, fe = fleet
    calls = {"n": 0}
    orig = fe.engines[0].search_batch

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    # shadow via an instance attribute (deleted below -- monkeypatch would
    # restore the bound method AS an instance attribute, which a later
    # engine.replicate() would then share)
    fe.engines[0].search_batch = counting
    fe.mark_down(0)
    try:
        ids, _, st = fe.search_batch(ds.queries, K, with_status=True)
        assert calls["n"] == 0 and 0 in st.shards_down
        assert (ids >= -1).all()
    finally:
        del fe.engines[0].search_batch
        fe.mark_up(0)


def test_replica_failover_keeps_shard_up(fleet):
    """With n_replicas=2, a faulted replica fails over round-robin inside
    the RUN instruction; the shard stays up and answers stay clean."""
    ds, fe = fleet
    rt = ServeRuntime(fe.shard_vids, fe.engines,
                      host_indexes=fe.host_indexes, n_replicas=2)
    clean_ids, clean_d = rt.serve_batch(ds.queries, K)
    rt.engines[0].inject_fault()     # replica 0 of shard 0 = caller's engine
    try:
        # two batches: round-robin lands on the healthy replica first, then
        # wraps onto the faulted one, which fails over inside the RUN
        for _ in range(2):
            ids, dists, st = rt.serve_batch(ds.queries, K, with_status=True)
            assert not st.degraded.any() and st.shards_up == rt.n_shards
            np.testing.assert_array_equal(ids, clean_ids)
            np.testing.assert_array_equal(dists, clean_d)
        h = rt.health()
        assert h["shards_up"] == rt.n_shards
        assert h["per_shard"][0]["errors"] >= 1
        assert h["replicas"][0] == [False, True]
    finally:
        rt.engines[0].heal()
        rt.mark_up(0)


def test_runtime_all_shards_down(fleet):
    ds, fe = fleet
    rt = fe.runtime
    for s in range(rt.n_shards):
        rt.mark_down(s)
    try:
        ids, d, st = rt.serve_batch(ds.queries, K, with_status=True)
        assert (ids == -1).all() and np.isinf(d).all() and st.shards_up == 0
    finally:
        for s in range(rt.n_shards):
            rt.mark_up(s)


# ---------------------------------------------------------------------------
# continuous-batching scheduler
# ---------------------------------------------------------------------------
def test_queue_no_deadline_inversion():
    """EDF pop: every popped deadline precedes every remaining deadline."""
    rng = np.random.default_rng(0)
    q = RequestQueue()
    for i in range(50):
        a = float(rng.uniform(0, 1))
        q.push(Request(rid=i, query=np.zeros(4), arrival=a,
                       deadline=a + float(rng.uniform(0.01, 2.0))))
    popped = q.pop_batch(16)
    assert len(popped) == 16 and len(q) == 34
    assert max(r.deadline for r in popped) <= q.min_deadline()


def test_formation_urgent_tier_first(fleet):
    """Micro-batch formation triages by slack and runs shrunk tiers first."""
    _, fe = fleet
    sched = Scheduler(fe.runtime, SchedulerConfig(k=K, max_batch=8, slo=1.0,
                                                  shrink_slack=0.5))
    now = 0.0
    for i, dl in enumerate((0.1, 2.0, 0.2, 3.0)):   # two urgent, two relaxed
        sched.queue.push(Request(rid=i, query=np.zeros(4), arrival=0.0,
                                 deadline=dl))
    batches = sched.form_microbatches(now)
    assert [t for t, _ in batches] == [1, 0]        # shrunk tier first
    assert sorted(r.rid for r in batches[0][1]) == [0, 2]
    assert sorted(r.rid for r in batches[1][1]) == [1, 3]


def test_slo_shrunk_beam_valid_topk(fleet):
    """Near-deadline requests execute on the shrunk tier and still return
    a valid (sorted, in-corpus) top-k, flagged degraded."""
    ds, fe = fleet
    sched = Scheduler(fe.runtime,
                      SchedulerConfig(k=K, max_batch=8, slo=1e-6,
                                      tiers=(BeamTier(),
                                             BeamTier(l=16, max_hops=4))))
    # deadline == arrival: zero slack at formation, every request shrinks
    reqs = [Request(rid=i, query=q, arrival=0.0, deadline=0.0)
            for i, q in enumerate(ds.queries[:8])]
    done = sched.run(reqs)
    assert len(done) == 8
    for c in done:
        assert c.tier == 1 and c.degraded
        assert c.ids.shape == (K,) and (c.ids >= 0).all()
        assert (c.ids < len(ds.base)).all()
        assert (np.diff(c.dists) >= 0).all()


def test_low_load_matches_unscheduled(fleet):
    """With generous slack every request runs the full beam: scheduled
    answers are bit-identical to the unscheduled runtime path."""
    ds, fe = fleet
    ref_ids, ref_d = fe.runtime.serve_batch(ds.queries, K)
    sched = Scheduler(fe.runtime, SchedulerConfig(k=K, max_batch=4,
                                                  slo=1e4))
    reqs = make_requests(ds.queries, qps=50.0, slo=1e4,
                         n=len(ds.queries), seed=2)
    done = sched.run(reqs)
    assert all(c.tier == 0 and not c.degraded for c in done)
    ids = np.stack([c.ids for c in done])      # rid i served query i
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(np.stack([c.dists for c in done]), ref_d)


# ---------------------------------------------------------------------------
# satellites: mesh validation + default-instance sharing
# ---------------------------------------------------------------------------
def test_make_host_mesh_validates_axis_sizes():
    import jax

    from repro.launch.mesh import make_host_mesh
    n = len(jax.devices())
    with pytest.raises(ValueError, match="zero-sized"):
        make_host_mesh(model=n + 1)
    with pytest.raises(ValueError, match="axis sizes must be >= 1"):
        make_host_mesh(model=1, data=0)
    with pytest.raises(ValueError, match="axis sizes must be >= 1"):
        make_host_mesh(model=0)
    with pytest.raises(ValueError, match="needs"):
        make_host_mesh(model=1, data=n + 1)
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")


def test_no_shared_dataclass_instance_defaults(tiny_points):
    """serve/ callables must not bake a dataclass *instance* into their
    signature (one shared object across every call)."""
    from repro.serve.deploy import BlueGreenEngine, DeploymentManager
    targets = [ShardedFrontend.build, BatchedANNEngine.__init__,
               BatchedANNEngine.from_index, DeploymentManager.validate,
               DeploymentManager.deploy, BlueGreenEngine.__init__,
               ServeRuntime.build, Scheduler.__init__]
    for fn in targets:
        for name, p in inspect.signature(fn).parameters.items():
            if p.default is inspect.Parameter.empty:
                continue
            assert not dataclasses.is_dataclass(p.default), \
                f"{fn.__qualname__}({name}=...) shares one dataclass " \
                f"instance across calls; default to None instead"
    # construct-per-call: two builds get distinct config objects
    a = ShardedFrontend.build(tiny_points, 2,
                              params=BAMGParams(r=8, l_build=16, knn_k=8))
    b = ShardedFrontend.build(tiny_points, 2,
                              params=BAMGParams(r=8, l_build=16, knn_k=8))
    assert a.engines[0].config is not b.engines[0].config


# ---------------------------------------------------------------------------
# streaming-freshness satellites (ISSUE 9): compiled-MERGE small-candidate
# regression + EDF same-deadline FIFO replay
# ---------------------------------------------------------------------------
def test_compiled_merge_fewer_candidates_than_k(fleet):
    """Regression: with all but one shard masked and a beam override that
    caps the survivor's rerank below k, the compiled MERGE sees fewer
    total candidates than k -- it must pad to k, not crash, and the tail
    must be -1/+inf."""
    ds, fe = fleet
    rt = fe.runtime
    small_l = 4
    n_valid = rt.engines[0].effective_rerank(small_l)
    assert n_valid < K                         # the premise of the test
    for s in (1, 2):
        rt.mark_down(s)
    try:
        ids, d, st = rt.serve_batch(ds.queries, K, with_status=True,
                                    l=small_l)
        assert st.shards_up == 1 and st.degraded.all()
        assert ids.shape == (len(ds.queries), K)
        assert (ids[:, :n_valid] >= 0).all()   # real results up front...
        assert (ids[:, n_valid:] == -1).all()  # ...then explicit padding
        assert np.isinf(d[:, n_valid:]).all()
        assert (np.diff(d[:, :n_valid], axis=1) >= 0).all()
        # the survivors are the true per-shard answers, globally mapped
        oids, od = fe.engines[0].search_batch(ds.queries, n_valid, l=small_l)
        np.testing.assert_array_equal(ids[:, :n_valid],
                                      fe._lut[0][np.asarray(oids)])
        np.testing.assert_array_equal(d[:, :n_valid], od)
    finally:
        rt.mark_up(1)
        rt.mark_up(2)


def test_queue_same_deadline_fifo_by_arrival():
    """Regression: requests with *equal* deadlines must dequeue in arrival
    order, even when rids are not monotone with arrival (the EDF heap
    must never fall through to comparing rids or Request objects)."""
    q = RequestQueue()
    rids = [5, 3, 9, 1, 7, 0, 8, 2]
    for i, rid in enumerate(rids):
        q.push(Request(rid=rid, query=np.zeros(4, np.float32),
                       arrival=float(i), deadline=1.0))
    out = q.pop_batch(len(rids))
    assert [r.rid for r in out] == rids        # FIFO by arrival, not by rid


def test_queue_edf_dominates_then_fifo_breaks_ties():
    """Mixed deadlines: strictly earlier deadline wins; within a deadline
    class, arrival order is preserved (stable EDF replay)."""
    q = RequestQueue()
    seq = [(9, 2.0), (4, 1.0), (7, 2.0), (1, 1.0), (8, 3.0), (0, 2.0)]
    for i, (rid, dl) in enumerate(seq):
        q.push(Request(rid=rid, query=np.zeros(2, np.float32),
                       arrival=float(i), deadline=dl))
    got = [(r.deadline, r.rid) for r in q.pop_batch(len(seq))]
    assert got == [(1.0, 4), (1.0, 1), (2.0, 9), (2.0, 7), (2.0, 0),
                   (3.0, 8)]
    assert len(q) == 0
