"""Blue/green index deployment: versioned builds, checksummed manifests,
atomic promotion, rollback.

Layout under a deployment root::

    root/
      builds/<build_id>/index.npz      the saved BAMG index artifact
      builds/<build_id>/MANIFEST.json  IndexManifest (sha256 of the artifact)
      ACTIVE                           build_id of the live index (pointer)
      HISTORY                          one promoted build_id per line

The live index is named by a single small pointer file; promotion writes
the new pointer to a temp file and `os.replace`s it over ACTIVE, so a
reader sees either the old build or the new one -- never a torn pointer.
Rollback is just promotion of the previous HISTORY entry.

Lifecycle (`DeploymentManager.deploy`): build -> publish (write artifact +
manifest) -> verify (sha256 round-trip) -> validate (recall smoke against
a golden query set) -> promote.  A build that fails validation is left
published-but-inactive for inspection; ACTIVE keeps serving the old index.

`BlueGreenEngine` is the serving side: it holds a `BatchedANNEngine` for
the ACTIVE build and `refresh()` hot-swaps the engine when the pointer
moved (the swap is one attribute assignment -- queries before it see the
old index, queries after see the new one, no in-between).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Optional

import numpy as np

from repro.core.distances import recall_at_k
from repro.core.engine import BAMGIndex, BAMGParams
from repro.utils.faults import IntegrityError

from .ann_engine import BatchedANNEngine, EngineConfig

_ARTIFACT = "index.npz"
_MANIFEST = "MANIFEST.json"


def _sha256(path: str, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                return h.hexdigest()
            h.update(b)


def _atomic_write(path: str, text: str) -> None:
    """Write-then-rename so readers never observe a partial file."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


@dataclasses.dataclass(frozen=True)
class IndexManifest:
    """Immutable description of one published build."""
    build_id: str
    created: float            # unix seconds at publish time
    path: str                 # artifact path relative to the build dir
    sha256: str               # checksum of the artifact
    n: int                    # corpus size
    d: int                    # vector dimension
    meta: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "IndexManifest":
        return cls(**json.loads(text))


class DeploymentManager:
    """Publish / verify / promote / rollback over one deployment root."""

    def __init__(self, root: str):
        self.root = root
        self.builds_dir = os.path.join(root, "builds")
        self.active_path = os.path.join(root, "ACTIVE")
        self.history_path = os.path.join(root, "HISTORY")
        os.makedirs(self.builds_dir, exist_ok=True)

    # --- publish ------------------------------------------------------------
    def publish(self, index: BAMGIndex, build_id: str,
                meta: Optional[dict] = None) -> IndexManifest:
        """Write the index artifact + checksummed manifest for `build_id`.

        Publishing does NOT change what is served; only `promote` moves the
        ACTIVE pointer."""
        bdir = os.path.join(self.builds_dir, build_id)
        os.makedirs(bdir, exist_ok=True)
        apath = os.path.join(bdir, _ARTIFACT)
        index.save(apath)
        man = IndexManifest(
            build_id=build_id, created=time.time(), path=_ARTIFACT,
            sha256=_sha256(apath), n=len(index.x), d=index.x.shape[1],
            meta=dict(meta or {}))
        _atomic_write(os.path.join(bdir, _MANIFEST), man.to_json())
        return man

    def manifest(self, build_id: str) -> IndexManifest:
        with open(os.path.join(self.builds_dir, build_id, _MANIFEST)) as f:
            return IndexManifest.from_json(f.read())

    def builds(self) -> list[str]:
        """Published build ids, oldest first (by manifest creation time)."""
        out = []
        if os.path.isdir(self.builds_dir):
            for b in os.listdir(self.builds_dir):
                if os.path.exists(os.path.join(self.builds_dir, b, _MANIFEST)):
                    out.append(b)
        return sorted(out, key=lambda b: self.manifest(b).created)

    # --- verify / load ------------------------------------------------------
    def verify(self, build_id: str) -> IndexManifest:
        """Checksum the artifact against its manifest.

        Raises `IntegrityError` on mismatch (torn write, bit rot, tampering)
        so a corrupt build can never be promoted or loaded."""
        man = self.manifest(build_id)
        apath = os.path.join(self.builds_dir, build_id, man.path)
        got = _sha256(apath)
        if got != man.sha256:
            raise IntegrityError(
                f"build {build_id!r}: artifact sha256 {got[:12]}... != "
                f"manifest {man.sha256[:12]}...")
        return man

    def load(self, build_id: str) -> BAMGIndex:
        """Verify then load a published build."""
        man = self.verify(build_id)
        return BAMGIndex.load(
            os.path.join(self.builds_dir, build_id, man.path))

    # --- promote / rollback -------------------------------------------------
    def active(self) -> Optional[str]:
        if not os.path.exists(self.active_path):
            return None
        with open(self.active_path) as f:
            return f.read().strip() or None

    def history(self) -> list[str]:
        if not os.path.exists(self.history_path):
            return []
        with open(self.history_path) as f:
            return [ln.strip() for ln in f if ln.strip()]

    def promote(self, build_id: str) -> str:
        """Atomically point ACTIVE at a verified build; append to HISTORY."""
        self.verify(build_id)
        _atomic_write(self.active_path, build_id + "\n")
        with open(self.history_path, "a") as f:
            f.write(build_id + "\n")
        return build_id

    def rollback_target(self) -> Optional[str]:
        """The build `rollback()` would promote: the most recent HISTORY
        entry that is not the active build and is still published (a
        pruned entry cannot be re-promoted, so it is skipped)."""
        published = set(self.builds())
        cur = self.active()
        for b in reversed(self.history()):
            if b != cur and b in published:
                return b
        return None

    def rollback(self) -> str:
        """Re-promote the previous distinct *still-published* build."""
        target = self.rollback_target()
        if target is None:
            raise RuntimeError("rollback: no previous build in history")
        return self.promote(target)

    def prune(self, keep: int = 2) -> list[str]:
        """Drop the oldest published builds beyond `keep`.

        The ACTIVE build and the current rollback target are protected
        unconditionally -- even `keep=0` can never delete the build being
        served or strand `rollback()`.  Returns the removed build ids."""
        import shutil
        protected = {b for b in (self.active(), self.rollback_target())
                     if b is not None}
        victims = []
        candidates = [b for b in self.builds() if b not in protected]
        n_keep = max(0, keep - len(protected))
        excess = len(candidates) - n_keep
        for b in candidates[:max(0, excess)]:
            shutil.rmtree(os.path.join(self.builds_dir, b))
            victims.append(b)
        return victims

    # --- validate / full lifecycle ------------------------------------------
    def validate(self, build_id: str, queries: np.ndarray, gt: np.ndarray,
                 k: int = 10, min_recall: float = 0.8,
                 config: Optional[EngineConfig] = None) -> float:
        """Recall smoke test of a published build against a golden set.

        Returns the measured recall; raises ValueError below `min_recall`."""
        eng = BatchedANNEngine.from_index(self.load(build_id), config)
        ids, _ = eng.search_batch(queries, min(k, eng.rerank_capacity))
        rec = recall_at_k(ids, gt[:, :ids.shape[1]], ids.shape[1])
        if rec < min_recall:
            raise ValueError(
                f"build {build_id!r} failed validation: recall@{k} "
                f"{rec:.3f} < {min_recall:.3f} (left unpromoted)")
        return rec

    def deploy(self, x: np.ndarray, build_id: str, queries: np.ndarray,
               gt: np.ndarray, params: Optional[BAMGParams] = None,
               k: int = 10, min_recall: float = 0.8,
               config: Optional[EngineConfig] = None,
               meta: Optional[dict] = None) -> IndexManifest:
        """Full lifecycle: build -> publish -> verify -> validate -> promote.

        ACTIVE is untouched until the new build passes every gate, so a bad
        deploy degrades nothing."""
        idx = BAMGIndex.build(x, params or BAMGParams())
        man = self.publish(idx, build_id, meta=meta)
        self.verify(build_id)
        rec = self.validate(build_id, queries, gt, k=k,
                            min_recall=min_recall, config=config)
        self.promote(build_id)
        return dataclasses.replace(
            man, meta={**man.meta, "validated_recall": rec})


class BlueGreenEngine:
    """Serves the ACTIVE build; `refresh()` hot-swaps on pointer moves.

    The swap is a single attribute assignment after the new engine is fully
    constructed, so `search_batch` always runs against a complete index --
    the blue index serves until the green one is ready, then the next call
    uses green."""

    def __init__(self, manager: DeploymentManager,
                 config: Optional[EngineConfig] = None,
                 keep_index: bool = False):
        self.manager = manager
        self.config = config if config is not None else EngineConfig()
        self.keep_index = keep_index   # retain the loaded BAMGIndex (the
        # streaming delta layer wires its in-memory graph off it)
        self.build_id: Optional[str] = None
        self._engine: Optional[BatchedANNEngine] = None
        self.index: Optional[BAMGIndex] = None
        self.refresh()

    def refresh(self) -> bool:
        """Follow the ACTIVE pointer; returns True when the engine swapped."""
        target = self.manager.active()
        if target is None or target == self.build_id:
            return False
        idx = self.manager.load(target)
        engine = BatchedANNEngine.from_index(idx, self.config)
        if self.keep_index:
            self.index = idx
        self._engine, self.build_id = engine, target   # atomic swap
        return True

    @property
    def engine(self) -> Optional[BatchedANNEngine]:
        """The live engine (None until a build is promoted)."""
        return self._engine

    def search_batch(self, queries: np.ndarray, k: int, exclude=None):
        if self._engine is None:
            raise RuntimeError("no ACTIVE build promoted yet")
        return self._engine.search_batch(queries, k, exclude=exclude)
