"""Paper Fig. 5: NIO (exact block reads per query) vs recall."""
from . import common


def _interp_nio_at(sw, target_recall):
    """NIO of the cheapest l reaching target recall (None if unreachable)."""
    ok = [r for r in sw if r[1] >= target_recall]
    return min(ok, key=lambda r: r[2])[2] if ok else None


def run(regimes=("sift-like", "gist-like")) -> None:
    for regime in regimes:
        sw_b = common.sweep(common.default_bamg(regime), regime)
        sw_s = common.sweep(common.starling_index(regime), regime)
        sw_d = common.sweep(common.diskann_index(regime), regime)
        for method, sw in (("bamg", sw_b), ("starling", sw_s),
                           ("diskann", sw_d)):
            for (l, recall, nio, qps, g, v) in sw:
                common.emit(f"fig5_nio.{regime}.{method}.l{l}", round(nio, 2),
                            f"recall={recall:.3f};graph={g:.1f};vec={v:.1f}")
        # NIO reduction vs Starling at matched recall
        for target in (0.8, 0.9):
            nb = _interp_nio_at(sw_b, target)
            ns = _interp_nio_at(sw_s, target)
            if nb and ns:
                common.emit(f"fig5_nio.{regime}.reduction_at_{target}",
                            round(100 * (1 - nb / ns), 1),
                            f"bamg={nb:.1f};starling={ns:.1f};pct")


if __name__ == "__main__":
    run()
