"""Paper Fig. 4: QPS vs recall for BAMG / Starling / DiskANN.

QPS is the simulator's calibrated cost model (NIO x SSD read latency +
distance compute); NIO itself is exact -- see bench_nio_recall.py.
"""
from . import common


def run(regimes=("sift-like", "gist-like")) -> None:
    for regime in regimes:
        rows = {}
        rows["bamg"] = common.sweep(common.default_bamg(regime), regime)
        rows["starling"] = common.sweep(common.starling_index(regime), regime)
        rows["diskann"] = common.sweep(common.diskann_index(regime), regime)
        for method, sw in rows.items():
            for (l, recall, nio, qps, g, v) in sw:
                common.emit(f"fig4_qps.{regime}.{method}.l{l}",
                            round(1e6 / max(qps, 1e-9), 2),
                            f"recall={recall:.3f};qps={qps:.0f}")
        # headline: QPS ratio vs Starling at the best shared recall band
        b = max(rows["bamg"], key=lambda r: r[1])
        s = max(rows["starling"], key=lambda r: r[1])
        common.emit(f"fig4_qps.{regime}.bamg_vs_starling_best",
                    round(b[3] / max(s[3], 1e-9), 3),
                    f"bamg_recall={b[1]:.3f};starling_recall={s[1]:.3f}")


if __name__ == "__main__":
    run()
