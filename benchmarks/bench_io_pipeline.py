"""Block-cache policy x capacity x queue-depth sweep over the pipelined
I/O scheduler, for all three systems (beyond-paper; ROADMAP "fast as the
hardware allows").

Emits, per the PR's acceptance criteria, for BAMG on the synthetic corpus:
  (a) `parity_nio_delta` -- batched-submission vs serial read path must
      report *identical* NIO (the scheduler changes timing, never
      accounting); the row's value is the absolute delta (must be 0).
  (b) `qd{q}.service_us` -- mean pipelined service time per query; QD>=4
      must beat QD=1.
  (c) `pinned.graph_reads` vs `lru.graph_reads` at equal cache capacity --
      pinning the hot navigation-entry blocks must strictly reduce graph
      reads.
Plus a policy x cache-size sweep (NIO + hit rate) for bamg / starling /
diskann, and a `warm` row for the cross-query warm-cache serving mode.

Fault sweep (resilience PR): read-error rate x retry budget ->
qps_pipelined, p99 service time, recall delta vs the clean run, and the
degraded-query fraction.  Acceptance: at a 1% error rate with the default
budget of 3 retries, >=95% of queries are non-degraded and nothing
crashes; a zero-rate plan is asserted bit-identical to no plan.
"""
from repro.utils.faults import FaultSpec, RetryPolicy

from . import common

POLICIES = ("lru", "fifo", "clock", "2q")
CACHE_SIZES = (16, 64, 256)
QDS = (1, 4, 16)
K, L = 10, 48
ERROR_RATES = (0.01, 0.05)
RETRY_BUDGETS = (0, 1, 3)


def run(regime: str = "sift-like") -> None:
    ds = common.dataset(regime)
    q = ds.queries

    # --- (a) batched submission vs serial: identical accounting ----------
    bamg = common.bamg_index(regime)
    serial = bamg.search_batch(q, k=K, l=L, gt=ds.gt, batch_io=False)
    bamg.configure_io(qd=8, batch_io=True)
    batched = bamg.search_batch(q, k=K, l=L, gt=ds.gt)
    delta = abs(batched.mean_nio - serial.mean_nio)
    common.emit(f"io_pipeline.{regime}.bamg.parity_nio_delta", delta,
                f"serial={serial.mean_nio:.2f};batched={batched.mean_nio:.2f};"
                f"recall_delta={abs(batched.recall - serial.recall):.4f}")
    assert delta == 0.0, "batched submission changed NIO accounting"

    # --- (b) queue-depth sweep (batched submissions) ----------------------
    svc = {}
    for qd in QDS:
        bamg.configure_io(qd=qd, batch_io=True)
        st = bamg.search_batch(q, k=K, l=L, gt=ds.gt)
        svc[qd] = st.mean_service_us
        common.emit(f"io_pipeline.{regime}.bamg.qd{qd}.service_us",
                    round(st.mean_service_us, 1),
                    f"serial_us={st.mean_serial_us:.1f};nio={st.mean_nio:.2f};"
                    f"qps_pipelined={st.qps_pipelined:.0f}")
    common.emit(f"io_pipeline.{regime}.bamg.qd_speedup_4v1",
                round(svc[1] / max(svc[4], 1e-9), 2),
                f"qd1={svc[1]:.1f}us;qd4={svc[4]:.1f}us")
    assert svc[4] < svc[1], "QD=4 must beat QD=1 on service time"

    # --- (c) pinned nav blocks vs plain LRU at equal capacity -------------
    cap = 64
    bamg.configure_io(cache_policy="lru", cache_blocks=cap, qd=1,
                      batch_io=False, pin_nav_blocks=0)
    unpinned = bamg.search_batch(q, k=K, l=L, gt=ds.gt)
    bamg.configure_io(pin_nav_blocks=cap // 2)
    pinned = bamg.search_batch(q, k=K, l=L, gt=ds.gt)
    common.emit(f"io_pipeline.{regime}.bamg.pinned.graph_reads",
                round(pinned.mean_graph_reads, 2),
                f"unpinned_lru={unpinned.mean_graph_reads:.2f};cap={cap};"
                f"pins={cap // 2};hit_rate={pinned.cache_hit_rate:.3f}")
    assert pinned.mean_graph_reads < unpinned.mean_graph_reads, \
        "pinning nav blocks must strictly reduce graph reads"
    bamg.configure_io(pin_nav_blocks=0, cache_blocks=256)

    # --- policy x cache-size sweep, all three systems ---------------------
    systems = (("bamg", bamg), ("starling", common.starling_index(regime)),
               ("diskann", common.diskann_index(regime)))
    for name, idx in systems:
        for pol in POLICIES:
            for cap in CACHE_SIZES:
                idx.configure_io(cache_policy=pol, cache_blocks=cap, qd=1,
                                 batch_io=False)
                st = idx.search_batch(q, k=K, l=L, gt=ds.gt)
                common.emit(
                    f"io_pipeline.{regime}.{name}.{pol}.c{cap}.nio",
                    round(st.mean_nio, 2),
                    f"recall={st.recall:.3f};hit_rate={st.cache_hit_rate:.3f}")
        # cross-query warm cache (serving mode), default policy/capacity
        idx.configure_io(cache_policy="lru", cache_blocks=256)
        warm = idx.search_batch(q, k=K, l=L, gt=ds.gt, warm_cache=True)
        common.emit(f"io_pipeline.{regime}.{name}.warm.nio",
                    round(warm.mean_nio, 2),
                    f"recall={warm.recall:.3f};"
                    f"hit_rate={warm.cache_hit_rate:.3f}")

    # --- fault sweep: error rate x retry budget ---------------------------
    bamg.configure_io(cache_policy="lru", cache_blocks=256, qd=8,
                      batch_io=True, faults=None, retry=None)
    clean = bamg.search_batch(q, k=K, l=L, gt=ds.gt)

    # zero-rate plan with the machinery armed: bit-identical accounting
    bamg.configure_io(faults=FaultSpec(), retry=RetryPolicy(),
                      timeout_us=20_000.0, hedge_us=500.0)
    z = bamg.search_batch(q, k=K, l=L, gt=ds.gt)
    common.emit(f"io_pipeline.{regime}.bamg.fault0.parity_nio_delta",
                abs(z.mean_nio - clean.mean_nio),
                f"recall_delta={abs(z.recall - clean.recall):.4f};"
                f"retries={z.mean_retries};hedges={z.mean_hedges}")
    assert z.mean_nio == clean.mean_nio and z.recall == clean.recall, \
        "zero-rate fault plan changed accounting"
    assert z.mean_retries == 0 and z.mean_hedges == 0

    for rate in ERROR_RATES:
        for budget in RETRY_BUDGETS:
            bamg.configure_io(faults=FaultSpec(read_error_rate=rate),
                              fault_seed=7, retry=RetryPolicy(budget=budget),
                              timeout_us=None, hedge_us=None)
            st = bamg.search_batch(q, k=K, l=L, gt=ds.gt)
            common.emit(
                f"io_pipeline.{regime}.bamg.err{rate}.retry{budget}.qps",
                round(st.qps_pipelined, 1),
                f"p99_service_us={st.p99_service_us:.1f};"
                f"recall_delta={clean.recall - st.recall:.4f};"
                f"degraded={st.degraded_fraction:.3f};"
                f"retries={st.mean_retries:.2f};"
                f"failed_reads={st.mean_failed_reads:.2f}")
            if rate == 0.01 and budget == 3:
                assert st.degraded_fraction <= 0.05, \
                    "1% errors at budget 3 must keep >=95% queries clean"
    bamg.configure_io(faults=None, retry=None, qd=1, batch_io=False)


if __name__ == "__main__":
    run()
