"""Static instruction stream for the scatter-gather serving pipeline.

The alpa pipeline runtime (SNIPPETS.md Snippet 1) compiles execution into
a per-worker list of RUN/SEND/RECV instructions walked by a dumb
interpreter; the win is that control flow -- who runs what, in which
order, what gets skipped -- becomes *data* fixed at compile time instead
of ad-hoc loop code.  The serving pipeline here is small enough for one
stream per fleet topology::

    SCATTER                      stage the query batch, snapshot the mask
    RUN(s) ; GATHER(s)   (x S)   shard-batch search ; local->global remap
    MERGE                        one global top-k over gathered candidates

`compile_program` emits the stream once per topology;
`InstructionInterpreter.execute` walks it against a per-batch execution
state.  Dead shards are *masked*: a RUN whose shard is administratively
down (or whose replica group is exhausted) marks its own and its GATHER's
slot inactive, so degraded mode is a mask over a static program, never a
different program and never control-flow-by-exception.  A replica that
raises during RUN is marked down and the RUN retries on the shard's next
healthy replica (round-robin) before the shard masks out.

Merge semantics are bit-identical to the pre-runtime `ShardedFrontend`
loop: per-shard candidates concatenate in ascending shard order, are
padded with -1/+inf when a shard contributes fewer than k, and merge via
`merge_topk`'s stable argsort (ties keep shard order).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence

import numpy as np

from .placement import ShardPlacement


class Opcode(enum.IntEnum):
    SCATTER = 0
    RUN = 1
    GATHER = 2
    MERGE = 3


@dataclasses.dataclass(frozen=True)
class Instruction:
    """One step of the serving program; `shard` is the RUN/GATHER operand."""
    op: Opcode
    shard: int = -1

    @classmethod
    def scatter(cls) -> "Instruction":
        return cls(Opcode.SCATTER)

    @classmethod
    def run(cls, shard: int) -> "Instruction":
        return cls(Opcode.RUN, shard)

    @classmethod
    def gather(cls, shard: int) -> "Instruction":
        return cls(Opcode.GATHER, shard)

    @classmethod
    def merge(cls) -> "Instruction":
        return cls(Opcode.MERGE)

    def __repr__(self) -> str:
        arg = f"({self.shard})" if self.op in (Opcode.RUN, Opcode.GATHER) \
            else ""
        return f"{self.op.name}{arg}"


def compile_program(n_shards: int) -> tuple[Instruction, ...]:
    """The static serving program for an S-shard fleet."""
    if n_shards < 1:
        raise ValueError(f"n_shards={n_shards} must be >= 1")
    prog = [Instruction.scatter()]
    for s in range(n_shards):
        prog += [Instruction.run(s), Instruction.gather(s)]
    prog.append(Instruction.merge())
    return tuple(prog)


@dataclasses.dataclass
class ServeStatus:
    """Per-batch serving report returned by `with_status=True`."""
    degraded: np.ndarray                 # (B,) bool: answer missed >=1 shard
    shards_up: int
    shards_down: tuple                   # shard indices skipped this batch


@dataclasses.dataclass
class _ExecState:
    """Mutable per-batch state threaded through the instruction stream."""
    queries: np.ndarray
    k: int
    l: Optional[int]
    max_hops: Optional[int]
    exclude: Optional[Sequence] = None   # per-shard local tombstone masks
    b: int = 0
    mask: Optional[np.ndarray] = None
    results: dict = dataclasses.field(default_factory=dict)
    all_ids: list = dataclasses.field(default_factory=list)
    all_d: list = dataclasses.field(default_factory=list)
    down: list = dataclasses.field(default_factory=list)
    ids: Optional[np.ndarray] = None
    dists: Optional[np.ndarray] = None


class InstructionInterpreter:
    """Executes a compiled serving program against the placement."""

    def __init__(self, placement: ShardPlacement,
                 luts: Sequence[np.ndarray]):
        self.placement = placement
        self.luts = list(luts)
        self._dispatch = {Opcode.SCATTER: self._scatter,
                          Opcode.RUN: self._run,
                          Opcode.GATHER: self._gather,
                          Opcode.MERGE: self._merge}

    def execute(self, program: Sequence[Instruction], queries: np.ndarray,
                k: int, *, l: Optional[int] = None,
                max_hops: Optional[int] = None,
                exclude: Optional[Sequence] = None):
        """Run one query batch through the program.

        `exclude` is an optional per-shard sequence of shard-local VID
        lists/masks (the delta-layer tombstone mask, already scattered to
        local id space by the runtime); each live RUN forwards its shard's
        entry to the engine.  Returns (ids (B, k) int64, dists (B, k),
        ServeStatus)."""
        st = _ExecState(queries=queries, k=k, l=l, max_hops=max_hops,
                        exclude=exclude)
        for ins in program:
            self._dispatch[ins.op](st, ins)
        status = ServeStatus(
            degraded=np.full(st.b, bool(st.down)),
            shards_up=self.placement.n_shards - len(st.down),
            shards_down=tuple(st.down))
        return st.ids, st.dists, status

    # --- opcodes ------------------------------------------------------------
    def _scatter(self, st: _ExecState, ins: Instruction) -> None:
        st.queries = np.atleast_2d(st.queries)
        st.b = len(st.queries)
        st.mask = self.placement.mask()

    def _run(self, st: _ExecState, ins: Instruction) -> None:
        s = ins.shard
        if not st.mask[s]:                       # masked: known-dead shard
            st.down.append(s)
            return
        while True:
            rep = self.placement.select(s)
            if rep is None:                      # replica group exhausted
                st.mask[s] = False
                st.down.append(s)
                return
            # a shard smaller than k contributes what it has, padded at
            # GATHER -- the merge still sees plenty from the other shards
            ks = min(st.k, rep.engine.effective_rerank(st.l))
            excl = st.exclude[s] if st.exclude is not None else None
            try:
                ids_s, d_s = rep.worker.run(rep, st.queries, ks,
                                            l=st.l, max_hops=st.max_hops,
                                            exclude=excl)
            except Exception as e:  # noqa: BLE001 -- replica down, try next
                self.placement.record_failure(rep, e)
                continue
            st.results[s] = (ids_s, d_s, ks)
            return

    def _gather(self, st: _ExecState, ins: Instruction) -> None:
        res = st.results.get(ins.shard)
        if res is None:                          # masked RUN: nothing to do
            return
        ids_s, d_s, ks = res
        if ks < st.k:
            ids_s = np.concatenate(
                [ids_s, np.full((st.b, st.k - ks), -1, ids_s.dtype)], axis=1)
            d_s = np.concatenate(
                [d_s, np.full((st.b, st.k - ks), np.inf, d_s.dtype)], axis=1)
        st.all_ids.append(self.luts[ins.shard][ids_s])  # -1 -> global -1
        st.all_d.append(d_s)

    def _merge(self, st: _ExecState, ins: Instruction) -> None:
        if st.all_ids:
            ids = np.concatenate(st.all_ids, axis=1)    # (B, S*k)
            d = np.concatenate(st.all_d, axis=1)
        else:                                           # every shard down
            ids = np.full((st.b, st.k), -1, np.int64)
            d = np.full((st.b, st.k), np.inf, np.float64)
        gd, gi = merge_topk(d, st.k)
        ids = pad_cols(ids, st.k, -1)                   # match merge pad
        gids = np.take_along_axis(ids, gi, axis=1)
        st.ids = np.where(np.isfinite(gd), gids, -1)
        st.dists = gd


def pad_cols(a: np.ndarray, k: int, fill) -> np.ndarray:
    """Pad (B, C) to at least k columns with `fill` (no-op when C >= k)."""
    if a.shape[1] >= k:
        return a
    pad = np.full((a.shape[0], k - a.shape[1]), fill, a.dtype)
    return np.concatenate([a, pad], axis=1)


def merge_topk(dists: np.ndarray, k: int):
    """Host-side (B, C) -> ascending (B, k); tiny, so plain numpy.

    C is normally S*k but can drop below k when shards are down or the
    fleet is small -- pad with +inf so argpartition's kth stays in range
    (the caller pads its id matrix the same way).
    """
    dists = pad_cols(dists, k, np.inf)
    part = np.argpartition(dists, k - 1, axis=1)[:, :k]
    pd = np.take_along_axis(dists, part, axis=1)
    o = np.argsort(pd, axis=1, kind="stable")
    return np.take_along_axis(pd, o, axis=1), np.take_along_axis(part, o, axis=1)
