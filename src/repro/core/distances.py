"""Batched / chunked distance computation and exact kNN.

All distances are SQUARED Euclidean unless noted -- monotone with L2, so
every lune / occlusion / ordering test in the paper is unchanged, and we
avoid sqrt everywhere (matches standard ANN practice, e.g. faiss).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=())
def _sq_l2(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(na,d),(nb,d) -> (na,nb) squared L2 via the expanded form (MXU-friendly)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    a2 = jnp.sum(a * a, axis=1, keepdims=True)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)
    d = a2 + b2.T - 2.0 * (a @ b.T)
    return jnp.maximum(d, 0.0)


def pairwise_sq_l2(a, b) -> np.ndarray:
    return np.asarray(_sq_l2(jnp.asarray(a), jnp.asarray(b)))


@functools.partial(jax.jit, static_argnames=("k",))
def _knn_chunk(q: jnp.ndarray, base: jnp.ndarray, k: int):
    d = _sq_l2(q, base)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


def exact_knn(base: np.ndarray, queries: np.ndarray, k: int, chunk: int = 1024):
    """Exact kNN by brute force, chunked over queries. Returns (dists, ids)."""
    base_j = jnp.asarray(base, jnp.float32)
    out_d, out_i = [], []
    for s in range(0, len(queries), chunk):
        dd, ii = _knn_chunk(jnp.asarray(queries[s : s + chunk], jnp.float32), base_j, k)
        out_d.append(np.asarray(dd))
        out_i.append(np.asarray(ii))
    return np.concatenate(out_d, 0), np.concatenate(out_i, 0)


def knn_graph(x: np.ndarray, k: int, chunk: int = 1024) -> np.ndarray:
    """Exact directed kNN graph (self excluded). Returns int32 (n, k).

    Rows shorter than k (corpora with fewer than k+1 points) are padded
    with -1, the standard missing-edge sentinel -- consumers skip
    negatives.
    """
    n = x.shape[0]
    _, ids = exact_knn(x, x, min(k + 1, n), chunk=chunk)
    adj = -np.ones((n, k), np.int32)
    for i in range(n):
        row = ids[i]
        row = row[row != i][:k]
        adj[i, : len(row)] = row
    return adj


def medoid(x: np.ndarray, sample: int = 4096, seed: int = 0) -> int:
    """Approximate medoid: point closest to the dataset mean.

    For n > sample the argmin is restricted to a seeded uniform sample of
    candidate points (the mean still uses every point) -- O(sample * d)
    distance work instead of O(n * d), standard for billion-scale builds.
    `sample=None` forces the exact argmin.
    """
    mean = x.mean(axis=0, keepdims=True)
    n = len(x)
    if sample is not None and n > sample:
        cand = np.random.default_rng(seed).choice(n, size=sample,
                                                  replace=False)
        d = pairwise_sq_l2(mean, x[cand])[0]
        return int(cand[np.argmin(d)])
    d = pairwise_sq_l2(mean, x)[0]
    return int(np.argmin(d))


def recall_at_k(ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    """Mean recall@k of (B, >=k) result ids against (B, >=k) ground truth.

    Padding ids (-1) never appear in ground truth, so they count as misses.
    """
    hits = sum(len(set(ids[i, :k].tolist()) & set(gt[i, :k].tolist()))
               for i in range(len(ids)))
    return hits / (len(ids) * k)
