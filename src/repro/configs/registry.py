"""Architecture registry: --arch <id> resolution for every launcher."""
from __future__ import annotations

from . import (din_cfg, dimenet_cfg, gemma_7b, graphcast_cfg,
               h2o_danube3_4b, mace_cfg, moonshot_v1_16b_a3b, nequip_cfg,
               olmo_1b, qwen2_moe_a2_7b)

ARCHS = {m.ARCH_ID: m for m in (
    h2o_danube3_4b, gemma_7b, olmo_1b, qwen2_moe_a2_7b, moonshot_v1_16b_a3b,
    graphcast_cfg, nequip_cfg, mace_cfg, dimenet_cfg, din_cfg)}


def get_arch(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells():
    """Every (arch_id, shape_name) pair -- the 40 dry-run cells."""
    out = []
    for aid, mod in ARCHS.items():
        for sname in mod.SHAPES:
            out.append((aid, sname))
    return out
