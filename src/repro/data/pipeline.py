"""Restart-safe sharded host data pipeline.

Design (DESIGN.md §4, fault tolerance):
  * Stateless: batch for global step s is a pure function of (seed, s) --
    no iterator state to checkpoint; restoring `step` restores the stream.
  * Sharded: each data-parallel host slices its rows of the global batch by
    process index, so every host touches only its shard (at 1000+ nodes the
    hosts never materialize the global batch).
  * Prefetched: a tiny double-buffer thread hides host generation latency
    (straggler mitigation: generation is bounded work per step, and a slow
    host only delays its own shard by < one step).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional


class ShardedPipeline:
    """Wraps a `make_batch(step) -> pytree` function with sharding + prefetch."""

    def __init__(self, make_batch: Callable[[int], object],
                 shard_fn: Optional[Callable[[object], object]] = None,
                 prefetch: int = 2):
        self.make_batch = make_batch
        self.shard_fn = shard_fn or (lambda b: b)
        self.prefetch = prefetch

    def batch_at(self, step: int):
        """Random access -- the restart-safety primitive."""
        return self.shard_fn(self.make_batch(step))

    def iterate(self, start_step: int, num_steps: int) -> Iterator:
        """Prefetching iterator from `start_step` (exclusive of end)."""
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = object()

        def producer():
            try:
                for s in range(start_step, start_step + num_steps):
                    q.put((s, self.batch_at(s)))
            finally:
                q.put(stop)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item


def shard_rows(process_index: int, process_count: int):
    """Row-slice a batch pytree for this host (leading dim = global batch)."""
    import jax

    def fn(batch):
        def slice_leaf(x):
            n = x.shape[0]
            per = n // process_count
            return x[process_index * per: (process_index + 1) * per]
        return jax.tree.map(slice_leaf, batch)
    return fn
