"""Fault tolerance: checkpoint/restart orchestration + failure injection.

At 1000+ nodes the relevant failure modes and this framework's answers:

  node loss / preemption     atomic checkpoints every `ckpt_every` steps
                             (train/checkpoint.py); restart resumes from
                             the latest step; the stateless step-indexed
                             data pipeline replays the exact stream.
  changed topology           elastic restore: restore_sharded device_puts
  (lose a pod, resize DP)    host arrays under the *new* mesh; ZeRO-1
                             moment shards re-partition automatically.
  mid-save crash             tmp-file + os.replace: the previous
                             checkpoint stays valid.
  stragglers                 (a) bounded per-step host work: generation is
                             O(batch) with a prefetch thread; (b) the
                             scan-over-microbatches step gives XLA slack to
                             overlap a slow replica's collective; (c) the
                             async checkpointer keeps serialization off the
                             step path.  On real multi-host TPU, slow-host
                             detection would sit in the launcher
                             (launch/train.py polls step latency EWMA and
                             reports outliers).

`run_with_recovery` drives a training loop with optional injected failures
(used by tests to prove restart-equivalence: a run killed at step k and
resumed matches the uninterrupted run bit-for-bit on CPU).

Failure injection shares `repro.utils.faults` with the storage simulator:
`SimulatedFailure` is the training face of that taxonomy, and a seeded
`FaultPlan` (``FaultSpec(step_fail_rate=...)``) can drive probabilistic
step crashes the same deterministic way the I/O layer draws read errors --
one seed reproduces an entire run's failure schedule.  Transient semantics
come from the plan's per-attempt draw: a step that failed on attempt 0 is
re-drawn under the restart's attempt number, so a retried run makes
progress exactly like a retried block read.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.utils.faults import (FaultPlan, InjectedFault,  # noqa: F401
                                SimulatedFailure)

from . import checkpoint as ckpt


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    async_save: bool = True


def run_loop(state, step_fn: Callable, batch_fn: Callable, n_steps: int,
             ft: FTConfig, fail_at: Optional[int] = None,
             fault_plan: Optional[FaultPlan] = None, fault_attempt: int = 0,
             log_every: int = 0) -> tuple[Any, list]:
    """Run from state["step"] to n_steps, checkpointing; optionally raise a
    SimulatedFailure after completing step `fail_at` (before its save), or
    wherever the seeded `fault_plan` draws a step failure
    (`FaultSpec.step_fail_rate`; `fault_attempt` is the restart count, so
    transient failures clear on retry)."""
    saver = ckpt.AsyncCheckpointer(ft.ckpt_dir, keep=ft.keep)
    metrics_log = []
    start = int(state["step"])
    ewma = None
    for s in range(start, n_steps):
        t0 = time.perf_counter()
        batch = batch_fn(s)
        state, m = step_fn(state, batch)
        if log_every and (s + 1) % log_every == 0:
            m = {k: float(v) for k, v in m.items()}
            metrics_log.append((s + 1, m))
        dt = time.perf_counter() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt  # straggler probe
        if fail_at is not None and s + 1 == fail_at:
            raise SimulatedFailure(f"injected failure after step {s + 1}")
        if fault_plan is not None and fault_plan.fail_step(s + 1, fault_attempt):
            raise SimulatedFailure(
                f"planned failure after step {s + 1} (attempt {fault_attempt})")
        if (s + 1) % ft.ckpt_every == 0 or s + 1 == n_steps:
            if ft.async_save:
                saver.save(s + 1, state)
            else:
                ckpt.save(ft.ckpt_dir, s + 1, state)
    saver.wait()
    return state, metrics_log


def resume_or_init(init_fn: Callable[[], Any], ft: FTConfig,
                   shardings=None) -> Any:
    """Restore the latest checkpoint if present, else fresh init."""
    step = ckpt.latest_step(ft.ckpt_dir)
    state = init_fn()
    if step is None:
        return state
    if shardings is not None:
        state, _ = ckpt.restore_sharded(ft.ckpt_dir, state, shardings)
    else:
        host, _ = ckpt.restore(ft.ckpt_dir, state)
        state = jax.tree.map(jax.numpy.asarray, host)
    return state


def run_with_recovery(init_fn, step_fn, batch_fn, n_steps, ft: FTConfig,
                      fail_at: Optional[int] = None,
                      fault_plan: Optional[FaultPlan] = None,
                      max_restarts: int = 3):
    """Training with automatic restart-from-checkpoint on failure.

    The restart count is fed back into the fault plan as the attempt
    number, so a plan's transient step failures are re-drawn on retry
    (persistent bad luck still exhausts `max_restarts` and re-raises)."""
    attempts = 0
    logs = []
    while True:
        state = resume_or_init(init_fn, ft)
        try:
            state, mlog = run_loop(state, step_fn, batch_fn, n_steps, ft,
                                   fail_at=fail_at, fault_plan=fault_plan,
                                   fault_attempt=attempts)
            logs.extend(mlog)
            return state, logs, attempts
        except InjectedFault:
            attempts += 1
            fail_at = None  # fail only once per run_with_recovery call
            if attempts > max_restarts:
                raise
