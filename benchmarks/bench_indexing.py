"""Paper Fig. 6 + 7: indexing time and index size."""
import time

from . import common


def run(regimes=("sift-like",)) -> None:
    for regime in regimes:
        b = common.base_graphs(regime)
        t0 = time.time()
        idx = common.bamg_index(regime)
        t_refine = time.time() - t0
        t_bamg = b["t"]["nsg"] + b["t"]["bnf"] + b["t"]["pq"] + t_refine
        common.emit(f"fig6_time.{regime}.bamg", round(t_bamg, 1),
                    f"nsg={b['t']['nsg']:.1f};bnf={b['t']['bnf']:.1f};"
                    f"refine+nav={t_refine:.1f};s")
        common.emit(f"fig6_time.{regime}.vamana_base",
                    round(b["t"]["vamana"], 1), "s (diskann/starling graph)")
        common.emit(f"fig7_size.{regime}.bamg",
                    round(idx.index_bytes() / 2 ** 20, 2),
                    f"graph={idx.store.graph_bytes/2**20:.1f}MiB;"
                    f"vec={idx.store.vector_bytes/2**20:.1f}MiB")
        common.emit(f"fig7_size.{regime}.starling",
                    round(common.starling_index(regime).index_bytes() / 2 ** 20, 2),
                    "MiB coupled")
        common.emit(f"fig7_size.{regime}.diskann",
                    round(common.diskann_index(regime).index_bytes() / 2 ** 20, 2),
                    "MiB coupled")


if __name__ == "__main__":
    run()
