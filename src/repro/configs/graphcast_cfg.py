"""graphcast [arXiv:2212.12794]: encoder-processor-decoder mesh GNN,
16 processor layers, d_hidden=512, sum aggregation, n_vars=227.

mesh_refinement=6 belongs to the weather-pipeline graph generator; the
benchmark cells supply generic graphs (configs/base.GNN_SHAPES), which the
architecture consumes unchanged (DESIGN.md §5).
"""
from repro.models.gnn.graphcast import GraphCastConfig

from .base import GNN_SHAPES

ARCH_ID = "graphcast"
FAMILY = "gnn"
SHAPES = GNN_SHAPES


def model_config(reduced: bool = False, d_feat: int = 227,
                 edge_chunks: int = 1) -> GraphCastConfig:
    if reduced:
        return GraphCastConfig(name=ARCH_ID + "-smoke", n_layers=2,
                               d_hidden=32, n_vars=8, d_feat=d_feat, d_edge=8)
    return GraphCastConfig(name=ARCH_ID, n_layers=16, d_hidden=512,
                           n_vars=227, d_feat=d_feat, d_edge=8,
                           aggregator="sum", dtype="bfloat16",
                           edge_chunks=edge_chunks)
