"""Property tests for the I/O simulator: cache policies, pinning, and the
pipelined scheduler.

Every invariant is exercised twice: with seeded numpy traces (always run, so
CI without hypothesis still locks the accounting down) and, when hypothesis
is installed, with generated traces/capacities as well.  The invariants are
the ones later PRs must not break silently:

  * hits + misses == total reads, nio == graph_reads + vector_reads
  * cache occupancy never exceeds capacity (any policy, any trace)
  * LRU evicts exactly the least-recently-used block
  * reset(drop_cache=False) preserves hit behavior; reset(True) drops it
  * NIO with an infinite cache == number of distinct blocks touched
  * the scheduler changes timing, never accounting (batched submissions and
    speculative prefetch produce bit-identical NIO/cache state)
"""
import numpy as np
import pytest

from repro.core.io_sim import (_MISS, BlockDevice, CostModel, IOScheduler,
                               LRUCache, PinnedCache, make_policy)
from repro.core.storage import DecoupledStorage, max_capacity_for

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - container without dev deps
    HAS_HYPOTHESIS = False

POLICIES = ("lru", "fifo", "clock", "2q")
SEEDS = (0, 1, 2, 3, 4)


def _trace(seed: int, n_blocks: int | None = None, length: int | None = None):
    """Random skewed read trace: half uniform, half over a small hot set
    (re-references are what distinguish the policies)."""
    rng = np.random.default_rng(seed)
    n_blocks = n_blocks or int(rng.integers(2, 40))
    length = length or int(rng.integers(1, 300))
    hot = rng.integers(0, n_blocks, size=max(1, n_blocks // 4))
    out = []
    for _ in range(length):
        if rng.random() < 0.5:
            out.append(int(rng.choice(hot)))
        else:
            out.append(int(rng.integers(0, n_blocks)))
    return n_blocks, out


def _run_trace(dev: BlockDevice, trace, check_occupancy=True):
    for b in trace:
        dev.read(b)
        if check_occupancy:
            assert len(dev.policy) <= dev.policy.capacity


# ---------------------------------------------------------------------------
# Accounting identities
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("seed", SEEDS)
def test_hits_plus_misses_equals_total_reads(policy, seed):
    n_blocks, trace = _trace(seed)
    cap = int(np.random.default_rng(seed + 100).integers(1, n_blocks + 4))
    dev = BlockDevice(list(range(n_blocks)), cache_blocks=cap, kind="graph",
                      policy=policy)
    _run_trace(dev, trace)
    assert dev.stats.cache_hits + dev.stats.nio == len(trace)
    assert dev.stats.vector_reads == 0        # graph device counts as graph
    assert dev.stats.total_accesses == len(trace)


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("seed", SEEDS[:3])
def test_infinite_cache_nio_is_distinct_blocks(policy, seed):
    n_blocks, trace = _trace(seed)
    dev = BlockDevice(list(range(n_blocks)), cache_blocks=n_blocks + 1,
                      kind="vector", policy=policy)
    _run_trace(dev, trace)
    assert dev.stats.nio == len(set(trace))
    assert dev.stats.vector_reads == dev.stats.nio   # kind routes the counter


@pytest.mark.parametrize("seed", SEEDS[:3])
def test_nio_is_graph_plus_vector_reads(seed):
    """End-to-end over the decoupled layout: graph + vector devices."""
    rng = np.random.default_rng(seed)
    n, d, r = 40, 12, 4
    x = rng.normal(size=(n, d)).astype(np.float32)
    adj = rng.integers(0, n, (n, r)).astype(np.int32)
    cap = max_capacity_for(r)
    blocks = (np.arange(n) // cap).astype(np.int32)
    m = int(blocks.max()) + 1
    members = -np.ones((m, cap), np.int32)
    for b in range(m):
        mem = np.nonzero(blocks == b)[0]
        members[b, :len(mem)] = mem
    st = DecoupledStorage(x, adj, blocks, members, cache_blocks=2,
                          vec_cache_blocks=2)
    for _ in range(60):
        if rng.random() < 0.5:
            st.read_graph_block(int(rng.integers(0, m)))
        else:
            st.read_vector(int(st.vid2oid[int(rng.integers(0, n))]))
    g, v = st.graph_dev.stats, st.vector_dev.stats
    assert g.nio == g.graph_reads and g.vector_reads == 0
    assert v.nio == v.vector_reads and v.graph_reads == 0
    assert (g.nio + v.nio) == (g.graph_reads + v.vector_reads)


def test_none_payload_counts_as_hit():
    """Regression: a cached payload of None must register as a hit (the old
    `_cache.pop(id, None)` miss marker re-read span placeholders forever)."""
    dev = BlockDevice([None, None, b"x"], cache_blocks=4, kind="graph")
    assert dev.read(0) is None
    assert dev.read(0) is None
    assert dev.stats.graph_reads == 1 and dev.stats.cache_hits == 1


def test_miss_sentinel_is_not_none():
    p = LRUCache(4)
    p.put(1, None)
    assert p.get(1) is None and p.get(1) is not _MISS
    assert p.get(2) is _MISS


# ---------------------------------------------------------------------------
# Policy behavior
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", SEEDS)
def test_lru_evicts_exactly_least_recently_used(seed):
    """Model-based check: the resident set must match a reference LRU after
    every read of a random trace."""
    from collections import OrderedDict
    n_blocks, trace = _trace(seed)
    cap = int(np.random.default_rng(seed + 7).integers(1, n_blocks + 2))
    dev = BlockDevice(list(range(n_blocks)), cache_blocks=cap, policy="lru")
    ref: OrderedDict[int, None] = OrderedDict()
    for b in trace:
        dev.read(b)
        if b in ref:
            ref.move_to_end(b)
        else:
            ref[b] = None
            while len(ref) > cap:
                ref.popitem(last=False)      # exactly the LRU entry
        assert set(dev.policy.keys()) == set(ref)


def test_lru_eviction_order_direct():
    dev = BlockDevice(list(range(8)), cache_blocks=3, policy="lru")
    dev.read(0); dev.read(1); dev.read(2)
    dev.read(0)                     # 1 is now least-recently-used
    dev.read(3)                     # evicts exactly 1
    assert set(dev.policy.keys()) == {0, 2, 3}
    dev.read(1)
    assert dev.stats.graph_reads == 5       # 1 was truly evicted


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("seed", SEEDS[:3])
def test_reset_keep_cache_preserves_hit_behavior(policy, seed):
    n_blocks, trace = _trace(seed)
    dev = BlockDevice(list(range(n_blocks)), cache_blocks=max(2, n_blocks // 2),
                      policy=policy)
    _run_trace(dev, trace)
    resident = [b for b in range(n_blocks) if dev.cached(b)]
    dev.reset(drop_cache=False)
    assert dev.stats.nio == 0 and dev.stats.cache_hits == 0
    for b in resident:
        dev.read(b)
    assert dev.stats.nio == 0                      # all still hits
    assert dev.stats.cache_hits == len(resident)
    dev.reset(drop_cache=True)
    if resident:
        dev.read(resident[0])
        assert dev.stats.nio == 1                  # cold again


def test_fifo_does_not_refresh_on_hit():
    dev = BlockDevice(list(range(8)), cache_blocks=2, policy="fifo")
    dev.read(0); dev.read(1)
    dev.read(0)                  # hit; FIFO keeps 0 the oldest anyway
    dev.read(2)                  # evicts 0 (oldest insertion), not 1
    assert set(dev.policy.keys()) == {1, 2}


def test_clock_second_chance():
    dev = BlockDevice(list(range(8)), cache_blocks=2, policy="clock")
    dev.read(0); dev.read(1)
    dev.read(0)                  # sets 0's reference bit
    dev.read(2)                  # hand clears 0's bit, evicts 1
    assert set(dev.policy.keys()) == {0, 2}


def test_2q_scan_resistance():
    """Blocks re-referenced after their A1in probation land in Am and then
    survive a long one-pass scan (which only churns A1in)."""
    dev = BlockDevice(list(range(64)), cache_blocks=8, policy="2q")
    for b in range(10):          # fill + overflow A1in: 0,1 demoted to ghost
        dev.read(b)
    dev.read(0); dev.read(1)     # ghosted -> promoted into Am (hot)
    for b in range(20, 50):      # long cold scan through A1in
        dev.read(b)
    assert dev.cached(0) and dev.cached(1)   # Am survived the scan
    dev.reset(drop_cache=False)
    dev.read(0); dev.read(1)
    assert dev.stats.nio == 0


# ---------------------------------------------------------------------------
# Pinned cache
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", SEEDS[:3])
def test_pinned_blocks_never_miss_and_never_evict(seed):
    n_blocks, trace = _trace(seed, n_blocks=30)
    pins = (0, 5, 7)
    dev = BlockDevice(list(range(n_blocks)), cache_blocks=8, policy="lru",
                      pinned=pins)
    _run_trace(dev, trace)
    for p in pins:
        assert dev.cached(p)
    before = dev.stats.nio
    for p in pins:
        dev.read(p)
    assert dev.stats.nio == before           # pinned reads are always hits
    assert len(dev.policy) <= 8              # pins count against capacity
    dev.reset(drop_cache=True)               # re-pins on reset
    dev.read(5)
    assert dev.stats.nio == 0


def test_pins_exceeding_capacity_raise():
    with pytest.raises(ValueError):
        PinnedCache(2, pins=(0, 1, 2))


def test_make_policy_rejects_unknown():
    with pytest.raises(ValueError):
        make_policy("arc", 8)


# ---------------------------------------------------------------------------
# Scheduler: timing never changes accounting
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("seed", SEEDS[:3])
def test_batched_submissions_identical_accounting(policy, seed):
    """The same demand trace, issued per-read vs in random batches, must
    produce bit-identical NIO, hits, and resident sets."""
    rng = np.random.default_rng(seed + 50)
    n_blocks, trace = _trace(seed)
    cap = max(1, n_blocks // 2)
    dev_a = BlockDevice(list(range(n_blocks)), cache_blocks=cap, policy=policy)
    dev_b = BlockDevice(list(range(n_blocks)), cache_blocks=cap, policy=policy)
    sch_a = IOScheduler(CostModel(qd=1))
    sch_b = IOScheduler(CostModel(qd=4))
    for b in trace:
        sch_a.read(dev_a, b)
    i = 0
    while i < len(trace):
        step = int(rng.integers(1, 6))
        sch_b.submit(dev_b, trace[i: i + step])
        i += step
    assert dev_a.stats.nio == dev_b.stats.nio
    assert dev_a.stats.cache_hits == dev_b.stats.cache_hits
    assert set(dev_a.policy.keys()) == set(dev_b.policy.keys())
    assert sch_a.serial_us == sch_b.serial_us
    assert sch_a.service_us == sch_a.serial_us        # qd=1: no overlap
    assert sch_b.service_us <= sch_b.serial_us        # qd=4: overlapped


@pytest.mark.parametrize("seed", SEEDS[:3])
def test_speculative_prefetch_never_touches_accounting(seed):
    """Random prefetch hints alongside each demand read: NIO, hits, and the
    resident set must be bit-identical to the hint-free run."""
    rng = np.random.default_rng(seed + 9)
    n_blocks, trace = _trace(seed, n_blocks=24)
    dev_a = BlockDevice(list(range(n_blocks)), cache_blocks=6)
    dev_b = BlockDevice(list(range(n_blocks)), cache_blocks=6)
    sch_a, sch_b = IOScheduler(), IOScheduler()
    for b in trace:
        sch_a.submit(dev_a, [b])
        hints = rng.integers(0, n_blocks, size=int(rng.integers(0, 4)))
        sch_b.submit(dev_b, [b], prefetch=hints.tolist())
    assert dev_a.stats.nio == dev_b.stats.nio
    assert dev_a.stats.cache_hits == dev_b.stats.cache_hits
    assert set(dev_a.policy.keys()) == set(dev_b.policy.keys())
    assert sch_a.serial_us == sch_b.serial_us         # accounting domain
    assert sch_b.service_us >= sch_a.service_us - 1e-9  # qd=1: hints only add


def test_prefetch_hit_makes_later_demand_free():
    dev = BlockDevice(list(range(8)), cache_blocks=4)
    sch = IOScheduler(CostModel(qd=2, read_us=100.0))
    sch.submit(dev, [0], prefetch=[1])      # 2 reads overlapped at qd=2
    assert sch.service_us == 100.0 and sch.serial_us == 100.0
    sch.submit(dev, [1])                    # prefetched: free in time...
    assert sch.service_us == 100.0
    assert dev.stats.nio == 2               # ...but still one NIO (data moved)
    assert sch.prefetch_hits == 1


@pytest.mark.parametrize("seed", SEEDS)
def test_service_never_exceeds_serial(seed):
    """Invariant: speculation only fills idle queue slots, so the pipelined
    service time can never exceed the serial baseline -- for any qd, any
    prefetch hints, any trace."""
    rng = np.random.default_rng(seed + 1234)
    n_blocks, trace = _trace(seed, n_blocks=24)
    qd = int(rng.integers(1, 9))
    submit_us = float(rng.choice([0.0, 2.0]))
    dev = BlockDevice(list(range(n_blocks)), cache_blocks=6)
    sch = IOScheduler(CostModel(qd=qd, submit_us=submit_us))
    for b in trace:
        hints = rng.integers(0, n_blocks, size=int(rng.integers(0, 5)))
        sch.submit(dev, [b], prefetch=hints.tolist())
    assert sch.service_us <= sch.serial_us + 1e-9
    if qd == 1 and submit_us == 0.0:
        assert sch.service_us == sch.serial_us   # no idle slots: no overlap


def test_make_policy_instance_with_pins_respects_capacity():
    """A caller-supplied policy instance + pins must still bound total
    residency (pins + inner) by the requested capacity."""
    pol = make_policy(LRUCache(8), 8, pins=(0, 1))
    assert isinstance(pol, PinnedCache)
    for b in range(20):
        pol.put(b, b)
    pol.put(0, 0); pol.put(1, 1)     # preload pins
    assert len(pol) <= 8
    assert pol.contains(0) and pol.contains(1)


def test_submission_time_ceil_model():
    cm = CostModel(read_us=100.0, qd=4)
    assert cm.submission_us(0) == 0.0
    assert cm.submission_us(1) == 100.0
    assert cm.submission_us(4) == 100.0
    assert cm.submission_us(5) == 200.0
    assert CostModel(read_us=100.0, qd=1).submission_us(5) == 500.0


# ---------------------------------------------------------------------------
# Hypothesis variants (run when the dev deps are installed)
# ---------------------------------------------------------------------------
if HAS_HYPOTHESIS:
    trace_strategy = hst.lists(hst.integers(min_value=0, max_value=31),
                               min_size=1, max_size=200)

    @settings(max_examples=30, deadline=None)
    @given(trace=trace_strategy, cap=hst.integers(min_value=1, max_value=40),
           policy=hst.sampled_from(POLICIES))
    def test_hyp_occupancy_and_accounting(trace, cap, policy):
        dev = BlockDevice(list(range(32)), cache_blocks=cap, policy=policy)
        for b in trace:
            dev.read(b)
            assert len(dev.policy) <= cap
        assert dev.stats.cache_hits + dev.stats.nio == len(trace)
        if cap >= 32:
            assert dev.stats.nio == len(set(trace))

    @settings(max_examples=30, deadline=None)
    @given(trace=trace_strategy, cap=hst.integers(min_value=1, max_value=40))
    def test_hyp_lru_reference_model(trace, cap):
        from collections import OrderedDict
        dev = BlockDevice(list(range(32)), cache_blocks=cap, policy="lru")
        ref: OrderedDict[int, None] = OrderedDict()
        for b in trace:
            dev.read(b)
            if b in ref:
                ref.move_to_end(b)
            else:
                ref[b] = None
                while len(ref) > cap:
                    ref.popitem(last=False)
            assert set(dev.policy.keys()) == set(ref)

    @settings(max_examples=30, deadline=None)
    @given(trace=trace_strategy, policy=hst.sampled_from(POLICIES),
           qd=hst.integers(min_value=1, max_value=8),
           chunks=hst.lists(hst.integers(min_value=1, max_value=7),
                            min_size=1, max_size=50))
    def test_hyp_scheduler_accounting_invariant(trace, policy, qd, chunks):
        dev_a = BlockDevice(list(range(32)), cache_blocks=8, policy=policy)
        dev_b = BlockDevice(list(range(32)), cache_blocks=8, policy=policy)
        sch_a = IOScheduler(CostModel(qd=1))
        sch_b = IOScheduler(CostModel(qd=qd))
        for b in trace:
            sch_a.read(dev_a, b)
        i = ci = 0
        while i < len(trace):
            step = chunks[ci % len(chunks)]
            sch_b.submit(dev_b, trace[i: i + step])
            i += step
            ci += 1
        assert dev_a.stats.nio == dev_b.stats.nio
        assert dev_a.stats.cache_hits == dev_b.stats.cache_hits
        assert set(dev_a.policy.keys()) == set(dev_b.policy.keys())
        assert sch_b.service_us <= sch_a.service_us + 1e-9
