"""Product Quantization: codebook training (JAX k-means), encoding, ADC.

The paper (following DiskANN) keeps PQ codes of all vectors in memory and
uses asymmetric distance computation (ADC) to order the search pool; raw
vectors are only read from disk for the final re-rank.

TPU adaptation (DESIGN.md §2): ADC on TPU is a one-hot @ LUT matmul (MXU)
instead of a gather LUT -- see kernels/pq_adc. This module holds the
reference / host implementations and training.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PQCodec:
    """codebooks: (M, K, dsub) float32; codes are uint8 (n, M)."""

    codebooks: np.ndarray

    @property
    def m(self) -> int:
        return self.codebooks.shape[0]

    @property
    def k(self) -> int:
        return self.codebooks.shape[1]

    @property
    def dsub(self) -> int:
        return self.codebooks.shape[2]

    @property
    def dim(self) -> int:
        return self.m * self.dsub

    # -- encoding -----------------------------------------------------------
    def encode(self, x: np.ndarray, chunk: int = 8192) -> np.ndarray:
        cb = jnp.asarray(self.codebooks)
        out = []
        for s in range(0, len(x), chunk):
            out.append(np.asarray(_encode(jnp.asarray(x[s : s + chunk], jnp.float32), cb)))
        return np.concatenate(out, 0).astype(np.uint8)

    def decode(self, codes: np.ndarray) -> np.ndarray:
        """Reconstruct vectors from codes (for error analysis)."""
        m = self.m
        parts = [self.codebooks[j][codes[:, j].astype(np.int64)] for j in range(m)]
        return np.concatenate(parts, axis=1)

    # -- ADC ----------------------------------------------------------------
    def adc_table(self, q: np.ndarray) -> np.ndarray:
        """Query -> (M, K) table of squared L2 distances per subspace."""
        return np.asarray(_adc_table(jnp.asarray(q, jnp.float32), jnp.asarray(self.codebooks)))

    def adc_tables(self, qs: np.ndarray) -> np.ndarray:
        """(B,d) -> (B, M, K)."""
        return np.asarray(adc_tables(jnp.asarray(qs, jnp.float32),
                                     jnp.asarray(self.codebooks)))

    def estimate(self, table: np.ndarray, codes: np.ndarray) -> np.ndarray:
        """ADC: (M,K) table + (n,M) codes -> (n,) estimated squared distances."""
        return _estimate_np(table, codes)

    def save(self, path: str) -> None:
        np.savez(path, codebooks=self.codebooks)

    @staticmethod
    def load(path: str) -> "PQCodec":
        with np.load(path) as z:
            return PQCodec(codebooks=z["codebooks"])


def _estimate_np(table: np.ndarray, codes: np.ndarray) -> np.ndarray:
    # table: (M,K); codes: (n,M) -> sum_m table[m, codes[:,m]]
    m = table.shape[0]
    acc = np.zeros(codes.shape[0], np.float32)
    for j in range(m):
        acc += table[j, codes[:, j].astype(np.int64)]
    return acc


@jax.jit
def _encode(x: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    # x: (b, M*dsub); codebooks: (M,K,dsub)
    m, k, dsub = codebooks.shape
    xs = x.reshape(x.shape[0], m, dsub)

    def per_sub(xm, cbm):  # (b,dsub),(K,dsub)
        d = (
            jnp.sum(xm * xm, 1, keepdims=True)
            - 2 * xm @ cbm.T
            + jnp.sum(cbm * cbm, 1)[None, :]
        )
        return jnp.argmin(d, axis=1)

    codes = jax.vmap(per_sub, in_axes=(1, 0), out_axes=1)(xs, codebooks)
    return codes.astype(jnp.uint8)


@jax.jit
def _adc_table(q: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    m, k, dsub = codebooks.shape
    qs = q.reshape(m, 1, dsub)
    diff = qs - codebooks
    return jnp.sum(diff * diff, axis=-1)  # (M, K)


def adc_tables(qs: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """Batched ADC tables, jnp in/out: (B, d) x (M, K, dsub) -> (B, M, K).

    The single jnp definition of the table formula -- the host codec and
    the batched serving engine both route through it."""
    return jax.vmap(_adc_table, in_axes=(0, None))(qs, codebooks)


# -- training ---------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("iters",))
def _kmeans_one(data: jnp.ndarray, init: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Lloyd iterations for one subspace. data (n,dsub), init (K,dsub)."""

    def step(cent, _):
        d = (
            jnp.sum(data * data, 1, keepdims=True)
            - 2 * data @ cent.T
            + jnp.sum(cent * cent, 1)[None, :]
        )
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, cent.shape[0], dtype=jnp.float32)
        counts = onehot.sum(0)
        sums = onehot.T @ data
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), cent)
        return new, None

    cent, _ = jax.lax.scan(step, init, None, length=iters)
    return cent


def train_pq(
    x: np.ndarray, m: int = 16, k: int = 256, iters: int = 12, sample: int = 65536, seed: int = 0
) -> PQCodec:
    """Train a PQ codec on (a sample of) x. d must be divisible by m."""
    n, d = x.shape
    if d % m != 0:
        raise ValueError(f"d={d} not divisible by M={m}")
    rng = np.random.default_rng(seed)
    if n > sample:
        x = x[rng.choice(n, sample, replace=False)]
    n = x.shape[0]
    k_eff = min(k, n)
    dsub = d // m
    xs = jnp.asarray(x, jnp.float32).reshape(n, m, dsub)
    inits = []
    for j in range(m):
        idx = rng.choice(n, k_eff, replace=False)
        init = np.asarray(xs[:, j, :])[idx]
        if k_eff < k:  # pad duplicate centroids (tiny datasets / tests)
            init = np.concatenate([init, init[rng.integers(0, k_eff, k - k_eff)]], 0)
        inits.append(init)
    inits = jnp.asarray(np.stack(inits), jnp.float32)  # (M,K,dsub)
    cents = jax.vmap(_kmeans_one, in_axes=(1, 0, None))(xs, inits, iters)
    return PQCodec(codebooks=np.asarray(cents, np.float32))
