"""Cell builder: (arch, shape, mesh) -> step fn + abstract inputs + shardings.

A *cell* is one dry-run unit: the exact jitted step a production job would
run for that architecture and input shape, with every argument described by
a ShapeDtypeStruct (no allocation) and every input tree annotated with a
NamedSharding.  launch/dryrun.py lowers + compiles each cell;
roofline/analysis.py reads the compiled artifacts.

Family mapping:
  lm     train_4k -> train_step (fwd+bwd+AdamW, ZeRO-1 moments)
         prefill_32k -> serve_prefill;  decode_* -> decode_step
  gnn    all shapes -> train_step on the shape's (padded) graph
  recsys train_batch -> train_step; serve_* -> forward_scores;
         retrieval_cand -> retrieval cascade (l2 shortlist + DIN rerank)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ShapeSpec, pad_to_multiple
from ..configs.registry import get_arch
from ..models.transformer import (LMConfig, ShardCtx, cache_len_for,
                                  cache_specs, decode_step, init_cache,
                                  init_lm_params, lm_loss, lm_param_specs,
                                  serve_prefill)
from ..train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                               opt_state_specs)

F32, I32 = jnp.float32, jnp.int32


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable                  # positional-arg step function
    args: tuple                   # ShapeDtypeStructs (pytrees)
    in_shardings: tuple           # NamedSharding pytrees matching args
    model_flops: float            # useful-FLOPs estimate (MODEL_FLOPS)
    comment: str = ""
    donate: tuple = ()            # donated arg indices (state / KV caches)

    def lower(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       donate_argnums=self.donate).lower(*self.args)


def _shardings(mesh: Mesh, spec_tree, like_tree):
    """Map a PartitionSpec tree (None = replicated) to NamedShardings."""
    def one(spec, _leaf):
        return NamedSharding(mesh, spec if spec is not None else P())
    return jax.tree.map(one, spec_tree, like_tree,
                        is_leaf=lambda x: x is None or isinstance(x, P))


def _batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp(mesh: Mesh) -> int:
    n = 1
    for a in _batch_axes(mesh):
        n *= mesh.devices.shape[mesh.axis_names.index(a)]
    return n


def _nmesh(mesh: Mesh) -> int:
    return int(np.prod(mesh.devices.shape))


def build_cell(arch_id: str, shape_name: str, mesh: Mesh,
               opt_overrides: Optional[dict] = None) -> Cell:
    mod = get_arch(arch_id)
    shape = mod.SHAPES[shape_name]
    if mod.FAMILY == "lm":
        return _build_lm(mod, shape, mesh, opt_overrides or {})
    if mod.FAMILY == "gnn":
        return _build_gnn(mod, shape, mesh, opt_overrides or {})
    if mod.FAMILY == "recsys":
        return _build_recsys(mod, shape, mesh, opt_overrides or {})
    raise ValueError(mod.FAMILY)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------
def _lm_state_specs(cfg: LMConfig, ctx: ShardCtx, opt_cfg, mesh):
    # 2D FSDP("data") x TP("model") parameter layout: params, grads and
    # AdamW moments all fully sharded (ZeRO-3-style memory)
    p_specs = lm_param_specs(cfg, ctx, fsdp_axis="data")
    p_shapes = jax.eval_shape(
        lambda: init_lm_params(cfg, jax.random.PRNGKey(0)))
    o_specs = opt_state_specs(p_specs, zero1=False)
    return {"step": P(), "params": p_specs, "opt": o_specs}, p_shapes


def _lm_flops(cfg: LMConfig, tokens: int, seq: int, train: bool) -> float:
    """6*N_active*D (+ causal attention term) for train; 2*N*D for fwd."""
    n_act = cfg.n_active_params()
    mult = 6.0 if train else 2.0
    core = mult * n_act * tokens
    # attention scores+values: 2 * 2 * S_eff * H * dh per token (x3 for bwd)
    s_eff = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    attn = (2 if not train else 6) * 2 * tokens * (s_eff / 2) \
        * cfg.n_heads * cfg.d_head * cfg.n_layers
    return core + attn


def _build_lm(mod, shape: ShapeSpec, mesh: Mesh, opt_over) -> Cell:
    cfg: LMConfig = mod.model_config()
    ctx = ShardCtx(mesh=mesh)
    ba = _batch_axes(mesh)
    dp = _dp(mesh)
    b = shape.global_batch
    batch_spec = P(ba, None) if b % max(dp, 1) == 0 and b >= dp else P(None, None)
    bvec_spec = P(ba) if b % max(dp, 1) == 0 and b >= dp else P(None)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(**opt_over) if opt_over else AdamWConfig()
        state_specs, p_shapes = _lm_state_specs(cfg, ctx, opt_cfg, mesh)
        state_sds = jax.eval_shape(lambda: {
            "step": jnp.zeros((), I32),
            "params": init_lm_params(cfg, jax.random.PRNGKey(0)),
            "opt": adamw_init(init_lm_params(cfg, jax.random.PRNGKey(0)))})

        accum = max(getattr(mod, "TRAIN_ACCUM", shape.accum), 1)

        def train_step(state, tokens, labels):
            params = state["params"]

            def grads_of(tok, lab):
                return jax.value_and_grad(
                    lambda p: lm_loss(p, cfg, tok, lab, ctx),
                    has_aux=True)(params)

            if accum == 1:
                (loss, _parts), g = grads_of(tokens, labels)
            else:
                # microbatch scan: halves the live activation carries and
                # lets XLA overlap each microbatch's DP collectives with the
                # next one's backward
                tm = tokens.reshape(accum, b // accum, shape.seq_len)
                lm_ = labels.reshape(accum, b // accum, shape.seq_len)

                def mb(carry, inp):
                    g_acc, l_acc = carry
                    (l, _), g = grads_of(*inp)
                    return (jax.tree.map(
                        lambda a, bb: a + bb.astype(F32), g_acc, g),
                        l_acc + l), None

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
                (g, lsum), _ = jax.lax.scan(mb, (g0, jnp.float32(0)),
                                            (tm, lm_))
                g = jax.tree.map(lambda x: x / accum, g)
                loss = lsum / accum
            new_p, new_o, om = adamw_update(opt_cfg, g, state["opt"], params)
            return (dict(step=state["step"] + 1, params=new_p, opt=new_o),
                    {"loss": loss, **om})

        tok = jax.ShapeDtypeStruct((b, shape.seq_len), I32)
        args = (state_sds, tok, tok)
        shardings = (_shardings(mesh, state_specs, state_sds),
                     NamedSharding(mesh, batch_spec),
                     NamedSharding(mesh, batch_spec))
        return Cell(mod.ARCH_ID, shape.name, "train", train_step, args,
                    shardings,
                    _lm_flops(cfg, b * shape.seq_len, shape.seq_len, True),
                    donate=(0,))

    p_specs = lm_param_specs(cfg, ctx)
    p_sds = jax.eval_shape(lambda: init_lm_params(cfg, jax.random.PRNGKey(0)))
    # serving holds parameters in bf16 (standard practice; halves HBM)
    p_sds = jax.tree.map(
        lambda s_: jax.ShapeDtypeStruct(s_.shape, jnp.bfloat16)
        if jnp.issubdtype(s_.dtype, jnp.floating) else s_, p_sds)
    p_shard = _shardings(mesh, p_specs, p_sds)

    if shape.kind == "prefill":
        def prefill(params, tokens):
            return serve_prefill(params, cfg, tokens, ctx)
        tok = jax.ShapeDtypeStruct((b, shape.seq_len), I32)
        return Cell(mod.ARCH_ID, shape.name, "prefill", prefill,
                    (p_sds, tok), (p_shard, NamedSharding(mesh, batch_spec)),
                    _lm_flops(cfg, b * shape.seq_len, shape.seq_len, False))

    # decode
    tp = ctx.tp
    kv_mode = shape.kv_mode
    if kv_mode == "auto":
        kv_mode = "head" if cfg.n_kv_heads % tp == 0 else "seq"
    sc = cache_len_for(cfg, shape.seq_len)
    cache_sds = jax.eval_shape(lambda: init_cache(cfg, b, shape.seq_len))
    ck_spec, cv_spec, len_spec = cache_specs(cfg, ctx, kv_mode)
    if b < dp:  # batch=1 cells: batch dim replicated
        ck_spec = P(None, *list(ck_spec)[1:])
        cv_spec = ck_spec
    cache_shard = (NamedSharding(mesh, ck_spec), NamedSharding(mesh, cv_spec),
                   NamedSharding(mesh, len_spec))

    def decode(params, tokens, positions, caches):
        return decode_step(params, cfg, tokens, positions, caches, ctx,
                           kv_mode=kv_mode)

    tok = jax.ShapeDtypeStruct((b, 1), I32)
    pos = jax.ShapeDtypeStruct((b,), I32)
    tok_shard = NamedSharding(mesh, P(ba, None) if b >= dp else P(None, None))
    pos_shard = NamedSharding(mesh, P(ba) if b >= dp else P(None))
    # decode model-flops: one token per sequence + KV-cache attention reads
    n_act = cfg.n_active_params()
    attn = 2 * 2 * b * sc * cfg.n_heads * cfg.d_head * cfg.n_layers
    return Cell(mod.ARCH_ID, shape.name, "decode", decode,
                (p_sds, tok, pos, cache_sds),
                (p_shard, tok_shard, pos_shard, cache_shard),
                2.0 * n_act * b + attn,
                comment=f"kv_mode={kv_mode} cache_len={sc}", donate=(3,))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------
def _gnn_sizes(shape: ShapeSpec, mesh: Mesh):
    nd = _nmesh(mesh)
    if shape.name == "minibatch_lg":
        from ..models.gnn.sampler import expected_sizes
        n, e = expected_sizes(shape.batch_nodes, list(shape.fanout))
    elif shape.name == "molecule":
        n = shape.n_nodes * shape.batch_graphs
        e = shape.n_edges * shape.batch_graphs
    else:
        n, e = shape.n_nodes, shape.n_edges
    n_pad = pad_to_multiple(n, 2 * nd)
    # 16*nd: keeps every edge-chunk slice (graphcast edge_chunks<=16)
    # aligned with the all-axes edge sharding
    e_pad = pad_to_multiple(e, 16 * nd)
    return n_pad, e_pad


def _gnn_batch_sds(arch_id: str, shape: ShapeSpec, mesh: Mesh, cfg):
    n, e = _gnn_sizes(shape, mesh)
    ng = shape.batch_graphs if shape.name == "molecule" else 1
    d_feat = shape.d_feat if shape.d_feat else 16
    base = {
        "edge_src": jax.ShapeDtypeStruct((e,), I32),
        "edge_dst": jax.ShapeDtypeStruct((e,), I32),
    }
    if arch_id == "graphcast":
        base["node_feat"] = jax.ShapeDtypeStruct((n, d_feat), F32)
        base["edge_feat"] = jax.ShapeDtypeStruct((e, cfg.d_edge), F32)
        base["targets"] = jax.ShapeDtypeStruct((n, cfg.n_vars), F32)
        base["node_mask"] = jax.ShapeDtypeStruct((n,), F32)
    else:
        base["species"] = jax.ShapeDtypeStruct((n,), I32)
        base["pos"] = jax.ShapeDtypeStruct((n, 3), F32)
        base["graph_ids"] = jax.ShapeDtypeStruct((n,), I32)
        base["energy"] = jax.ShapeDtypeStruct((ng,), F32)
        if arch_id == "dimenet":
            t = pad_to_multiple(4 * e, _nmesh(mesh))
            base["tri_in"] = jax.ShapeDtypeStruct((t,), I32)
            base["tri_out"] = jax.ShapeDtypeStruct((t,), I32)
    return base


def _gnn_batch_specs(batch_sds, mesh: Mesh):
    """Edges/triplets over every axis; node arrays over the data axes."""
    all_axes = tuple(mesh.axis_names)
    ba = _batch_axes(mesh)
    specs = {}
    nd = _nmesh(mesh)
    for k, v in batch_sds.items():
        if k.startswith(("edge_", "tri_")):
            specs[k] = P(all_axes, *([None] * (len(v.shape) - 1)))
        elif k == "energy":
            specs[k] = P(None)
        elif v.shape[0] % nd == 0:
            # node arrays: all axes when divisible (padded that way)
            specs[k] = P(all_axes, *([None] * (len(v.shape) - 1)))
        else:
            specs[k] = P(ba, *([None] * (len(v.shape) - 1)))
    return specs


def _gnn_flops(arch_id: str, cfg, n: int, e: int, t: int = 0) -> float:
    """Per-edge/node MAC counts from the config dims (x2 MACs, x3 train)."""
    if arch_id == "graphcast":
        h = cfg.d_hidden
        per_edge = 3 * h * h + h * h        # edge MLP (2 layers) approx
        per_node = 2 * h * h + h * h
        enc = n * (cfg.d_feat * h + h * h) + e * (cfg.d_edge * h + h * h)
        dec = n * (h * h + h * cfg.n_vars)
        return 6.0 * (cfg.n_layers * (e * per_edge + n * per_node) + enc + dec)
    if arch_id in ("nequip", "mace"):
        # per path (l1,l2,l3): radial MLP MACs + CG contraction ~27 mults/C
        c = cfg.channels
        paths = 15  # l<=2 CG paths
        per_edge = 2 * paths * (cfg.n_rbf * cfg.radial_hidden
                                + cfg.radial_hidden * c + 27 * c)
        n_mix = 3 if arch_id == "nequip" else (1 + 2 * paths)
        per_node = 2 * n_mix * 9 * c * c
        return 6.0 * cfg.n_layers * (e * per_edge + n * per_node)
    if arch_id == "dimenet":
        h = cfg.d_hidden
        per_tri = h * cfg.n_bilinear * (1 + h)
        per_edge = 2 * h * h
        return 6.0 * cfg.n_blocks * (t * per_tri + e * per_edge)
    raise ValueError(arch_id)


def _build_gnn(mod, shape: ShapeSpec, mesh: Mesh, opt_over) -> Cell:
    arch_id = mod.ARCH_ID
    if arch_id == "graphcast":
        d_feat = shape.d_feat if shape.d_feat else 16
        _, e_est = _gnn_sizes(shape, mesh)
        cfg = mod.model_config(d_feat=d_feat,
                               edge_chunks=16 if e_est > 4_000_000 else 1)
        from ..models.gnn import graphcast as m
        all_axes = tuple(mesh.axis_names)

        def gc_constrain(arr, kind):
            if kind == "edge_chunked":
                spec = P(None, all_axes, *([None] * (arr.ndim - 2)))
            elif kind == "nodes_replicated":
                spec = P(*([None] * arr.ndim))
            elif kind in ("edges", "edge_chunk", "nodes"):
                if arr.shape[0] % _nmesh(mesh) != 0:
                    return arr
                spec = P(all_axes, *([None] * (arr.ndim - 1)))
            else:
                return arr
            return jax.lax.with_sharding_constraint(
                arr, NamedSharding(mesh, spec))

        def loss_fn(params, batch):
            pred = m.forward(params, cfg, batch, constrain_fn=gc_constrain)
            err = (pred.astype(F32) - batch["targets"]) ** 2
            w = batch["node_mask"][:, None]
            return jnp.sum(err * w) / jnp.maximum(jnp.sum(w) * cfg.n_vars, 1.0), {}
        init_fn = functools.partial(m.init_params, cfg)
    else:
        cfg = mod.model_config()
        if arch_id == "nequip":
            from ..models.gnn import nequip as m
        elif arch_id == "mace":
            from ..models.gnn import mace as m
        else:
            from ..models.gnn import dimenet as m
        all_axes0 = tuple(mesh.axis_names)
        nd0 = _nmesh(mesh)

        def gnn_scatter(vals, ix, rows):
            if (rows % nd0 != 0 or ix.shape[0] % nd0 != 0
                    or rows * vals.shape[1] < 100_000_000):
                dump = jnp.where(ix >= 0, ix, rows)
                return jax.ops.segment_sum(
                    vals, dump, num_segments=rows + 1)[:rows]
            from jax.experimental.shard_map import shard_map
            from ..models.gnn.ring_gather import ring_scatter_add
            return shard_map(
                lambda v, i: ring_scatter_add(v, i, all_axes0, rows // nd0),
                mesh=mesh, in_specs=(P(all_axes0, None), P(all_axes0)),
                out_specs=P(all_axes0, None), check_rep=False)(vals, ix)

        def gnn_gather(table, ix):
            # distributed row gather (ring) for big node/edge tables
            if (table.shape[0] % nd0 != 0 or ix.shape[0] % nd0 != 0
                    or table.shape[0] < 1_000_000):
                return table[jnp.clip(ix, 0, table.shape[0] - 1)]
            from jax.experimental.shard_map import shard_map
            from ..models.gnn.ring_gather import ring_gather
            return shard_map(
                lambda t, i: ring_gather(t, i, all_axes0), mesh=mesh,
                in_specs=(P(all_axes0, None), P(all_axes0)),
                out_specs=P(all_axes0, None), check_rep=False)(table, ix)

        if arch_id == "dimenet":
            all_axes = tuple(mesh.axis_names)
            nd_ = _nmesh(mesh)

            def dn_constrain(arr, kind):
                if kind == "edges_replicated":
                    spec = P(*([None] * arr.ndim))
                elif kind in ("edges", "triplets"):
                    if arr.shape[0] % nd_ != 0:
                        return arr
                    spec = P(all_axes, *([None] * (arr.ndim - 1)))
                else:
                    return arr
                return jax.lax.with_sharding_constraint(
                    arr, NamedSharding(mesh, spec))

            from jax.experimental.shard_map import shard_map
            from ..models.gnn.ring_gather import ring_gather, ring_scatter_add

            def dn_scatter(vals, ix, rows):
                if (rows % nd_ != 0 or ix.shape[0] % nd_ != 0
                        or rows < 1_000_000):
                    dump = jnp.where(ix >= 0, ix, rows)
                    return jax.ops.segment_sum(
                        vals, dump, num_segments=rows + 1)[:rows]
                rows_local = rows // nd_
                return shard_map(
                    lambda v, i: ring_scatter_add(v, i, all_axes, rows_local),
                    mesh=mesh,
                    in_specs=(P(all_axes, None), P(all_axes)),
                    out_specs=P(all_axes, None), check_rep=False)(vals, ix)

            def dn_gather(table, ix):
                # distributed row gather: memory-bounded ring over the mesh
                if (table.shape[0] % nd_ != 0 or ix.shape[0] % nd_ != 0
                        or table.shape[0] < 1_000_000):
                    return table[jnp.clip(ix, 0, table.shape[0] - 1)]
                return shard_map(
                    lambda t, i: ring_gather(t, i, all_axes), mesh=mesh,
                    in_specs=(P(all_axes, None), P(all_axes)),
                    out_specs=P(all_axes, None), check_rep=False)(table, ix)

            def loss_fn(params, batch):
                return m.loss_fn(params, cfg, batch,
                                 constrain_fn=dn_constrain,
                                 gather_fn=dn_gather,
                                 scatter_fn=dn_scatter), {}
        else:
            def loss_fn(params, batch):
                return m.loss_fn(params, cfg, batch, gather_fn=gnn_gather,
                                 scatter_fn=gnn_scatter), {}
        init_fn = functools.partial(m.init_params, cfg)

    opt_cfg = AdamWConfig(**opt_over) if opt_over else AdamWConfig()
    batch_sds = _gnn_batch_sds(arch_id, shape, mesh, cfg)
    batch_specs = _gnn_batch_specs(batch_sds, mesh)
    state_sds = jax.eval_shape(lambda: {
        "step": jnp.zeros((), I32),
        "params": init_fn(jax.random.PRNGKey(0)),
        "opt": adamw_init(init_fn(jax.random.PRNGKey(0)))})
    state_specs = jax.tree.map(lambda _: P(), state_sds)

    def train_step(state, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True)(state["params"])
        new_p, new_o, om = adamw_update(opt_cfg, g, state["opt"],
                                        state["params"])
        return (dict(step=state["step"] + 1, params=new_p, opt=new_o),
                {"loss": loss, **om})

    n, e = _gnn_sizes(shape, mesh)
    t = batch_sds.get("tri_in")
    flops = _gnn_flops(arch_id, cfg, n, e, t.shape[0] if t is not None else 0)
    return Cell(arch_id, shape.name, "train", train_step,
                (state_sds, batch_sds),
                (_shardings(mesh, state_specs, state_sds),
                 _shardings(mesh, batch_specs, batch_sds)),
                flops, comment=f"n={n} e={e}", donate=(0,))


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------
def _din_batch_sds(cfg, b: int):
    s = cfg.seq_len
    return {"hist_items": jax.ShapeDtypeStruct((b, s), I32),
            "hist_cates": jax.ShapeDtypeStruct((b, s), I32),
            "hist_len": jax.ShapeDtypeStruct((b,), I32),
            "target_item": jax.ShapeDtypeStruct((b,), I32),
            "target_cate": jax.ShapeDtypeStruct((b,), I32),
            "label": jax.ShapeDtypeStruct((b,), F32)}


def _din_flops(cfg, b: int, train: bool) -> float:
    d = cfg.d_feat
    attn = cfg.seq_len * (4 * d * cfg.attn_mlp[0]
                          + cfg.attn_mlp[0] * cfg.attn_mlp[1] + cfg.attn_mlp[1])
    mlp = 3 * d * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1] + cfg.mlp[1]
    return (6.0 if train else 2.0) * b * (attn + mlp)


def _build_recsys(mod, shape: ShapeSpec, mesh: Mesh, opt_over) -> Cell:
    from ..models.recsys import din as m
    cfg = mod.model_config()
    ba = _batch_axes(mesh)
    dp = _dp(mesh)
    b = shape.batch
    mdl = "model"
    p_specs = m.param_specs(cfg, mesh, mdl)
    p_sds = jax.eval_shape(lambda: m.init_params(cfg, jax.random.PRNGKey(0)))
    p_shard = _shardings(mesh, p_specs, p_sds)
    batch_sds = _din_batch_sds(cfg, b)
    row = ba if (b % max(dp, 1) == 0 and b >= dp) else None
    batch_specs = {k: P(row, *([None] * (len(v.shape) - 1)))
                   for k, v in batch_sds.items()}
    batch_shard = _shardings(mesh, batch_specs, batch_sds)
    bx = ba if (b % max(dp, 1) == 0 and b >= dp) else ()

    if shape.kind == "train":
        opt_cfg = AdamWConfig(**opt_over) if opt_over else AdamWConfig()
        o_specs = opt_state_specs(p_specs, zero1=True, params_shapes=p_sds,
                                  mesh=mesh)
        state_specs = {"step": P(), "params": p_specs, "opt": o_specs}
        state_sds = jax.eval_shape(lambda: {
            "step": jnp.zeros((), I32),
            "params": m.init_params(cfg, jax.random.PRNGKey(0)),
            "opt": adamw_init(m.init_params(cfg, jax.random.PRNGKey(0)))})

        def train_step(state, batch):
            (loss), g = jax.value_and_grad(
                lambda p: m.loss_fn(p, cfg, batch, mesh, mdl, bx))(
                    state["params"])
            new_p, new_o, om = adamw_update(opt_cfg, g, state["opt"],
                                            state["params"])
            return (dict(step=state["step"] + 1, params=new_p, opt=new_o),
                    {"loss": loss, **om})

        return Cell(mod.ARCH_ID, shape.name, "train", train_step,
                    (state_sds, batch_sds),
                    (_shardings(mesh, state_specs, state_sds), batch_shard),
                    _din_flops(cfg, b, True), donate=(0,))

    if shape.kind == "serve":
        def serve(params, batch):
            return m.forward_scores(params, cfg, batch, mesh, mdl, bx)
        return Cell(mod.ARCH_ID, shape.name, "serve", serve,
                    (p_sds, batch_sds), (p_shard, batch_shard),
                    _din_flops(cfg, b, False))

    # retrieval_cand: the candidate set is the item table itself; using all
    # n_items (= 2^20 >= the 10^6 cell spec) keeps the slice shard-aligned
    n_cand = cfg.n_items

    def retrieval(params, batch):
        return m.retrieval_step(params, cfg, batch, n_cand, k=100,
                                mesh=mesh, model_axis=mdl, batch_axes=bx,
                                backend="ref")
    shortlist = 2.0 * b * n_cand * cfg.embed_dim
    rerank = _din_flops(cfg, b * cfg.rerank_k, False)
    return Cell(mod.ARCH_ID, shape.name, "retrieval", retrieval,
                (p_sds, batch_sds), (p_shard, batch_shard),
                shortlist + rerank, comment=f"n_cand={n_cand}")
