"""NamedSharding helpers shared by train/serve/dry-run paths.

Sharding conventions (see DESIGN.md §4):
  mesh axes: ("data", "model") single-pod / ("pod", "data", "model") multi-pod
  - batch-like dims        -> ("pod", "data") when multi_pod else ("data",)
  - tensor-parallel dims   -> "model"
  - replicated             -> None
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> tuple:
    """The mesh axes that jointly shard the batch dimension."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def spec_batch(mesh: Mesh, *rest: Any) -> P:
    """PartitionSpec with the leading dim sharded over the data(+pod) axes."""
    return P(batch_axes(mesh), *rest)


def ns(mesh: Mesh, spec: Optional[P]) -> NamedSharding:
    return NamedSharding(mesh, spec if spec is not None else P())


def shard_leaf(mesh: Mesh, spec: P, x):
    return jax.device_put(x, ns(mesh, spec))


def mesh_size(mesh: Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


def axis_size(mesh: Mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.devices.shape[mesh.axis_names.index(name)]


def tp_size(mesh: Mesh) -> int:
    return axis_size(mesh, "model")


def dp_size(mesh: Mesh) -> int:
    return axis_size(mesh, "data") * axis_size(mesh, "pod")


def check_divisible(dim: int, parts: int, what: str) -> None:
    if dim % parts != 0:
        raise ValueError(f"{what}={dim} not divisible by mesh factor {parts}")


def specs_like(tree, spec_fn) -> Any:
    """Map a function leaf->PartitionSpec over a pytree of arrays."""
    return jax.tree.map(spec_fn, tree)
