"""Fault injection, resilient I/O, degraded serving, blue/green deploy.

Locks down the PR's three contracts:

1. Determinism -- a `FaultPlan` is a pure function of (seed, kind, block,
   attempt): the schedule is bit-reproducible and independent of the order
   reads are issued in.
2. Accounting purity -- with a zero-rate plan (even with retry/hedge/
   timeout configured) every engine is bit-identical to no plan at all:
   same ids, dists, NIO, cache stats; zero resilience counters.
3. Degrade, never crash -- transient errors are retried to success
   (>=95%% non-degraded at the default budget under 1%% read errors),
   dead blocks/shards produce partial answers with the `degraded` flag,
   and blue/green promotion+rollback serves correct top-k throughout.
"""
import os
import sys
import tempfile

import numpy as np
import pytest

sys.path.insert(0, "src")

from repro.core.distances import recall_at_k
from repro.core.engine import (BAMGIndex, BAMGParams, DiskANNIndex,
                               DiskANNParams, StarlingIndex, StarlingParams)
from repro.serve import BlueGreenEngine, DeploymentManager
from repro.serve.ann_engine import BatchedANNEngine, EngineConfig
from repro.serve.frontend import ShardedFrontend, _merge_topk
from repro.utils.faults import (FaultPlan, FaultSpec, IntegrityError,
                                RetryPolicy, SimulatedFailure,
                                corrupt_payload, payload_checksum)

K, L = 10, 48
_CFG = EngineConfig(l=32, max_hops=16, backend="ref")


@pytest.fixture(scope="module")
def bamg(small_corpus):
    return BAMGIndex.build(small_corpus.base, BAMGParams(seed=0))


@pytest.fixture(scope="module")
def diskann(small_corpus):
    return DiskANNIndex.build(small_corpus.base, DiskANNParams(seed=0))


@pytest.fixture(scope="module")
def starling(small_corpus):
    return StarlingIndex.build(small_corpus.base, StarlingParams(seed=0))


def _batch(idx, ds, **kw):
    return idx.search_batch(ds.queries, k=K, l=L, gt=ds.gt, **kw)


def _ids(idx, ds):
    return np.stack([np.pad(r.ids[:K], (0, K - min(K, len(r.ids))),
                            constant_values=-1)
                     for r in (idx.search(q, k=K, l=L) for q in ds.queries)])


# ---------------------------------------------------------------------------
# 1. plan determinism
# ---------------------------------------------------------------------------
def test_fault_plan_reproducible_and_order_independent():
    spec = FaultSpec(read_error_rate=0.1, dead_rate=0.05, corrupt_rate=0.05,
                     spike_rate=0.1)
    keys = [(k, b, a) for k in ("graph", "vector")
            for b in range(64) for a in range(3)]
    p1, p2 = FaultPlan(spec, seed=11), FaultPlan(spec, seed=11)
    draws1 = [p1.outcome(*kk) for kk in keys]
    # same seed, reversed issue order -> identical schedule
    draws2 = list(reversed([p2.outcome(*kk) for kk in reversed(keys)]))
    assert draws1 == draws2
    assert [p1.dead(k, b) for k, b, _ in keys] == \
           [p2.dead(k, b) for k, b, _ in keys]
    # a different seed gives a different schedule
    p3 = FaultPlan(spec, seed=12)
    assert draws1 != [p3.outcome(*kk) for kk in keys]
    # zero-rate spec never draws anything
    p0 = FaultPlan(FaultSpec(), seed=11)
    assert not any(o.error or o.persistent or o.corrupt or o.spike_us
                   for o in (p0.outcome(*kk) for kk in keys))
    assert not FaultSpec().any_io


def test_checksum_roundtrip_and_corruption():
    rng = np.random.default_rng(0)
    payload = rng.standard_normal(32).astype(np.float32)
    c0 = payload_checksum(payload)
    assert c0 == payload_checksum(payload.copy())        # content-addressed
    bad = corrupt_payload(payload, salt=3)
    assert payload_checksum(bad) != c0                   # flips are visible
    assert c0 == payload_checksum(payload)               # original untouched
    bad2 = corrupt_payload(payload, salt=3)
    np.testing.assert_array_equal(bad, bad2)             # deterministic salt
    assert payload_checksum(None) == 0


# ---------------------------------------------------------------------------
# 2. zero-fault accounting purity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("which", ["bamg", "diskann", "starling"])
def test_zero_rate_plan_bit_identical(which, small_corpus, request):
    idx = request.getfixturevalue(which)
    ds = small_corpus
    idx.configure_io(faults=None, retry=None, timeout_us=None, hedge_us=None)
    clean, clean_ids = _batch(idx, ds), _ids(idx, ds)
    # zero-rate plan WITH retry/hedge/timeout armed: nothing may change
    idx.configure_io(faults=FaultSpec(), retry=RetryPolicy(budget=4),
                     timeout_us=10_000.0, hedge_us=200.0)
    z, z_ids = _batch(idx, ds), _ids(idx, ds)
    assert (z.recall, z.mean_nio, z.cache_hit_rate) == \
           (clean.recall, clean.mean_nio, clean.cache_hit_rate)
    assert (z.mean_service_us, z.mean_serial_us) == \
           (clean.mean_service_us, clean.mean_serial_us)
    assert z.mean_retries == 0 and z.mean_hedges == 0
    assert z.degraded_fraction == 0 and z.mean_failed_reads == 0
    np.testing.assert_array_equal(z_ids, clean_ids)
    idx.configure_io(faults=None, retry=None, timeout_us=None, hedge_us=None)


# ---------------------------------------------------------------------------
# 3. resilient reads / degraded mode
# ---------------------------------------------------------------------------
def test_transient_errors_retried_to_identical_answers(bamg, small_corpus):
    ds = small_corpus
    bamg.configure_io(faults=None, retry=None, timeout_us=None, hedge_us=None)
    clean, clean_ids = _batch(bamg, ds), _ids(bamg, ds)
    # acceptance plan: 1% read errors, default retry budget
    bamg.configure_io(faults=FaultSpec(read_error_rate=0.01), fault_seed=5)
    a = _batch(bamg, ds)
    assert a.degraded_fraction <= 0.05         # >=95% non-degraded, no crash
    assert a.recall == clean.recall
    # hotter plan so the retry machinery demonstrably fires (error draws are
    # per distinct (block, attempt), so 1% can legitimately draw nothing on
    # a small corpus)
    bamg.configure_io(faults=FaultSpec(read_error_rate=0.05), fault_seed=5)
    f, f_ids = _batch(bamg, ds), _ids(bamg, ds)
    assert f.degraded_fraction <= 0.05
    assert f.mean_retries > 0                  # the errors really fired
    assert f.mean_nio == clean.mean_nio        # NIO counts deliveries only
    assert f.recall == clean.recall
    np.testing.assert_array_equal(f_ids, clean_ids)
    assert f.mean_service_us > clean.mean_service_us   # retries cost time
    bamg.configure_io(faults=None)


def test_corruption_detected_and_reread(bamg, small_corpus):
    ds = small_corpus
    bamg.configure_io(faults=FaultSpec(corrupt_rate=0.05), fault_seed=9)
    r = bamg.search_batch(ds.queries, k=K, l=L, gt=ds.gt)
    total_csf = sum(bamg.search(q, k=K, l=L).checksum_failures
                    for q in ds.queries)
    assert total_csf > 0                       # torn payloads were caught
    assert r.degraded_fraction <= 0.05         # and re-read to success
    bamg.configure_io(faults=None)


def test_dead_blocks_degrade_not_crash(bamg, small_corpus):
    ds = small_corpus
    bamg.configure_io(faults=FaultSpec(dead_rate=0.05, read_error_rate=0.02),
                      fault_seed=1, retry=RetryPolicy(budget=2))
    r = _batch(bamg, ds)
    assert r.mean_failed_reads > 0             # some blocks were lost
    assert r.degraded_fraction > 0             # and flagged as degraded
    assert r.recall > 0.5                      # but answers remain useful
    res = bamg.search(ds.queries[0], k=K, l=L)
    assert res.degraded == (res.failed_reads > 0)
    bamg.configure_io(faults=None, retry=None)


def test_hedge_and_timeout_counters(bamg, small_corpus):
    ds = small_corpus
    # heavy spikes + an aggressive hedge: hedges must fire and win sometimes
    bamg.configure_io(faults=FaultSpec(spike_rate=0.3, spike_us=5000.0),
                      fault_seed=2, hedge_us=100.0)
    r = _batch(bamg, ds)
    assert r.mean_hedges > 0
    assert r.degraded_fraction == 0            # hedging never loses data
    # tight timeout turns spikes into retried attempts instead
    bamg.configure_io(faults=FaultSpec(spike_rate=0.3, spike_us=5000.0),
                      fault_seed=2, hedge_us=None, timeout_us=500.0)
    t = _batch(bamg, ds)
    assert t.mean_retries > 0
    bamg.configure_io(faults=None, timeout_us=None)


def test_service_time_invariant_holds_under_faults(bamg, small_corpus):
    ds = small_corpus
    bamg.configure_io(faults=FaultSpec(read_error_rate=0.05, spike_rate=0.2),
                      fault_seed=4, qd=8, batch_io=True)
    for q in ds.queries:
        r = bamg.search(q, k=K, l=L)
        assert r.service_us <= r.serial_us + 1e-6
    bamg.configure_io(faults=None, qd=1, batch_io=False)


def test_device_checksums_verify_both_layouts(bamg, diskann):
    gdev = bamg.store.graph_dev
    for b in range(min(8, len(gdev))):
        assert gdev.verify(b)
        assert not gdev.verify(b, gdev.attempt_payload(b, corrupt=True,
                                                       salt=1))
    vdev = bamg.store.vector_dev
    for b in range(min(8, len(vdev))):
        assert vdev.verify(b)
        assert not vdev.verify(b, vdev.attempt_payload(b, corrupt=True))
    cdev = diskann.store.device
    for b in range(min(8, len(cdev))):
        assert cdev.verify(b)
        assert not cdev.verify(b, cdev.attempt_payload(b, corrupt=True))


# ---------------------------------------------------------------------------
# 4. sharded front-end: dead shards + small-shard merge regression
# ---------------------------------------------------------------------------
def test_merge_topk_fewer_candidates_than_k():
    d = np.array([[3.0, 1.0], [np.inf, 2.0]])
    gd, gi = _merge_topk(d, 5)                 # 2 columns, k=5: must not crash
    assert gd.shape == (2, 5)
    assert gd[0, 0] == 1.0 and gd[0, 1] == 3.0 and np.isinf(gd[0, 2:]).all()
    assert gd[1, 0] == 2.0 and np.isinf(gd[1, 1:]).all()


@pytest.fixture(scope="module")
def frontend(small_corpus):
    return ShardedFrontend.build(small_corpus.base, n_shards=3,
                                 params=BAMGParams(seed=0), config=_CFG)


def test_frontend_small_shards_padded(small_corpus):
    """Every shard smaller than k: merge must still return exact-ish top-k."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal((20, 8)).astype(np.float32)
    q = rng.standard_normal((4, 8)).astype(np.float32)
    fe = ShardedFrontend.build(x, n_shards=4,
                               config=EngineConfig(l=5, max_hops=8,
                                                   backend="ref"))
    k = 12                                     # > any shard's 5 vectors
    ids, d = fe.search_batch(q, k)
    assert ids.shape == (4, k) and d.shape == (4, k)
    from repro.core.distances import exact_knn
    gt = exact_knn(x, q, k)[1]
    assert recall_at_k(ids, gt, k) >= 0.9
    order = np.argsort(d, axis=1, kind="stable")
    np.testing.assert_array_equal(order, np.tile(np.arange(k), (4, 1)))


def test_frontend_dead_shard_skip_and_recover(frontend, small_corpus):
    ds = small_corpus
    clean_ids, _ = frontend.search_batch(ds.queries, K)
    clean_rec = recall_at_k(clean_ids, ds.gt, K)
    frontend.engines[1].inject_fault()
    ids, d, st = frontend.search_batch(ds.queries, K, with_status=True)
    assert st.degraded.all() and st.shards_down == (1,)
    assert frontend.health()["shards_down"] == [1]
    assert frontend.health()["per_shard"][1]["errors"] == 1
    deg_rec = recall_at_k(ids, ds.gt, K)
    assert 0 < deg_rec < clean_rec             # partial but useful
    # the marked-down shard is skipped without another engine call
    ids2, _, st2 = frontend.search_batch(ds.queries, K, with_status=True)
    assert frontend.health()["per_shard"][1]["errors"] == 1
    np.testing.assert_array_equal(ids, ids2)
    # repair: heal + mark_up restores bit-identical clean serving
    frontend.engines[1].heal()
    frontend.mark_up(1)
    ids3, _, st3 = frontend.search_batch(ds.queries, K, with_status=True)
    assert not st3.degraded.any()
    np.testing.assert_array_equal(ids3, clean_ids)


def test_frontend_all_shards_down(frontend, small_corpus):
    for s in range(frontend.n_shards):
        frontend.mark_down(s)
    ids, d, st = frontend.search_batch(small_corpus.queries, K,
                                       with_status=True)
    assert (ids == -1).all() and np.isinf(d).all() and st.shards_up == 0
    for s in range(frontend.n_shards):
        frontend.mark_up(s)


# ---------------------------------------------------------------------------
# 5. blue/green deployment
# ---------------------------------------------------------------------------
def test_blue_green_lifecycle(small_corpus, tmp_path):
    ds = small_corpus
    dm = DeploymentManager(str(tmp_path))
    assert dm.active() is None and dm.builds() == []
    man = dm.deploy(ds.base, "v1", ds.queries, ds.gt,
                    params=BAMGParams(seed=0), k=K, min_recall=0.5,
                    config=_CFG)
    assert dm.active() == "v1" and man.meta["validated_recall"] >= 0.5
    assert man.n == len(ds.base) and man.d == ds.base.shape[1]
    bg = BlueGreenEngine(dm, _CFG)
    ids1, d1 = bg.search_batch(ds.queries, K)
    rec1 = recall_at_k(ids1, ds.gt, K)
    assert rec1 >= 0.5
    # green build promoted; blue serves identically until refresh
    dm.deploy(ds.base, "v2", ds.queries, ds.gt, params=BAMGParams(seed=1),
              k=K, min_recall=0.5, config=_CFG)
    pre, _ = bg.search_batch(ds.queries, K)
    np.testing.assert_array_equal(pre, ids1)
    assert bg.refresh() and bg.build_id == "v2"
    assert not bg.refresh()                    # idempotent
    ids2, _ = bg.search_batch(ds.queries, K)
    assert recall_at_k(ids2, ds.gt, K) >= 0.5  # correct top-k after the swap
    # rollback re-activates v1 and serving returns bit-identical
    assert dm.rollback() == "v1"
    assert bg.refresh() and bg.build_id == "v1"
    back, _ = bg.search_batch(ds.queries, K)
    np.testing.assert_array_equal(back, ids1)
    assert dm.history()[-1] == "v1"


def test_deploy_tamper_detected(small_corpus, tmp_path):
    ds = small_corpus
    dm = DeploymentManager(str(tmp_path))
    idx = BAMGIndex.build(ds.base, BAMGParams(seed=0))
    dm.publish(idx, "b1")
    dm.verify("b1")                            # clean round-trip
    art = os.path.join(str(tmp_path), "builds", "b1", "index.npz")
    with open(art, "r+b") as f:
        f.seek(64)
        f.write(b"\x00\x01\x02\x03")
    with pytest.raises(IntegrityError):
        dm.verify("b1")
    with pytest.raises(IntegrityError):
        dm.load("b1")                          # corrupt build is unloadable


def test_deploy_failed_validation_keeps_active(small_corpus, tmp_path):
    ds = small_corpus
    dm = DeploymentManager(str(tmp_path))
    dm.deploy(ds.base, "good", ds.queries, ds.gt, params=BAMGParams(seed=0),
              k=K, min_recall=0.5, config=_CFG)
    with pytest.raises(ValueError, match="failed validation"):
        dm.deploy(ds.base, "bad", ds.queries, ds.gt,
                  params=BAMGParams(seed=1), k=K, min_recall=1.01,
                  config=_CFG)
    assert dm.active() == "good"               # bad deploy degraded nothing
    assert "bad" in dm.builds()                # left published for forensics
    dm.prune(keep=1)
    assert dm.builds() == ["good"]             # prune never drops the active


# ---------------------------------------------------------------------------
# 6. unified training-failure taxonomy
# ---------------------------------------------------------------------------
def test_ft_shares_fault_taxonomy(tmp_path):
    from repro.train.ft import (FTConfig, InjectedFault, run_with_recovery)
    from repro.train.ft import SimulatedFailure as FtFailure
    assert FtFailure is SimulatedFailure
    assert issubclass(FtFailure, InjectedFault)

    def init_fn():
        return {"step": np.asarray(0), "w": np.zeros(3, np.float32)}

    def step_fn(state, batch):
        return ({"step": state["step"] + 1, "w": state["w"] + batch},
                {"loss": float(batch.sum())})

    def batch_fn(s):
        return np.full(3, float(s), np.float32)

    # a plan whose transient step failures clear on the restart attempt
    plan = next(p for p in (FaultPlan(FaultSpec(step_fail_rate=0.15), seed=s)
                            for s in range(300))
                if any(p.fail_step(i, 0) for i in range(1, 16))
                and not any(p.fail_step(i, 1) for i in range(1, 16)))
    ft = FTConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=4,
                  async_save=False)
    state, _, attempts = run_with_recovery(init_fn, step_fn, batch_fn, 15,
                                           ft, fault_plan=plan)
    assert attempts >= 1 and int(state["step"]) == 15
    ft2 = FTConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=4,
                   async_save=False)
    ref, _, a0 = run_with_recovery(init_fn, step_fn, batch_fn, 15, ft2)
    assert a0 == 0
    np.testing.assert_array_equal(ref["w"], state["w"])  # restart-equivalent


# ---------------------------------------------------------------------------
# 7. streaming-freshness satellites (ISSUE 9): tombstones under faults +
#    prune protecting ACTIVE and the rollback target
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("which", ["bamg", "diskann", "starling"])
def test_deleted_ids_never_surface_under_faults(which, small_corpus, request):
    """Tombstone masking composes with fault injection: a deleted id must
    not surface even when its (or any) block READ_FAILEDs and the
    degraded skip-and-continue path activates -- on all three engines."""
    idx = request.getfixturevalue(which)
    ds = small_corpus
    # tombstone the exact top-1 of every query: the ids most likely to leak
    dead = set(ds.gt[:, 0].astype(int).tolist())
    idx.configure_io(faults=FaultSpec(dead_rate=0.15, read_error_rate=0.05),
                     fault_seed=3)
    try:
        n_degraded = 0
        for q in ds.queries:
            r = idx.search(q, k=K, l=L, exclude=dead)
            assert not (set(r.ids.tolist()) & dead)
            n_degraded += bool(r.degraded)
        assert n_degraded > 0       # skip-and-continue actually activated
    finally:
        idx.configure_io(faults=None, retry=None)
    # clean path: the mask alone never degrades anything
    r = idx.search(ds.queries[0], k=K, l=L, exclude=dead)
    assert not r.degraded and not (set(r.ids.tolist()) & dead)


def test_prune_protects_active_and_rollback_target(small_corpus, tmp_path):
    """Regression: aggressive prune (keep=0) must never delete the build
    being served or strand rollback()."""
    ds = small_corpus
    dm = DeploymentManager(str(tmp_path))
    idx = BAMGIndex.build(ds.base, BAMGParams(seed=0))
    for b in ("b1", "b2", "b3", "b4"):
        dm.publish(idx, b)
        dm.promote(b)
    dm.promote("b2")                # re-activate an *old* build
    removed = dm.prune(keep=0)      # as aggressive as it gets
    assert set(removed) == {"b1", "b3"}
    assert dm.active() == "b2"
    assert set(dm.builds()) == {"b2", "b4"}    # ACTIVE + rollback target
    dm.verify("b2")                            # ACTIVE still verifies
    assert dm.rollback() == "b4"               # rollback still succeeds
    dm.verify("b4")
    assert dm.active() == "b4" and "b2" in dm.builds()
