"""The paper's technique serving the assigned recsys architecture: DIN
retrieval over 10^5 item embeddings through a BAMG disk index vs brute
force (the retrieval_cand cell's workload, DESIGN.md §5).

    PYTHONPATH=src python examples/din_retrieval.py

Pipeline:
  1. train a reduced DIN for a few steps (so item embeddings are non-trivial)
  2. index the item-embedding table with BAMG (the disk-ANN engine)
  3. serve user queries: interest vector -> BAMG kNN shortlist -> full DIN
     re-rank; compare against the exact brute-force shortlist.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.engine import BAMGIndex, BAMGParams  # noqa: E402
from repro.data.synthetic import din_batch  # noqa: E402
from repro.models.recsys.din import (DINConfig, init_params,  # noqa: E402
                                     loss_fn, user_interest_vector)


def main() -> None:
    cfg = DINConfig(n_items=20_000, n_cates=128, seq_len=24, embed_dim=16,
                    attn_mlp=(32, 16), mlp=(64, 32))
    params = init_params(cfg, jax.random.PRNGKey(0))

    # 1. a few training steps so the table has structure
    @jax.jit
    def step(p, b):
        l, g = jax.value_and_grad(lambda p: loss_fn(p, cfg, b))(p)
        return jax.tree.map(lambda x, gg: x - 0.3 * gg, p, g), l

    for i in range(20):
        hi, hc, hl, ti, tc, y = din_batch(i, 512, cfg.seq_len, cfg.n_items,
                                          cfg.n_cates)
        b = {k: jnp.asarray(v) for k, v in
             zip(("hist_items", "hist_cates", "hist_len", "target_item",
                  "target_cate", "label"), (hi, hc, hl, ti, tc, y))}
        params, l = step(params, b)
    print(f"DIN trained 20 steps, loss={float(l):.4f}")

    # 2. BAMG over the item-embedding table (the ANN corpus)
    table = np.asarray(params["item_emb"], np.float32)
    # index a 20k-item slice (container-friendly; scales linearly)
    t0 = time.time()
    idx = BAMGIndex.build(table, BAMGParams(alpha=3, beta=1.05, r=16,
                                            l_build=32, knn_k=16))
    print(f"BAMG over {len(table):,} item embeddings in {time.time()-t0:.0f}s "
          f"({idx.graph.members.shape[0]} blocks)")

    # 3. serve: user interest -> ANN shortlist -> exact check
    hi, hc, hl, ti, tc, y = din_batch(99, 8, cfg.seq_len, cfg.n_items,
                                      cfg.n_cates)
    batch = {"hist_items": jnp.asarray(hi), "hist_cates": jnp.asarray(hc),
             "hist_len": jnp.asarray(hl)}
    # query = mean item embedding of the history (matches retrieval_step)
    e_hist = params["item_emb"][jnp.clip(batch["hist_items"], 0,
                                         cfg.n_items - 1)]
    mask = (jnp.arange(cfg.seq_len)[None] < batch["hist_len"][:, None])
    q = np.asarray(jnp.sum(jnp.where(mask[..., None], e_hist, 0), 1)
                   / jnp.maximum(batch["hist_len"], 1)[:, None])

    k = 10
    nio_tot, hit_tot = 0, 0
    for u in range(len(q)):
        r = idx.search(q[u], k=k, l=48)
        exact = np.argsort(((table - q[u]) ** 2).sum(1))[:k]
        hits = len(set(r.ids.tolist()) & set(exact.tolist()))
        nio_tot += r.nio
        hit_tot += hits
    print(f"BAMG shortlist: recall@{k}={hit_tot/(len(q)*k):.2f}, "
          f"avg NIO={nio_tot/len(q):.1f} "
          f"(brute force would read {table.nbytes//4096:,} blocks)")


if __name__ == "__main__":
    main()
