"""Shared neural building blocks (pure JAX, no flax/optax).

Conventions:
  * params are nested dicts of jnp arrays; a parallel tree of
    PartitionSpecs is produced by each model's `param_specs`.
  * compute dtype is configurable (bf16 default); norms/softmax in f32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray | None, eps: float = 1e-6,
            offset: float = 0.0) -> jnp.ndarray:
    """RMSNorm in f32; `offset`=1.0 gives the gemma (1+scale) convention."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (offset + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def nonparam_layernorm(x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """OLMo's non-parametric LayerNorm: normalize, no scale/bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(kind: str, x: jnp.ndarray, scale, **kw) -> jnp.ndarray:
    if kind == "rmsnorm":
        return rmsnorm(x, scale, **kw)
    if kind == "rmsnorm_gemma":
        return rmsnorm(x, scale, offset=1.0, **kw)
    if kind == "nonparam_ln":
        return nonparam_layernorm(x)
    raise ValueError(kind)


def norm_param(kind: str, d: int) -> jnp.ndarray | None:
    if kind == "nonparam_ln":
        return None
    if kind == "rmsnorm_gemma":
        return jnp.zeros((d,), jnp.float32)   # (1 + scale) convention
    return jnp.ones((d,), jnp.float32)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------
def act_fn(kind: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[kind]


def gated_mlp(x: jnp.ndarray, w_gate, w_in, w_out, activation: str = "silu"):
    """SwiGLU / GeGLU: act(x @ w_gate) * (x @ w_in) @ w_out."""
    g = act_fn(activation)(x @ w_gate)
    return (g * (x @ w_in)) @ w_out


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(dh: int, theta: float = 10000.0) -> jnp.ndarray:
    """(dh//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """x (..., S, H, Dh), positions (..., S) int32 -> rotated x (split halves
    convention, matching llama/gemma reference implementations)."""
    dh = x.shape[-1]
    inv = rope_frequencies(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., S, dh/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jnp.ndarray:
    std = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_xent_chunked(logits_fn, x: jnp.ndarray, labels: jnp.ndarray,
                         n_chunks: int = 8) -> jnp.ndarray:
    """Cross-entropy over vocab-sharded logits, scanned over seq chunks so
    the live logits tensor is (B, S/n_chunks, V) instead of (B, S, V).

    logits_fn: (B, s, d) -> (B, s, V) (the lm head; sharding-constrained
    inside).  x: (B, S, d) final hidden states.  labels: (B, S) int32.
    """
    b, s, d = x.shape
    assert s % n_chunks == 0, (s, n_chunks)
    cs = s // n_chunks
    xc = x.reshape(b, n_chunks, cs, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, cs).transpose(1, 0, 2)

    def body(acc, inp):
        xi, li = inp
        logits = logits_fn(xi).astype(jnp.float32)          # (B, cs, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    # checkpoint: without it the scan saves every chunk's logits for the
    # backward pass and chunking saves nothing (measured ~8 GiB on gemma)
    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.float32(0.0), (xc, lc))
    return total / (b * s)


def constrain(x: jnp.ndarray, spec: P | None):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
