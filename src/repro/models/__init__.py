"""Model zoo: LM transformers (dense + MoE), GNNs, recsys -- pure JAX."""
from . import attention, layers, moe, transformer  # noqa: F401
