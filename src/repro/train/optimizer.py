"""AdamW + global-norm clipping + LR schedules, in raw JAX (no optax).

ZeRO-1 (`zero1_specs`): optimizer moments shard their leading dim over the
data axis when divisible -- GSPMD then lowers the update into
reduce-scatter(grads) + sharded update + all-gather(params'), i.e. the
standard ZeRO-1 schedule, cutting optimizer-state memory by the DP degree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"      # cosine | constant


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gn = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = lr_at(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gn, "lr": lr}


def opt_state_specs(param_specs, zero1: bool = False, params_shapes=None,
                    mesh: Optional[Mesh] = None, data_axis: str = "data"):
    """Specs for the optimizer state tree.  zero1=True additionally shards
    each moment's first dim over `data_axis` when divisible and free."""
    def moment_spec(spec, shaped):
        if not zero1 or mesh is None or data_axis not in mesh.axis_names:
            return spec
        dp = mesh.devices.shape[mesh.axis_names.index(data_axis)]
        dims = list(spec) if spec is not None else [None] * len(shaped.shape)
        while len(dims) < len(shaped.shape):
            dims.append(None)
        if dims and dims[0] is None and shaped.shape[0] % dp == 0:
            dims[0] = data_axis
            return P(*dims)
        return spec

    if params_shapes is None:
        m_specs = param_specs
    else:
        m_specs = jax.tree.map(moment_spec, param_specs, params_shapes)
    return {"m": m_specs, "v": m_specs, "count": P() if mesh else None}
