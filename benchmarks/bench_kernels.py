"""Kernel micro-benchmarks (CPU wall time of the jnp reference backend;
the Pallas TPU path is validated in interpret mode by tests/test_kernels
and tests/test_beam_fused).

The beam_fused sweep pits the fused hop loop against the serve engine's
historical unfused scan (per-hop pop + gather + pq_adc_rowwise +
concat-sort pool_merge) at the serving shape B=64, L=64, R=32 -- both
jit'd XLA CPU programs over the same corpus, bit-identical pools, so the
speedup is pure merge/loop structure.  REPRO_BENCH_KERN_N sizes the
corpus (graph rows); REPRO_BENCH_KERN_HOPS the hop count.
"""
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from repro.build.pool import pool_merge
from repro.kernels.beam_fused import beam_hops
from repro.kernels.flash_decode import flash_decode
from repro.kernels.l2_topk import l2_topk
from repro.kernels.pq_adc import pq_adc, pq_adc_rowwise

KERN_N = int(os.environ.get("REPRO_BENCH_KERN_N", "20000"))
KERN_HOPS = int(os.environ.get("REPRO_BENCH_KERN_HOPS", "16"))
# corpus sizes of the resident-vs-streaming sweep (interpret mode on CPU,
# the Pallas programs on TPU); small defaults -- interpret DMA is slow
STREAM_N = tuple(int(v) for v in os.environ.get(
    "REPRO_BENCH_STREAM_N", "1024,4096").split(","))
STREAM_HOPS = int(os.environ.get("REPRO_BENCH_STREAM_HOPS", "6"))


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> None:
    rng = np.random.default_rng(0)
    tables = jnp.asarray(rng.random((8, 16, 256)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 256, (65536, 16)), jnp.uint8)
    us = _time(lambda t, c: pq_adc(t, c, backend="ref"), tables, codes)
    common.emit("kernel.pq_adc.b8xn65536", round(us, 1),
                f"gflops={8*65536*16*2/us/1e3:.1f}")

    q = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    base = jnp.asarray(rng.normal(size=(100_000, 128)), jnp.float32)
    us = _time(lambda a, b: l2_topk(a, b, 100, backend="ref"), q, base)
    common.emit("kernel.l2_topk.b8xn100k", round(us, 1),
                f"gflops={2*8*100_000*128/us/1e3:.1f}")

    qq = jnp.asarray(rng.normal(size=(4, 32, 128)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(4, 8192, 8, 128)), jnp.bfloat16)
    vv = jnp.asarray(rng.normal(size=(4, 8192, 8, 128)), jnp.bfloat16)
    lens = jnp.full((4,), 8192, jnp.int32)
    us = _time(lambda a, b, c, d: flash_decode(a, b, c, d, backend="ref"),
               qq, kk, vv, lens)
    common.emit("kernel.flash_decode.b4s8192", round(us, 1),
                f"gbps={(kk.nbytes+vv.nbytes)/us/1e3:.1f}")

    ccodes = jnp.asarray(rng.integers(0, 256, (8, 4096, 16)), jnp.int32)
    us = _time(lambda t, c: pq_adc_rowwise(t, c, backend="ref"),
               tables, ccodes)
    common.emit("kernel.pq_adc_rowwise.b8xr4096", round(us, 1),
                f"gflops={8*4096*16*2/us/1e3:.1f}")

    _beam_sweep(rng)
    _stream_sweep(rng)


@functools.partial(jax.jit, static_argnames=("max_hops",))
def _unfused_hops(adj, pool_ids, pool_d, pool_exp, max_hops, tables, codes):
    """The serve engine's unfused hop scan (its non-fused backend path),
    inlined here as the baseline the fused kernel is measured against."""
    b, l = pool_ids.shape
    rows = jnp.arange(b)

    def step(state, _):
        pool_ids, pool_d, pool_exp, hops = state
        frontier_d = jnp.where(pool_exp | (pool_ids < 0), jnp.inf, pool_d)
        j = jnp.argmin(frontier_d, axis=1)
        has = jnp.isfinite(frontier_d[rows, j])
        v = jnp.where(has, pool_ids[rows, j], 0)
        pool_exp = pool_exp.at[rows, j].set(pool_exp[rows, j] | has)
        nbrs = jnp.where(has[:, None], adj[v], -1)
        nd = pq_adc_rowwise(tables, codes[jnp.clip(nbrs, 0)], backend="ref")
        nd = jnp.where(nbrs >= 0, nd, jnp.inf)
        pool_ids, pool_d, pool_exp = pool_merge(
            pool_ids, pool_d, pool_exp, nbrs, nd, l)
        return (pool_ids, pool_d, pool_exp, hops + has), None

    (pool_ids, pool_d, pool_exp, hops), _ = jax.lax.scan(
        step, (pool_ids, pool_d, pool_exp, jnp.zeros(b, jnp.int32)),
        None, length=max_hops)
    return pool_ids, pool_d, pool_exp, hops


def _beam_sweep(rng) -> None:
    """Fused vs unfused hop loop at the serving shape B=64, L=64, R=32."""
    n, r, m, k = KERN_N, 32, 16, 256
    b, l, hops = 64, 64, KERN_HOPS
    adj = jnp.asarray(rng.integers(0, n, (n, r)), jnp.int32)
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.int32)
    tables = jnp.asarray(rng.random((b, m, k)), jnp.float32)
    seeds = np.sort(rng.choice(n, (b, 4), replace=False).astype(np.int32), 1)
    pool_ids = jnp.full((b, l), -1, jnp.int32).at[:, :4].set(seeds)
    pool_d = jnp.full((b, l), jnp.inf, jnp.float32).at[:, :4].set(
        jnp.asarray(np.sort(rng.random((b, 4)), axis=1), jnp.float32))
    pool_exp = jnp.zeros((b, l), bool)

    u = _time(lambda *a: _unfused_hops(*a, hops, tables, codes),
              adj, pool_ids, pool_d, pool_exp)
    f = _time(lambda *a: beam_hops(*a, hops, tables=tables, codes=codes,
                                   backend="ref"),
              adj, pool_ids, pool_d, pool_exp)
    ou = _unfused_hops(adj, pool_ids, pool_d, pool_exp, hops, tables, codes)
    of = beam_hops(adj, pool_ids, pool_d, pool_exp, hops,
                   tables=tables, codes=codes, backend="ref")
    match = all(bool(jnp.array_equal(x, y)) for x, y in zip(ou[:2], of[:2]))
    hps = b * hops / u * 1e6
    common.emit("kernel.beam_unfused.b64l64r32.hop_us", round(u / hops, 1),
                f"hops_per_s={hps:.0f}")
    hps = b * hops / f * 1e6
    common.emit("kernel.beam_fused.b64l64r32.hop_us", round(f / hops, 1),
                f"hops_per_s={hps:.0f}")
    common.emit("kernel.beam_fused.b64l64r32.speedup", round(u / f, 2),
                f"pools_identical={match}")


def _stream_sweep(rng) -> None:
    """Resident vs HBM-streaming fused hop loop over corpus size N.

    On CPU both run the Pallas program in interpret mode (same code path,
    so the ratio isolates the DMA/chunk-walk structure; absolute numbers
    are TPU-only).  The VMEM budget the auto backend would compare
    against is pinned to the resident footprint at the *smallest* N, so
    the sweep honestly crosses it: the first point fits (auto would run
    resident), the later points do not (auto would stream), and each row
    reports both footprints + the fit bit.  Outputs are asserted
    bit-identical between the two programs at every N."""
    from repro.kernels.beam_fused import stream_vmem_bytes, vmem_bytes
    on_tpu = jax.default_backend() == "tpu"
    res_bk, str_bk = (("pallas", "stream") if on_tpu
                      else ("interpret", "stream_interpret"))
    b, l, r, m, k, hops = 8, 32, 32, 16, 256, STREAM_HOPS
    n_chunk = 512
    dims = dict(m=m, k=k, l=l, max_hops=hops, tile_b=8, n_chunk=n_chunk)
    budget = vmem_bytes(min(STREAM_N), r, **dims)
    for n in sorted(STREAM_N):
        adj = jnp.asarray(rng.integers(0, n, (n, r)), jnp.int32)
        codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.int32)
        tables = jnp.asarray(rng.random((b, m, k)), jnp.float32)
        seeds = np.sort(rng.choice(n, (b, 4), replace=False)
                        .astype(np.int32), 1)
        pool_ids = jnp.full((b, l), -1, jnp.int32).at[:, :4].set(seeds)
        pool_d = jnp.full((b, l), jnp.inf, jnp.float32).at[:, :4].set(
            jnp.asarray(np.sort(rng.random((b, 4)), axis=1), jnp.float32))
        pool_exp = jnp.zeros((b, l), bool)
        args = (adj, pool_ids, pool_d, pool_exp)

        def hop(backend):
            return lambda *a: beam_hops(*a, hops, tables=tables,
                                        codes=codes, backend=backend,
                                        n_chunk=n_chunk)

        t_res = _time(hop(res_bk), *args, reps=2)
        t_str = _time(hop(str_bk), *args, reps=2)
        o_res = hop(res_bk)(*args)
        o_str = hop(str_bk)(*args)
        match = all(bool(jnp.array_equal(x, y))
                    for x, y in zip(o_res, o_str))
        assert match, f"stream pools diverged from resident at n={n}"
        vb, sb = vmem_bytes(n, r, **dims), stream_vmem_bytes(n, r, **dims)
        common.emit(
            f"kernel.beam_stream.n{n}.hop_us", round(t_str / hops, 1),
            f"resident_hop_us={t_res / hops:.1f};"
            f"overhead={t_str / t_res:.2f}x;"
            f"vmem_resident={vb};vmem_stream={sb};"
            f"fits_budget={int(vb <= budget)};bit_identical={int(match)};"
            f"backend={str_bk}")


if __name__ == "__main__":
    run()
