"""Scalable proximity-graph builders: Vamana (DiskANN) and NSG.

Host-side (numpy) construction with JAX used for the bulk distance work.
Graphs are padded int32 adjacency (n, R), -1 padded. These are the inputs
to the block-aware stage (core/bamg.py) and the baselines for benchmarks.
"""
from __future__ import annotations

import heapq

import numpy as np

from .distances import knn_graph, medoid, pairwise_sq_l2


def _dists_to(x: np.ndarray, ids: np.ndarray, q: np.ndarray) -> np.ndarray:
    v = x[ids] - q[None, :]
    return np.einsum("nd,nd->n", v, v)


def greedy_search(
    x: np.ndarray,
    adj: np.ndarray,
    entry: int,
    q: np.ndarray,
    ef: int,
    max_steps: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Beam search on a padded graph. Returns (visited_ids, visited_dists)
    in visit order -- the candidate pool used by Vamana/NSG construction.
    """
    dq = float(_dists_to(x, np.array([entry]), q)[0])
    # heap of (dist, id) candidates; visited dict id->dist
    cand: list[tuple[float, int]] = [(dq, entry)]
    visited: dict[int, float] = {}
    results: list[tuple[float, int]] = []  # max-heap via negation
    seen = {entry}
    steps = 0
    while cand:
        d, v = heapq.heappop(cand)
        if len(results) >= ef and d > -results[0][0]:
            break
        visited[v] = d
        heapq.heappush(results, (-d, v))
        if len(results) > ef:
            heapq.heappop(results)
        steps += 1
        if max_steps is not None and steps >= max_steps:
            break
        nbrs = adj[v]
        nbrs = nbrs[nbrs >= 0]
        new = [u for u in nbrs.tolist() if u not in seen]
        if not new:
            continue
        seen.update(new)
        nd = _dists_to(x, np.asarray(new), q)
        bound = -results[0][0] if len(results) >= ef else np.inf
        for u, du in zip(new, nd.tolist()):
            if du < bound or len(results) < ef:
                heapq.heappush(cand, (du, u))
    ids = np.fromiter(visited.keys(), np.int64, len(visited))
    ds = np.fromiter(visited.values(), np.float64, len(visited))
    o = np.argsort(ds, kind="stable")
    return ids[o], ds[o]


def robust_prune(
    x: np.ndarray,
    p: int,
    cand_ids: np.ndarray,
    cand_d: np.ndarray,
    r: int,
    alpha: float = 1.0,
) -> np.ndarray:
    """Vamana RobustPrune / NSG MRNG-style edge selection (alpha=1 -> MRNG).

    Keep v (ascending distance from p) unless an already kept u satisfies
    alpha * d(u, v) <= d(p, v).
    """
    o = np.argsort(cand_d, kind="stable")
    cand_ids = cand_ids[o]
    cand_d = cand_d[o]
    kept: list[int] = []
    kept_vecs: list[np.ndarray] = []
    for v, dv in zip(cand_ids.tolist(), cand_d.tolist()):
        if v == p:
            continue
        ok = True
        xv = x[v]
        for xu in kept_vecs:
            duv = float(np.dot(xu - xv, xu - xv))
            if alpha * duv <= dv:
                ok = False
                break
        if ok:
            kept.append(v)
            kept_vecs.append(xv)
            if len(kept) >= r:
                break
    return np.asarray(kept, np.int32)


def _pad_adj(neighbors: list[np.ndarray], r: int) -> np.ndarray:
    n = len(neighbors)
    adj = -np.ones((n, r), np.int32)
    for i, row in enumerate(neighbors):
        row = row[:r]
        adj[i, : len(row)] = row
    return adj


def build_vamana(
    x: np.ndarray,
    r: int = 32,
    l_build: int = 64,
    alpha: float = 1.2,
    seed: int = 0,
    passes: int = 2,
) -> tuple[np.ndarray, int]:
    """DiskANN's Vamana graph. Returns (padded adjacency (n,R), medoid)."""
    n = len(x)
    rng = np.random.default_rng(seed)
    # random regular-ish init
    neighbors = [rng.choice(n, size=min(r, n - 1), replace=False) for _ in range(n)]
    neighbors = [row[row != i][: r] for i, row in enumerate(neighbors)]
    adj = _pad_adj([np.asarray(v, np.int32) for v in neighbors], r)
    med = medoid(x)
    alphas = [1.0] * (passes - 1) + [alpha]
    for a in alphas:
        order = rng.permutation(n)
        for p in order.tolist():
            vis_ids, vis_d = greedy_search(x, adj, med, x[p], ef=l_build)
            # candidate set: visited U current neighbors
            cur = adj[p]
            cur = cur[cur >= 0]
            cand = np.unique(np.concatenate([vis_ids.astype(np.int64), cur.astype(np.int64)]))
            cand = cand[cand != p]
            cd = _dists_to(x, cand, x[p])
            kept = robust_prune(x, p, cand, cd, r, alpha=a)
            adj[p] = -1
            adj[p, : len(kept)] = kept
            # add reverse edges with pruning on overflow
            dp = _dists_to(x, kept, x[p])
            for v, dvp in zip(kept.tolist(), dp.tolist()):
                row = adj[v]
                if p in row[row >= 0]:
                    continue
                slot = np.nonzero(row < 0)[0]
                if len(slot):
                    adj[v, slot[0]] = p
                else:
                    cc = np.concatenate([row[row >= 0].astype(np.int64), [p]])
                    cd2 = _dists_to(x, cc, x[v])
                    kept2 = robust_prune(x, v, cc, cd2, r, alpha=a)
                    adj[v] = -1
                    adj[v, : len(kept2)] = kept2
    return adj, med


def build_nsg(
    x: np.ndarray,
    r: int = 32,
    l_build: int = 64,
    knn_k: int = 32,
    seed: int = 0,
) -> tuple[np.ndarray, int]:
    """NSG [Fu et al. 2019]: approximate MRNG from a kNN graph.

    1) exact kNN graph; 2) for each node, search from the medoid ("navigating
    node") over the kNN graph to collect candidates; 3) MRNG-style prune
    (alpha=1); 4) DFS-tree pass to guarantee connectivity from the medoid.
    """
    n = len(x)
    knn = knn_graph(x, knn_k)
    med = medoid(x)
    neighbors: list[np.ndarray] = []
    for p in range(n):
        vis_ids, vis_d = greedy_search(x, knn, med, x[p], ef=l_build)
        cand = np.unique(np.concatenate([vis_ids.astype(np.int64), knn[p].astype(np.int64)]))
        cand = cand[(cand != p) & (cand >= 0)]   # drop -1 kNN padding
        cd = _dists_to(x, cand, x[p])
        kept = robust_prune(x, p, cand, cd, r, alpha=1.0)
        neighbors.append(kept)
    adj = _pad_adj(neighbors, r)
    connect_to_entry(x, adj, med)
    return adj, med


def connect_to_entry(x: np.ndarray, adj: np.ndarray, entry: int) -> None:
    """In-place NSG "tree spanning" step: BFS from `entry`; attach every
    unreachable node to its nearest reachable neighbor (force-linking into
    the last slot when the row is full -- connectivity beats pruning)."""
    n, r = adj.shape
    reached = np.zeros(n, bool)
    stack = [entry]
    reached[entry] = True
    while stack:
        v = stack.pop()
        for u in adj[v]:
            if u >= 0 and not reached[u]:
                reached[u] = True
                stack.append(int(u))
    missing = np.nonzero(~reached)[0]
    if len(missing):
        ridx = np.nonzero(reached)[0]
        d = pairwise_sq_l2(x[missing], x[ridx])
        near = ridx[np.argmin(d, axis=1)]
        for m, v in zip(missing.tolist(), near.tolist()):
            row = adj[v]
            slot = np.nonzero(row < 0)[0]
            if len(slot):
                adj[v, slot[0]] = m
            else:
                adj[v, r - 1] = m
            reached[m] = True


def degree_stats(adj: np.ndarray, blocks: np.ndarray | None = None) -> dict:
    """Average out-degree; if blocks given, split intra / cross (Table 2)."""
    valid = adj >= 0
    total = valid.sum(1).mean()
    out = {"total": float(total)}
    if blocks is not None:
        n, r = adj.shape
        src = np.repeat(np.arange(n), r)[valid.ravel()]
        dst = adj.ravel()[valid.ravel()]
        same = blocks[src] == blocks[dst]
        out["intra"] = float(same.sum() / n)
        out["cross"] = float((~same).sum() / n)
    return out
