"""din [arXiv:1706.06978]: target-attention CTR model, embed_dim=18,
behavior seq 100, attn MLP 80-40, MLP 200-80; 10^6-row embedding tables
row-sharded over `model`.  retrieval_cand is the paper's ANN workload
(BAMG index over the item embeddings in examples/din_retrieval.py)."""
from repro.models.recsys.din import DINConfig

from .base import RECSYS_SHAPES

ARCH_ID = "din"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def model_config(reduced: bool = False) -> DINConfig:
    if reduced:
        return DINConfig(name=ARCH_ID + "-smoke", embed_dim=8, seq_len=12,
                         attn_mlp=(16, 8), mlp=(32, 16), n_items=2048,
                         n_cates=64, rerank_k=32)
    return DINConfig(name=ARCH_ID, embed_dim=18, seq_len=100,
                     attn_mlp=(80, 40), mlp=(200, 80), n_items=1_048_576,
                     n_cates=1024, rerank_k=1024)
