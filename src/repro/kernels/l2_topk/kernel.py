"""Pallas TPU kernel: fused squared-L2 distance + running top-k.

One MXU matmul per (query-block x base-tile) computes the distance tile;
a k-step selection loop merges the tile into the running top-k held in the
output block (constant out index map over the base-tile grid axis -- the
sequential TPU grid makes the output an accumulator).

VMEM per step: q (TB, D) + x (TN, D) + dist (TB, TN) + out (TB, k) --
with TB=8, TN=512, D<=1024: ~32 KB + 2 MB + 16 KB + small.  TN and D in
multiples of 128 keep the MXU aligned; selection is VPU work, k * (TN + k)
ops per row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _l2_topk_kernel(q_ref, x_ref, vals_ref, ids_ref, *, k: int, tile_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, jnp.inf)
        ids_ref[...] = jnp.full_like(ids_ref, -1)

    q = q_ref[...].astype(jnp.float32)          # (TB, D)
    x = x_ref[...].astype(jnp.float32)          # (TN, D)
    d = (jnp.sum(q * q, 1, keepdims=True) + jnp.sum(x * x, 1)[None, :]
         - 2.0 * jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32))
    d = jnp.maximum(d, 0.0)                     # (TB, TN)
    base_id = j * tile_n
    tile_ids = base_id + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)

    # merge buffer: [running top-k | tile]
    buf_v = jnp.concatenate([vals_ref[...], d], axis=1)          # (TB, k+TN)
    buf_i = jnp.concatenate([ids_ref[...], tile_ids], axis=1)

    def select(s, carry):
        bv, bi, ov, oi = carry
        am = jnp.argmin(bv, axis=1)                              # (TB,)
        rows = jax.lax.broadcasted_iota(jnp.int32, bv.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, bv.shape, 1)
        hit = cols == am[:, None]
        mv = jnp.min(bv, axis=1)
        mi = jnp.sum(jnp.where(hit, bi, 0), axis=1)
        bv = jnp.where(hit, jnp.inf, bv)
        out_col = jax.lax.broadcasted_iota(jnp.int32, ov.shape, 1)
        write = out_col == s
        ov = jnp.where(write, mv[:, None], ov)
        oi = jnp.where(write, mi[:, None], oi)
        return bv, bi, ov, oi

    ov = jnp.zeros_like(vals_ref)
    oi = jnp.zeros_like(ids_ref)
    _, _, ov, oi = jax.lax.fori_loop(0, k, select, (buf_v, buf_i, ov, oi))
    vals_ref[...] = ov
    ids_ref[...] = oi


@functools.partial(jax.jit,
                   static_argnames=("k", "tile_b", "tile_n", "interpret"))
def l2_topk_pallas(queries: jnp.ndarray, base: jnp.ndarray, k: int,
                   tile_b: int = 8, tile_n: int = 512,
                   interpret: bool = False):
    """queries (B, D), base (N, D) -> (vals (B,k) ascending, ids (B,k)).

    B % tile_b == 0 and N % tile_n == 0 (ops.py pads).
    """
    b, d = queries.shape
    n = base.shape[0]
    assert b % tile_b == 0 and n % tile_n == 0

    vals, ids = pl.pallas_call(
        functools.partial(_l2_topk_kernel, k=k, tile_n=tile_n),
        grid=(b // tile_b, n // tile_n),
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_b, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries.astype(jnp.float32), base.astype(jnp.float32))
    return vals, ids
