"""Memory-bounded distributed row gather: `out[t] = table[idx[t]]` where
`table` is row-sharded over a mesh axis and idx indexes it *globally*.

Instead of all-gathering the table (measured: 29.5 GiB x 12 live copies for
dimenet/ogb_products triplet gathers), the local shards rotate around the
axis with collective-permute; each shard picks the rows it needs from the
chunk it currently holds.  Peak extra memory = one shard chunk.

The VJP is the mirrored ring *scatter*: cotangent rows accumulate into a
rotating per-owner buffer; after P steps every owner's buffer has visited
every shard and returns home complete.  Both directions are fori_loops with
O(1) live chunks (no per-step autodiff residuals).

Call inside shard_map with `axis_name` bound.  Collective volume equals one
logical all-gather of the table per call -- the win is memory, not bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.utils.sharding import bound_axis_size as _axis_size


def _ring_perm(p: int):
    return [(j, (j + 1) % p) for j in range(p)]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ring_gather(table_local: jnp.ndarray, idx: jnp.ndarray,
                axis_name: str) -> jnp.ndarray:
    """table_local (R, d) = this shard's rows [me*R, (me+1)*R); idx (T,)
    global row ids (negative = padding -> zeros).  Returns (T, d)."""
    return _ring_gather_fwd_impl(table_local, idx, axis_name)


def _ring_gather_fwd_impl(table_local, idx, axis_name):
    p = _axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    r, d = table_local.shape
    t = idx.shape[0]
    perm = _ring_perm(p)

    def step(i, carry):
        chunk, out = carry
        owner = (me - i) % p          # who produced the chunk we now hold
        lo = owner * r
        sel = (idx >= lo) & (idx < lo + r)
        rows = chunk[jnp.clip(idx - lo, 0, r - 1)]
        out = jnp.where(sel[:, None], rows, out)
        chunk = jax.lax.ppermute(chunk, axis_name, perm)
        return chunk, out

    out0 = jnp.zeros((t, d), table_local.dtype)
    _, out = jax.lax.fori_loop(0, p, step, (table_local, out0))
    return out


def _fwd(table_local, idx, axis_name):
    # shape/dtype ride in a zero-byte proxy (raw dtypes are not JAX types)
    proxy = jnp.zeros((table_local.shape[0], 0), table_local.dtype)
    return _ring_gather_fwd_impl(table_local, idx, axis_name), (idx, proxy)


def _bwd(axis_name, res, dout):
    idx, proxy = res
    r, dtype = proxy.shape[0], proxy.dtype
    p = _axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    perm = _ring_perm(p)
    d = dout.shape[1]

    def step(i, acc):
        # acc currently belongs to owner (me - i) % p; add our rows for it
        owner = (me - i) % p
        lo = owner * r
        sel = (idx >= lo) & (idx < lo + r)
        local = jnp.where(sel, idx - lo, r)   # r = dump row
        contrib = jax.ops.segment_sum(
            jnp.where(sel[:, None], dout, 0.0).astype(jnp.float32),
            local, num_segments=r + 1)[:r]
        acc = acc + contrib
        return jax.lax.ppermute(acc, axis_name, perm)

    acc0 = jnp.zeros((r, d), jnp.float32)
    # after p rotations each owner's accumulator is back home
    acc = jax.lax.fori_loop(0, p, step, acc0)
    return (acc.astype(dtype), None)


ring_gather.defvjp(_fwd, _bwd)


# ---------------------------------------------------------------------------
# The mirrored primitive: distributed segment-sum into a row-sharded table.
# VJP(ring_scatter_add) = ring_gather, and vice versa.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def ring_scatter_add(values: jnp.ndarray, idx: jnp.ndarray,
                     axis_name, rows_local: int) -> jnp.ndarray:
    """out[idx[t]] += values[t] with `out` row-sharded over axis_name.

    values (T_local, d); idx (T_local,) *global* row ids (negative =
    dropped); returns this shard's (rows_local, d) slice.  Accumulation
    buffers rotate around the ring: one chunk live at a time.
    """
    return _ring_scatter_impl(values, idx, axis_name, rows_local)


def _ring_scatter_impl(values, idx, axis_name, rows_local):
    p = _axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    perm = _ring_perm(p)
    d = values.shape[1]

    def step(i, acc):
        owner = (me - i) % p
        lo = owner * rows_local
        sel = (idx >= lo) & (idx < lo + rows_local)
        local = jnp.where(sel, idx - lo, rows_local)  # dump row
        contrib = jax.ops.segment_sum(
            jnp.where(sel[:, None], values, 0.0).astype(jnp.float32),
            local, num_segments=rows_local + 1)[:rows_local]
        acc = acc + contrib
        return jax.lax.ppermute(acc, axis_name, perm)

    acc = jax.lax.fori_loop(0, p, step, jnp.zeros((rows_local, d), jnp.float32))
    return acc.astype(values.dtype)


def _scat_fwd(values, idx, axis_name, rows_local):
    return _ring_scatter_impl(values, idx, axis_name, rows_local), \
        (idx, jnp.zeros((0,), values.dtype))


def _scat_bwd(axis_name, rows_local, res, dout):
    idx, proxy = res
    dv = _ring_gather_fwd_impl(dout, idx, axis_name)
    return (dv.astype(proxy.dtype), None)


ring_scatter_add.defvjp(_scat_fwd, _scat_bwd)
