"""BAMG construction (Algorithm 2): linear-time block-aware refinement of a
monotonic base graph (NSG), per §4.1.

Steps (paper-faithful):
  1. Build NSG G from X.
  2. Block assignment via BNF block shuffling on G.
  3. Keep ALL intra-block edges of G (mitigates suboptimal assignment).
  4. Treat cross-block edges as candidates; prune with relaxed Rule 2 Case 2:
       prune (u, q) iff for some kept cross-block neighbor v, a monotone
       (toward q) intra-block path of <= alpha hops from v inside B_L(v)
       ends at z with  delta(z, q) * beta < delta(v, q).
  5. Sibling heuristic: if candidate q shares a block with kept neighbor v,
     add intra-block edges (v, q) and (q, v) (Alg. 2 lines 18-20).

`occlusion_ref` selects the pruning reference distance. The paper is
internally inconsistent: Alg. 2 line 16 compares the path endpoint against
delta(v, q) ("alg2"), while the formal Prune() rule in §4.1 -- and the
BMRNG Rule 2 lune condition delta(z,q) < delta(u,q) it relaxes -- compare
against delta(u, q) ("rule").  "alg2" over-prunes badly (measured: total
degree ~5 vs the paper's ~24 on a SIFT-like corpus, destroying recall), so
the faithful default is "rule"; "alg2" is kept for the ablation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .block_assign import bnf_blocks, block_members
from .graph_build import build_nsg


@dataclasses.dataclass
class BAMGGraph:
    adj: np.ndarray          # (n, R') padded int32 adjacency
    blocks: np.ndarray       # (n,) int32 block assignment
    members: np.ndarray      # (m, c) padded block member table
    entry: int               # medoid of the base NSG
    capacity: int            # block capacity c
    alpha: int
    beta: float


def _sqd(x: np.ndarray, a: int, b_vec: np.ndarray) -> float:
    v = x[a] - b_vec
    return float(np.dot(v, v))


def _block_search_toward(
    x: np.ndarray,
    adj_lists: list[np.ndarray],
    blocks: np.ndarray,
    v: int,
    q_vec: np.ndarray,
    alpha: int,
) -> float:
    """Greedy monotone search toward q inside block B_L(v), <= alpha hops.

    Returns the best (smallest) squared distance to q reached -- the
    `delta(C[0], q)` of Algorithm 2 line 15/16. Exactly the paper's
    search_within_block restricted to intra-block neighbors with strictly
    decreasing distance.
    """
    blk = blocks[v]
    cur = v
    dv = q_vec - x[v]
    best = float(np.dot(dv, dv))
    for _ in range(alpha):
        nbrs = adj_lists[cur]
        improved = False
        for w in nbrs.tolist():
            if blocks[w] != blk:
                continue
            dw = q_vec - x[w]
            dwq = float(np.dot(dw, dw))
            if dwq < best:
                best = dwq
                cur = w
                improved = True
        if not improved:
            break
    return best


def build_bamg_from(
    x: np.ndarray,
    nsg_adj: np.ndarray,
    entry: int,
    blocks: np.ndarray,
    capacity: int,
    alpha: int = 3,
    beta: float = 1.0,
    occlusion_ref: str = "rule",
    sibling_edges: bool = True,
    max_degree: int | None = None,
    probe=None,
) -> BAMGGraph:
    """Algorithm 2 given a prebuilt base graph + block assignment.

    `probe(u, v, q, q_vec, dvq) -> float` supplies the intra-block
    monotone-search minimum `delta(C[0], q)` for the occlusion test; the
    default runs the host `_block_search_toward`.  The batched backend
    (`repro.build.bamg_refine`) passes a lookup into device-precomputed
    walks, so both backends share this scan verbatim and cannot diverge.
    """
    n = len(x)
    r = nsg_adj.shape[1]
    adj_lists = [row[row >= 0].astype(np.int64) for row in nsg_adj]
    if probe is None:
        def probe(u, v, q, q_vec, dvq):
            return _block_search_toward(x, adj_lists, blocks, v, q_vec,
                                        alpha)
    new_lists: list[list[int]] = [[] for _ in range(n)]

    # Pass 1: intra-block edges are kept verbatim (Alg. 2 lines 7-8).
    for u in range(n):
        for v in adj_lists[u].tolist():
            if blocks[v] == blocks[u]:
                new_lists[u].append(v)

    # Pass 2: cross-block candidates, ascending distance, Rule 2 Case 2.
    for u in range(n):
        xu = x[u]
        cout = [v for v in adj_lists[u].tolist() if blocks[v] != blocks[u]]
        if not cout:
            continue
        dq = np.array([_sqd(x, u, x[v]) for v in cout])
        order = np.argsort(dq, kind="stable")
        r_out: list[int] = []
        r_out_d: list[float] = []
        for oi in order.tolist():
            q = cout[oi]
            duq = float(dq[oi])
            q_vec = x[q]
            occlude = False
            folded = False
            for v, dvq_u in zip(r_out, r_out_d):
                dvv = q_vec - x[v]
                dvq = float(np.dot(dvv, dvv))  # delta(v, q)
                best = probe(u, v, q, q_vec, dvq)
                ref = dvq if occlusion_ref == "alg2" else duq
                if best * beta < ref:
                    occlude = True
                    break
                if sibling_edges and blocks[v] == blocks[q]:
                    # Alg. 2 lines 18-20: fold q in as intra-block sibling of v
                    if q not in new_lists[v]:
                        new_lists[v].append(q)
                    if v not in new_lists[q]:
                        new_lists[q].append(v)
                    folded = True
                    break
            if occlude or folded:
                continue
            r_out.append(q)
            r_out_d.append(duq)
        new_lists[u].extend(r_out)

    rmax = max((len(l) for l in new_lists), default=1)
    if max_degree is not None:
        rmax = min(rmax, max_degree)
    adj = -np.ones((n, max(rmax, 1)), np.int32)
    for u, l in enumerate(new_lists):
        # intra edges first (they are free at search time), then cross
        intra = [v for v in l if blocks[v] == blocks[u]]
        cross = [v for v in l if blocks[v] != blocks[u]]
        row = (intra + cross)[: adj.shape[1]]
        adj[u, : len(row)] = row
    members = block_members(blocks, capacity)
    return BAMGGraph(
        adj=adj, blocks=np.asarray(blocks, np.int32), members=members,
        entry=entry, capacity=capacity, alpha=alpha, beta=beta,
    )


def build_bamg(
    x: np.ndarray,
    capacity: int,
    alpha: int = 3,
    beta: float = 1.0,
    r: int = 32,
    l_build: int = 64,
    knn_k: int = 32,
    seed: int = 0,
    occlusion_ref: str = "rule",
    sibling_edges: bool = True,
) -> BAMGGraph:
    """build_BAMG(X, alpha, beta) -- Algorithm 2 end to end."""
    nsg_adj, entry = build_nsg(x, r=r, l_build=l_build, knn_k=knn_k, seed=seed)
    blocks = bnf_blocks(nsg_adj, capacity, seed=seed)
    return build_bamg_from(
        x, nsg_adj, entry, blocks, capacity, alpha=alpha, beta=beta,
        occlusion_ref=occlusion_ref, sibling_edges=sibling_edges,
    )
