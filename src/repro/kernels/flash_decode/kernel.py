"""Pallas TPU kernel: KV-chunked decode attention (flash-decode).

One query token per sequence attends over a long KV cache.  The KV cache is
streamed through VMEM in chunks of TS positions; an online-softmax state
(m = running max, l = running normalizer, acc = weighted value sum) lives in
VMEM scratch and is carried across the sequential KV grid axis.  The same
partial-softmax merge runs *across devices* when the cache is
sequence-sharded (models/attention.py `decode_attention(kv_shards=...)`),
so this kernel is the per-device building block of the distributed decode.

Grid: (B, S // TS) -- batch outer, KV chunks inner (sequential).
VMEM per step: q (1, H, Dh) + k/v (1, TS, Hkv, Dh) + acc (H, Dh) + m/l (H,).
With H=32, Hkv=8, Dh=128, TS=512: ~16 KB + 2*2 MB + 16 KB.  GQA is handled
by an Hkv-step loop of (G, Dh) x (Dh, TS) MXU matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, out_ref,
                         m_ref, l_ref, acc_ref,
                         *, ts: int, hkv: int, g: int, dh: int, scale: float):
    j = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = len_ref[0]
    q = q_ref[...].reshape(hkv, g, dh).astype(jnp.float32) * scale
    k = k_ref[...].reshape(ts, hkv, dh).astype(jnp.float32)
    v = v_ref[...].reshape(ts, hkv, dh).astype(jnp.float32)

    pos = j * ts + jax.lax.broadcasted_iota(jnp.int32, (1, ts), 1)  # (1, TS)
    valid = pos < cache_len                                         # (1, TS)

    def per_kv_head(n, carry):
        m, l, acc = carry                                # (Hkv*G,), (Hkv*G,), (Hkv*G, Dh)
        qn = jax.lax.dynamic_slice_in_dim(q, n, 1, 0).reshape(g, dh)
        kn = jax.lax.dynamic_slice_in_dim(k, n, 1, 1).reshape(ts, dh)
        vn = jax.lax.dynamic_slice_in_dim(v, n, 1, 1).reshape(ts, dh)
        s = jax.lax.dot_general(qn, kn, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, TS)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(
            jax.lax.dynamic_slice_in_dim(m, n * g, g, 0), s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])                              # (G, TS)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(jax.lax.dynamic_slice_in_dim(m, n * g, g, 0) - m_new)
        l_new = corr * jax.lax.dynamic_slice_in_dim(l, n * g, g, 0) + p.sum(1)
        acc_n = jax.lax.dynamic_slice_in_dim(acc, n * g, g, 0)
        acc_n = acc_n * corr[:, None] + jax.lax.dot_general(
            p, vn, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, n * g, 0)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, n * g, 0)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, acc_n, n * g, 0)
        return m, l, acc

    m, l, acc = jax.lax.fori_loop(
        0, hkv, per_kv_head, (m_ref[...], l_ref[...], acc_ref[...]))
    m_ref[...], l_ref[...], acc_ref[...] = m, l, acc

    @pl.when(j == n_chunks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        out_ref[...] = (acc_ref[...] / denom).reshape(1, hkv * g, dh)


@functools.partial(jax.jit, static_argnames=("ts", "scale", "interpret"))
def flash_decode_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        cache_len: jnp.ndarray, ts: int = 512,
                        scale: float | None = None,
                        interpret: bool = False) -> jnp.ndarray:
    """q (B,H,Dh) | k,v (B,S,Hkv,Dh) | cache_len (B,) -> (B,H,Dh) f32.

    S % ts == 0 (ops.py pads; padded positions are masked by cache_len).
    """
    b, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    assert s % ts == 0 and h % hkv == 0
    g = h // hkv
    scale = float(dh ** -0.5) if scale is None else scale

    out = pl.pallas_call(
        functools.partial(_flash_decode_kernel, ts=ts, hkv=hkv, g=g, dh=dh,
                          scale=scale),
        grid=(b, s // ts),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, ts, hkv, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, ts, hkv, dh), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),      # m: running max
            pltpu.VMEM((h,), jnp.float32),      # l: running normalizer
            pltpu.VMEM((h, dh), jnp.float32),   # acc: weighted value sum
        ],
        interpret=interpret,
    )(cache_len.astype(jnp.int32), q, k, v)
    return out
