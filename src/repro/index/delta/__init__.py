"""FreshDiskANN-style delta layer over a frozen BAMG index.

The write path, in four pieces:

- `layer.DeltaLayer` -- the in-memory overlay: new points are wired into
  copy-on-write adjacency rows by incremental RobustPrune
  (`repro.build.prune.robust_prune_inc`), deletes become tombstones that
  stay *navigable* but can never surface in a result.
- `engine.FreshBAMGEngine` -- unified base+delta queries: beam search
  over the frozen BAMG index (host Alg-4 or the batched serve engine)
  and over the delta graph, merged through the existing pool machinery
  with tombstones masked on every path.
- `consolidate.consolidate` -- background fold of the delta into a fresh
  BAMG build: edge repair around deleted nodes via neighbor-of-neighbor
  RobustPrune, then BNF block re-assignment + block-aware Alg-2 refine
  so block topology realigns with the merged graph.
- `service.FreshService` -- the read-write facade: stable external ids,
  insert/delete/search while consolidated builds publish through
  `repro.serve.deploy` (publish -> verify -> validate -> promote) and
  `BlueGreenEngine.refresh()` hot-swaps with zero read downtime.
"""
from .consolidate import consolidate
from .engine import FreshBAMGEngine
from .layer import DeltaLayer, DeltaParams
from .service import FreshService

__all__ = [
    "DeltaLayer",
    "DeltaParams",
    "FreshBAMGEngine",
    "FreshService",
    "consolidate",
]
