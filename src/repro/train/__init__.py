"""Training substrate: optimizer, trainer, checkpointing, compression, FT."""
