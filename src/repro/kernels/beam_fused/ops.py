"""Public jit'd wrapper for the fused beam-hop kernel: padding + backend.

`beam_hops` runs `max_hops` fused beam hops (frontier select + gather +
score + pool merge per hop) over a seeded sorted pool and returns the
final pool plus the per-hop frontier trace, next pick, and done mask.
Two scoring modes select the operand set:

- ADC (serving): pass ``tables`` (B, M, K) and ``codes`` (N, M);
- exact L2 (construction frontier): pass ``x`` (N, D), ``n2`` (N,)
  squared norms, and ``queries`` (B, D).

backend: "pallas" (TPU), "interpret" (CPU-validated kernel), or "ref"
(pure jnp scan, bit-identical to the unfused serve hop loop); "auto" =
pallas on TPU else ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import beam_hops_adc_pallas, beam_hops_l2_pallas
from .ref import beam_hops_ref


def _pad_rows(a, mult: int, fill=0):
    pad = (-a.shape[0]) % mult
    if pad == 0:
        return a
    widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("max_hops", "backend", "tile_b",
                                             "n_chunk"))
def beam_hops(adj, pool_ids, pool_d, pool_exp, max_hops: int,
              tables=None, codes=None, x=None, n2=None, queries=None,
              backend: str = "auto", tile_b: int = 8, n_chunk: int = 2048):
    """Fused beam-hop loop.  adj (N, R) int32 with -1 pad; the seeded pool
    (B, L) triplet must satisfy the `pool_merge` invariant (sorted by
    (dist, id), invalid = (-1, +inf, False)).

    Returns (pool_ids (B, L) int32, pool_d (B, L) f32, pool_exp (B, L)
    bool, hops (B,) int32, trace_ids (B, max_hops) int32, trace_d
    (B, max_hops) f32, next_id (B,) int32, done (B,) bool).
    """
    mode = "adc" if codes is not None else "l2"
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return beam_hops_ref(adj, pool_ids, pool_d, pool_exp, max_hops,
                             mode=mode, tables=tables, codes=codes,
                             x=x, n2=n2, queries=queries)

    b0 = pool_ids.shape[0]
    nc = min(n_chunk, max(adj.shape[0], 128))
    adj_p = _pad_rows(adj.astype(jnp.float32), nc, fill=-1)
    pids = _pad_rows(pool_ids.astype(jnp.float32), tile_b, fill=-1)
    pd = _pad_rows(pool_d.astype(jnp.float32), tile_b, fill=jnp.inf)
    pexp = _pad_rows(pool_exp.astype(jnp.float32), tile_b)
    interpret = backend == "interpret"
    if mode == "adc":
        out = beam_hops_adc_pallas(
            adj_p, _pad_rows(codes.astype(jnp.float32), nc),
            _pad_rows(tables.astype(jnp.float32), tile_b),
            pids, pd, pexp, max_hops, tile_b=tile_b, n_chunk=nc,
            interpret=interpret)
    else:
        xn = jnp.concatenate(
            [x.astype(jnp.float32), n2.astype(jnp.float32)[:, None]], axis=1)
        out = beam_hops_l2_pallas(
            adj_p, _pad_rows(xn, nc),
            _pad_rows(queries.astype(jnp.float32), tile_b),
            pids, pd, pexp, max_hops, tile_b=tile_b, n_chunk=nc,
            interpret=interpret)
    ids, d, exp, hops, tid, td, nxt, done = out
    return (ids[:b0], d[:b0], exp[:b0].astype(bool), hops[:b0, 0],
            tid[:b0], td[:b0], nxt[:b0, 0], done[:b0, 0].astype(bool))
