"""Batched BAMG refinement (Algorithm 2) -- block-aware cross-edge pruning
with all intra-block monotone probes evaluated on device.

The host reference (`repro.core.bamg.build_bamg_from`) spends almost all
its time in `_block_search_toward`: for every ordered pair (v, q) of
cross-block candidates of a node it walks <= alpha monotone intra-block
hops from v toward q, one Python loop per hop per neighbor.  Here the
probes for a whole node batch are flattened into (v, q) pair arrays and
evaluated hop-by-hop in a jitted kernel (padded gathers, argmin steps);
the occlusion / sibling-fold scan then runs `build_bamg_from` itself with
a probe that looks up the precomputed walks, so the refined adjacency is
bit-identical to the reference by construction (pinned by
tests/test_build_parity.py).

Work reduction vs the naive all-pairs sweep:

- only *ordered* pairs are probed (v strictly closer to u than q in the
  host's stable scan order -- the only pairs its occlusion loop can
  check);
- walks gather from a prefiltered intra-block adjacency (built once, max
  intra-degree wide) instead of masking the full graph row per hop;
- pairs whose walk stopped improving are compacted away between hops, so
  hop h only pays for walks still alive.

Parity notes:
- the walk reproduces the host's running-minimum semantics exactly: a hop
  moves to the first argmin neighbor iff it strictly improves, and stops
  otherwise;
- the probe returns the walk minimum only for walks that improved
  (+inf otherwise) and the host takes `min(dvq, walk)`, so the
  no-improvement case compares the *host-computed* delta(v, q) against the
  occlusion reference -- the exact-equality case (beta=1, "alg2") cannot
  flip on an XLA-vs-numpy ulp;
- delta(u, q) ordering and the occlusion reference reuse the host's
  `_sqd` values verbatim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bamg import BAMGGraph, _sqd, build_bamg_from


@jax.jit
def _probe_hop(x, intra_adj, cur, best, q_ids):
    """One monotone intra-block hop for a flat chunk of walks.

    x (N, D) f32; intra_adj (N, R') int32 intra-block neighbors, -1 pad;
    cur (P,) int32 walk positions; best (P,) f32 running minima; q_ids
    (P,) int32 walk targets.  Returns (cur', best', improved (P,) bool) --
    the host's running-minimum hop: move to the first argmin neighbor iff
    it strictly improves, else stop.
    """
    p = cur.shape[0]
    qv = x[q_ids].astype(jnp.float32)                       # (P, D)
    nbrs = intra_adj[cur]                                   # (P, R')
    diff = x[jnp.clip(nbrs, 0)].astype(jnp.float32) - qv[:, None, :]
    dw = jnp.sum(diff * diff, axis=-1)                      # (P, R')
    dw = jnp.where(nbrs >= 0, dw, jnp.inf)
    mn = jnp.min(dw, axis=1)
    amn = jnp.argmin(dw, axis=1)                            # first argmin
    improved = mn < best
    cur = jnp.where(improved, nbrs[jnp.arange(p), amn], cur)
    best = jnp.where(improved, mn, best)
    return cur, best, improved


def intra_adjacency(adj: np.ndarray, blocks: np.ndarray) -> np.ndarray:
    """(n, R') adjacency restricted to same-block neighbors, -1 pad, row
    order preserved (the walk's argmin tie-break needs host order)."""
    n, r = adj.shape
    valid = adj >= 0
    same = np.zeros_like(valid)
    same[valid] = blocks[adj[valid]] == np.repeat(blocks, valid.sum(1))
    width = max(1, int(same.sum(1).max()))
    out = -np.ones((n, width), np.int32)
    for u in range(n):
        row = adj[u][same[u]]
        out[u, : len(row)] = row
    return out


class _ProbeEngine:
    """Flat (v, q) pair probes, chunked + compacted between hops."""

    def __init__(self, x, intra_adj, alpha: int, pair_chunk: int):
        self.x = jnp.asarray(x, jnp.float32)
        self.adj = jnp.asarray(intra_adj, jnp.int32)
        self.alpha = alpha
        self.chunk = pair_chunk

    def _hop(self, cur, best, q_ids):
        """Chunked single hop over flat pair arrays (numpy in/out)."""
        m = len(cur)
        out_c = np.empty(m, np.int32)
        out_b = np.empty(m, np.float32)
        out_i = np.empty(m, bool)
        for s in range(0, m, self.chunk):
            c = cur[s : s + self.chunk]
            bt = best[s : s + self.chunk]
            q = q_ids[s : s + self.chunk]
            pad = self.chunk - len(c)
            if pad:
                c = np.concatenate([c, np.zeros(pad, c.dtype)])
                bt = np.concatenate([bt, np.full(pad, -np.inf, bt.dtype)])
                q = np.concatenate([q, np.zeros(pad, q.dtype)])
            nc, nb, ni = _probe_hop(self.x, self.adj, jnp.asarray(c),
                                    jnp.asarray(bt), jnp.asarray(q))
            e = s + self.chunk - pad
            out_c[s:e] = np.asarray(nc)[: e - s]
            out_b[s:e] = np.asarray(nb)[: e - s]
            out_i[s:e] = np.asarray(ni)[: e - s]
        return out_c, out_b, out_i

    def __call__(self, v_ids: np.ndarray, q_ids: np.ndarray,
                 d0: np.ndarray) -> np.ndarray:
        """Walk minima for pairs (v, q); d0 = delta(v, q) seeds the running
        minimum.  Returns +inf where no hop improved (the host then falls
        back to its own delta(v, q))."""
        m = len(v_ids)
        walk = np.full(m, np.inf, np.float32)
        cur = np.asarray(v_ids, np.int32)
        best = np.asarray(d0, np.float32)
        q_ids = np.asarray(q_ids, np.int32)
        alive = np.arange(m)
        for _ in range(self.alpha):
            if not len(alive):
                break
            nc, nb, ni = self._hop(cur, best, q_ids[alive])
            walk[alive[ni]] = nb[ni]
            alive = alive[ni]
            cur, best = nc[ni], nb[ni]
        return walk


def refine_bamg_batched(
    x: np.ndarray,
    nsg_adj: np.ndarray,
    entry: int,
    blocks: np.ndarray,
    capacity: int,
    alpha: int = 3,
    beta: float = 1.0,
    occlusion_ref: str = "rule",
    sibling_edges: bool = True,
    max_degree: int | None = None,
    pair_chunk: int = 4096,
) -> BAMGGraph:
    """Algorithm 2 with batched probes; bit-identical to `build_bamg_from`
    by construction -- the scan IS `build_bamg_from`, handed a probe that
    looks up device-precomputed walk minima instead of walking in Python.
    """
    n = len(x)
    blocks = np.asarray(blocks)
    adj_lists = [row[row >= 0].astype(np.int64) for row in nsg_adj]
    cross = [[v for v in adj_lists[u].tolist() if blocks[v] != blocks[u]]
             for u in range(n)]

    # every *ordered* pair (v strictly before q in the host's stable
    # ascending-delta(u, .) scan order -- the only pairs its occlusion
    # loop can check), flattened across all nodes
    pv, pq, pd, owner = [], [], [], []
    for u in range(n):
        cu = cross[u]
        if not cu:
            continue
        dq = np.array([_sqd(x, u, x[v]) for v in cu])
        srt = np.argsort(dq, kind="stable").tolist()
        for i, oi in enumerate(srt):
            for oj in srt[i + 1 :]:
                v, q = cu[oi], cu[oj]
                if v == q:
                    continue
                dvv = x[q] - x[v]
                pv.append(v)
                pq.append(q)
                pd.append(float(np.dot(dvv, dvv)))
                owner.append(u)

    engine = _ProbeEngine(x, intra_adjacency(nsg_adj, blocks), alpha,
                          pair_chunk)
    walk = engine(np.asarray(pv, np.int64), np.asarray(pq, np.int64),
                  np.asarray(pd, np.float32))
    tables: dict[int, dict[tuple[int, int], float]] = {}
    for v, q, u, w in zip(pv, pq, owner, walk.tolist()):
        tables.setdefault(u, {})[(v, q)] = w

    def probe(u, v, q, q_vec, dvq):
        # +inf when no hop improved: the comparison then uses the host's
        # own delta(v, q), keeping exact-equality semantics (beta=1/alg2)
        return min(dvq, tables.get(u, {}).get((v, q), np.inf))

    return build_bamg_from(x, nsg_adj, entry, blocks, capacity,
                           alpha=alpha, beta=beta,
                           occlusion_ref=occlusion_ref,
                           sibling_edges=sibling_edges,
                           max_degree=max_degree, probe=probe)
