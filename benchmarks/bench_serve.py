"""Serving throughput: batched fixed-shape engine vs the host query loop.

Rows: host-engine wall-clock qps, then the batched engine's qps at batch
sizes {1, 8, 64, 256} (same index, same search budget l), plus recall of
both so the speedup is apples-to-apples.  The acceptance bar for the
serving layer is batched-qps(B=64) > host-qps.
"""
import time

import numpy as np

from . import common
from repro.core.distances import recall_at_k
from repro.serve import BatchedANNEngine, EngineConfig

K = 10
L = 48
BATCHES = (1, 8, 64, 256)


def run() -> None:
    regime = "sift-like"
    ds = common.dataset(regime)
    idx = common.default_bamg(regime)

    t0 = time.perf_counter()
    st = idx.search_batch(ds.queries, k=K, l=L, gt=ds.gt)
    host_s = time.perf_counter() - t0
    host_qps = len(ds.queries) / host_s
    common.emit("serve.host_loop.qps", round(host_qps, 1),
                f"recall={st.recall:.3f}")

    eng = BatchedANNEngine.from_index(idx, EngineConfig(l=L, max_hops=32))
    ids, _ = eng.search_batch(ds.queries, K)
    common.emit("serve.batched.recall", round(recall_at_k(ids, ds.gt, K), 3),
                f"l={L}")

    nq = len(ds.queries)
    for b in BATCHES:
        q = np.tile(ds.queries, (-(-b // nq), 1))[:b]
        eng.search_batch(q, K)                       # compile + warm
        reps = max(1, 256 // b)
        t0 = time.perf_counter()
        for _ in range(reps):
            eng.search_batch(q, K)
        dt = time.perf_counter() - t0
        qps = b * reps / dt
        common.emit(f"serve.batched.b{b}.qps", round(qps, 1),
                    f"speedup_vs_host={qps / host_qps:.2f}x")


if __name__ == "__main__":
    run()
