"""End-to-end index behaviour: recall, NIO accounting, persistence (§4-5)."""
import os

import numpy as np
import pytest

from repro.core.engine import (BAMGIndex, BAMGParams, DiskANNIndex,
                               DiskANNParams, StarlingIndex, StarlingParams)


@pytest.fixture(scope="module")
def indexes(small_corpus):
    ds = small_corpus
    bamg = BAMGIndex.build(ds.base, BAMGParams(alpha=3, beta=1.05, r=16,
                                               l_build=32, knn_k=16))
    return ds, bamg


def test_bamg_recall_and_io_accounting(indexes):
    ds, idx = indexes
    st = idx.search_batch(ds.queries, k=10, l=48, gt=ds.gt)
    assert st.recall >= 0.9, st
    assert st.mean_graph_reads > 0 and st.mean_vector_reads > 0
    assert st.mean_nio == pytest.approx(
        st.mean_graph_reads + st.mean_vector_reads)


def test_bamg_recall_improves_with_l(indexes):
    ds, idx = indexes
    lo = idx.search_batch(ds.queries, k=10, l=12, gt=ds.gt)
    hi = idx.search_batch(ds.queries, k=10, l=64, gt=ds.gt)
    assert hi.recall >= lo.recall
    assert hi.mean_nio >= lo.mean_nio


def test_early_stop_rerank_cuts_vector_reads(indexes):
    ds, idx = indexes
    base = idx.search_batch(ds.queries, k=10, l=64, gt=ds.gt)
    es = idx.search_batch(ds.queries, k=10, l=64, gt=ds.gt,
                          rerank_margin=1.3)
    assert es.mean_vector_reads <= base.mean_vector_reads
    assert es.recall >= base.recall - 0.1


def test_nav_graph_beats_random_entry(indexes):
    ds, idx = indexes
    nav = idx.search_batch(ds.queries, k=10, l=24, gt=ds.gt)
    rnd = idx.search_batch(ds.queries, k=10, l=24, gt=ds.gt,
                           random_entry=True)
    # ablation "BAMG w/o NG": random entries can't do better on hops
    assert nav.mean_hops <= rnd.mean_hops + 2


def test_ablation_no_bmrng_prune_denser_graph(small_corpus):
    ds = small_corpus
    pruned = BAMGIndex.build(ds.base, BAMGParams(r=16, l_build=32, knn_k=16,
                                                 use_bmrng_prune=True))
    dense = BAMGIndex.build(ds.base, BAMGParams(r=16, l_build=32, knn_k=16,
                                                use_bmrng_prune=False))
    assert (pruned.degree_stats()["total"]
            <= dense.degree_stats()["total"] + 1e-9)
    st = dense.search_batch(ds.queries, k=10, l=48, gt=ds.gt)
    assert st.recall > 0.85


def test_baselines_recall(small_corpus):
    ds = small_corpus
    da = DiskANNIndex.build(ds.base, DiskANNParams(r=16, l_build=32))
    sl = StarlingIndex.build(ds.base, StarlingParams(r=16, l_build=32))
    for idx in (da, sl):
        st = idx.search_batch(ds.queries, k=10, l=48, gt=ds.gt)
        assert st.recall >= 0.9, type(idx).__name__
    # Starling block-level search reads fewer blocks than DiskANN
    s_st = sl.search_batch(ds.queries, k=10, l=48, gt=ds.gt)
    d_st = da.search_batch(ds.queries, k=10, l=48, gt=ds.gt)
    assert s_st.mean_nio <= d_st.mean_nio


def test_bamg_fewer_graph_reads_than_starling_total(small_corpus):
    """The structural claim: decoupling multiplies nodes/block, so BAMG
    needs fewer *graph* I/Os than Starling needs total I/Os."""
    ds = small_corpus
    bamg = BAMGIndex.build(ds.base, BAMGParams(r=16, l_build=32, knn_k=16))
    sl = StarlingIndex.build(ds.base, StarlingParams(r=16, l_build=32))
    b = bamg.search_batch(ds.queries, k=10, l=48, gt=ds.gt)
    s = sl.search_batch(ds.queries, k=10, l=48, gt=ds.gt)
    assert b.mean_graph_reads < s.mean_nio


def test_save_load_roundtrip(indexes, tmp_path):
    ds, idx = indexes
    path = os.path.join(tmp_path, "idx.npz")
    idx.save(path)
    idx2 = BAMGIndex.load(path)
    r1 = idx.search(ds.queries[0], k=5, l=24)
    r2 = idx2.search(ds.queries[0], k=5, l=24)
    np.testing.assert_array_equal(r1.ids, r2.ids)
    assert r1.nio == r2.nio


def test_alpha_controls_intra_block_depth(indexes):
    ds, idx = indexes
    a1 = idx.search_batch(ds.queries, k=10, l=32, gt=ds.gt, alpha=1)
    a4 = idx.search_batch(ds.queries, k=10, l=32, gt=ds.gt, alpha=4)
    # deeper intra-block exploration never increases graph reads per hop
    assert a4.mean_graph_reads <= a1.mean_graph_reads + 3
