"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 200 \
      --reduced --batch 8 --seq 128

Runs the real train step (same code path as the dry-run cells) on whatever
devices exist, with checkpoint/restart (--ckpt-dir), deterministic
step-indexed data, and metrics logging.  --reduced uses the arch's smoke
config (CPU-sized); full configs need TPUs.
"""
import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from ..configs.registry import get_arch
    from ..data.synthetic import lm_batch, din_batch, random_graph
    from ..models.transformer import LMConfig, ShardCtx, init_lm_params, lm_loss
    from ..train.optimizer import AdamWConfig
    from ..train.trainer import make_train_step, init_train_state
    from ..train import checkpoint as ckpt

    mod = get_arch(args.arch)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
                          total_steps=args.steps)
    ctx = ShardCtx(mesh=None)

    if mod.FAMILY == "lm":
        cfg = mod.model_config(reduced=args.reduced)

        def loss_fn(params, batch):
            return lm_loss(params, cfg, batch["tokens"], batch["labels"], ctx)

        def batch_fn(step):
            t, l = lm_batch(step, args.batch, args.seq, cfg.vocab,
                            seed=args.seed)
            return {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}

        params = init_lm_params(cfg, jax.random.PRNGKey(args.seed))
    elif mod.FAMILY == "recsys":
        from ..models.recsys import din as m
        cfg = mod.model_config(reduced=args.reduced)

        def loss_fn(params, batch):
            return m.loss_fn(params, cfg, batch), {}

        def batch_fn(step):
            hi, hc, hl, ti, tc, y = din_batch(step, args.batch, cfg.seq_len,
                                              cfg.n_items, cfg.n_cates,
                                              seed=args.seed)
            return {k: jnp.asarray(v) for k, v in
                    zip(("hist_items", "hist_cates", "hist_len",
                         "target_item", "target_cate", "label"),
                        (hi, hc, hl, ti, tc, y))}

        params = m.init_params(cfg, jax.random.PRNGKey(args.seed))
    else:  # gnn
        cfg = mod.model_config(reduced=args.reduced)
        from . import cells as cell_mod  # reuse loss plumbing conventions
        from ..models.gnn import graphcast as gc
        if args.arch != "graphcast":
            raise SystemExit("gnn trainer demo supports graphcast; "
                             "see tests/test_arch_smoke.py for the others")
        g = random_graph(256, 2048, d_feat=cfg.d_feat, seed=args.seed)
        targets = np.random.default_rng(1).normal(
            size=(256, cfg.n_vars)).astype(np.float32)

        def loss_fn(params, batch):
            pred = gc.forward(params, cfg, batch)
            return jnp.mean((pred.astype(jnp.float32) - batch["targets"]) ** 2), {}

        def batch_fn(step):
            return {"node_feat": jnp.asarray(g.node_feat),
                    "edge_src": jnp.asarray(g.edge_src),
                    "edge_dst": jnp.asarray(g.edge_dst),
                    "edge_feat": jnp.asarray(g.edge_feat),
                    "targets": jnp.asarray(targets)}

        params = gc.init_params(cfg, jax.random.PRNGKey(args.seed))

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={args.arch} reduced={args.reduced} params={n_params:,}")

    state = init_train_state(params, opt_cfg)
    step_fn = make_train_step(loss_fn, opt_cfg, donate=False)

    start = 0
    if args.ckpt_dir:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            host, start = ckpt.restore(args.ckpt_dir, state)
            state = jax.tree.map(jnp.asarray, host)
            print(f"restored step {start} from {args.ckpt_dir}")

    t_start = time.time()
    for s in range(start, args.steps):
        state, metrics = step_fn(state, batch_fn(s))
        if (s + 1) % args.log_every == 0:
            dt = (time.time() - t_start) / (s + 1 - start)
            print(f"step {s+1:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt*1e3:.0f} ms/step)",
                  flush=True)
        if args.ckpt_dir and (s + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, s + 1, state)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state)
    print("done")


if __name__ == "__main__":
    main()
