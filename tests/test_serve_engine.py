"""Batched serving engine vs the host BAMG engine (parity + shapes).

The batched engine explores the same monotonic graph with the same PQ
estimates; under an exhaustive configuration (pool holds the whole corpus,
hop budget covers it, full exact re-rank) it must return the *identical*
top-k ids as brute force -- and so must `BAMGIndex.search` with l=n.  At
practical settings the two engines only need to agree on recall within a
small tolerance.
"""
import numpy as np
import pytest

from repro.core.distances import exact_knn, recall_at_k
from repro.core.engine import BAMGIndex, BAMGParams
from repro.serve import BatchedANNEngine, EngineConfig, ShardedFrontend

K = 10


@pytest.fixture(scope="module")
def built(small_corpus):
    idx = BAMGIndex.build(small_corpus.base,
                          BAMGParams(alpha=3, beta=1.05, r=16, l_build=32,
                                     knn_k=16, seed=0))
    return small_corpus, idx


def test_exhaustive_rerank_identical_topk(built):
    """l = n, hops = n, full re-rank: batched ids == host ids == brute force."""
    ds, idx = built
    n = len(ds.base)
    eng = BatchedANNEngine.from_index(idx, EngineConfig(l=n, max_hops=n))
    ids, dists = eng.search_batch(ds.queries, K)
    gd, gi = exact_knn(ds.base, ds.queries, K)
    np.testing.assert_array_equal(ids, gi)
    np.testing.assert_allclose(dists, gd, rtol=1e-4, atol=1e-3)
    for qi, q in enumerate(ds.queries):
        r = idx.search(q, k=K, l=n)
        np.testing.assert_array_equal(ids[qi], r.ids)


def test_practical_settings_recall_parity(built):
    ds, idx = built
    eng = BatchedANNEngine.from_index(idx, EngineConfig(l=48, max_hops=32))
    ids, dists = eng.search_batch(ds.queries, K)
    assert ids.shape == (len(ds.queries), K)
    assert (np.diff(dists, axis=1) >= 0).all()        # ascending
    host = idx.search_batch(ds.queries, k=K, l=48, gt=ds.gt)
    assert recall_at_k(ids, ds.gt, K) >= host.recall - 0.05


def test_single_query_batch(built):
    ds, idx = built
    eng = BatchedANNEngine.from_index(idx, EngineConfig(l=32, max_hops=24))
    ids, dists = eng.search_batch(ds.queries[0], K)   # 1-D query promoted
    assert ids.shape == (1, K)
    assert np.isfinite(dists).all() and (ids >= 0).all()


def test_pool_capacity_exceeding_corpus_is_clamped(built):
    ds, idx = built
    n = len(ds.base)
    eng = BatchedANNEngine.from_index(idx, EngineConfig(l=10 * n, max_hops=8))
    ids, _ = eng.search_batch(ds.queries[:2], K)
    assert ids.shape == (2, K)


def test_max_hops_plumbed_through_host_engine(built):
    """BAMGIndex.search(max_hops=...) bounds the walk (satellite check)."""
    ds, idx = built
    r1 = idx.search(ds.queries[0], k=K, l=48, max_hops=1)
    rfull = idx.search(ds.queries[0], k=K, l=48)
    assert r1.hops == 1
    assert rfull.hops >= r1.hops


def test_frontend_shard_smaller_than_k(built):
    """A shard with fewer points than k contributes what it has; the global
    merge still returns k valid ids from the other shards."""
    ds, _ = built
    n = len(ds.base)
    # 8 shards of a 75-point prefix -> ~9 points per shard, k=10 > shard size
    small = ds.base[:75]
    fe = ShardedFrontend.build(
        small, n_shards=8,
        params=BAMGParams(alpha=3, beta=1.05, r=8, l_build=16, knn_k=8),
        config=EngineConfig(l=75, max_hops=75))
    ids, dists = fe.search_batch(ds.queries, K)
    assert ids.shape == (len(ds.queries), K)
    assert (ids >= 0).all() and np.isfinite(dists).all()
    _, gi = exact_knn(small, ds.queries, K)
    np.testing.assert_array_equal(ids, gi)


def test_sharded_frontend_matches_global_brute_force(built):
    """2-shard scatter-gather at exhaustive budget == global brute force."""
    ds, _ = built
    n = len(ds.base)
    fe = ShardedFrontend.build(
        ds.base, n_shards=2,
        params=BAMGParams(alpha=3, beta=1.05, r=16, l_build=32, knn_k=16),
        config=EngineConfig(l=n, max_hops=n))
    ids, dists = fe.search_batch(ds.queries, K)
    _, gi = exact_knn(ds.base, ds.queries, K)
    np.testing.assert_array_equal(ids, gi)
    assert (np.diff(dists, axis=1) >= 0).all()
