"""Public wrapper: seq padding (masked via cache_len) + backend switch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_decode_pallas
from .ref import flash_decode_ref


@functools.partial(jax.jit, static_argnames=("ts", "scale", "backend"))
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 cache_len: jnp.ndarray, ts: int = 512,
                 scale: float | None = None, backend: str = "auto"):
    """Decode (single new token) GQA attention over a KV cache.

    q (B, H, Dh); k, v (B, S, Hkv, Dh); cache_len (B,) valid prefix lengths.
    Returns (B, H, Dh) float32.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return flash_decode_ref(q, k, v, cache_len, scale=scale).astype(jnp.float32)
    s = k.shape[1]
    pad = (-s) % ts
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return flash_decode_pallas(q, k, v, cache_len, ts=ts, scale=scale,
                               interpret=(backend == "interpret"))
