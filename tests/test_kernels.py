"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode, flash_decode_ref
from repro.kernels.l2_topk import l2_topk, l2_topk_ref
from repro.kernels.pq_adc import (pq_adc, pq_adc_ref, pq_adc_rowwise,
                                  pq_adc_rowwise_ref)

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("b,n,m,k", [
    (1, 256, 8, 16), (3, 700, 16, 256), (9, 1024, 4, 64), (2, 100, 32, 256),
])
def test_pq_adc_sweep(b, n, m, k):
    tables = jnp.asarray(RNG.random((b, m, k)), jnp.float32)
    codes = jnp.asarray(RNG.integers(0, k, (n, m)), jnp.uint8)
    ref = pq_adc_ref(tables, codes)
    out = pq_adc(tables, codes, backend="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,r,m,k", [
    (1, 8, 8, 16), (3, 33, 16, 256), (9, 64, 4, 64), (2, 5, 32, 256),
])
def test_pq_adc_rowwise_sweep(b, r, m, k):
    """Per-row codes (the serve hop's neighbor scoring): interpret vs ref."""
    tables = jnp.asarray(RNG.random((b, m, k)), jnp.float32)
    codes = jnp.asarray(RNG.integers(0, k, (b, r, m)), jnp.int32)
    ref = pq_adc_rowwise_ref(tables, codes)
    out = pq_adc_rowwise(tables, codes, backend="interpret")
    assert out.shape == (b, r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_pq_adc_matches_host_codec():
    from repro.core.pq import train_pq
    x = RNG.normal(size=(500, 32)).astype(np.float32)
    codec = train_pq(x, m=8, k=32, iters=4)
    codes = codec.encode(x)
    q = RNG.normal(size=(2, 32)).astype(np.float32)
    tables = codec.adc_tables(q)
    ref = np.stack([codec.estimate(tables[i], codes) for i in range(2)])
    out = pq_adc(jnp.asarray(tables), jnp.asarray(codes), backend="interpret")
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,n,d,k", [
    (1, 1000, 16, 10), (5, 512, 128, 4), (8, 2000, 24, 32), (2, 300, 960, 8),
])
def test_l2_topk_sweep(b, n, d, k):
    q = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    rv, ri = l2_topk_ref(q, x, k)
    v, i = l2_topk(q, x, k, backend="interpret")
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv),
                               rtol=1e-3, atol=1e-3)
    # indices may permute within distance ties; compare distance multisets
    assert (np.asarray(i) == np.asarray(ri)).mean() > 0.95


def test_l2_topk_bf16_inputs():
    q = jnp.asarray(RNG.normal(size=(2, 64)), jnp.bfloat16)
    x = jnp.asarray(RNG.normal(size=(600, 64)), jnp.bfloat16)
    v, i = l2_topk(q, x, 5, backend="interpret")
    rv, ri = l2_topk_ref(q, x, 5)
    assert (np.asarray(i) == np.asarray(ri)).mean() > 0.9


def test_l2_topk_n_smaller_than_k():
    q = jnp.asarray(RNG.normal(size=(1, 8)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(3, 8)), jnp.float32)
    v, i = l2_topk(q, x, 5, backend="interpret")
    assert np.isinf(np.asarray(v)[0, 3:]).all()
    assert (np.asarray(i)[0, 3:] == -1).all()


@pytest.mark.parametrize("b,h,hkv,dh,s,ts", [
    (2, 8, 4, 64, 300, 128), (1, 4, 1, 32, 512, 512), (3, 16, 16, 64, 200, 64),
    (2, 8, 2, 128, 1000, 256),
])
def test_flash_decode_sweep(b, h, hkv, dh, s, ts):
    q = jnp.asarray(RNG.normal(size=(b, h, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, dh)), jnp.float32)
    lens = jnp.asarray(RNG.integers(1, s + 1, b), jnp.int32)
    ref = flash_decode_ref(q, k, v, lens)
    out = flash_decode(q, k, v, lens, ts=ts, backend="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_flash_decode_bf16_cache():
    b, h, hkv, dh, s = 2, 8, 4, 64, 256
    q = jnp.asarray(RNG.normal(size=(b, h, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, dh)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, dh)), jnp.bfloat16)
    lens = jnp.full((b,), s, jnp.int32)
    ref = flash_decode_ref(q, k.astype(jnp.float32), v.astype(jnp.float32), lens)
    out = flash_decode(q, k, v, lens, ts=128, backend="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_causal_attention_vs_naive():
    """The chunked flash-style prefill path vs a naive masked softmax."""
    from repro.models.attention import causal_attention
    b, s, h, hkv, dh = 2, 64, 4, 2, 16
    q = jnp.asarray(RNG.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, dh)), jnp.float32)

    def naive(q, k, v, window=None):
        g = h // hkv
        kk = jnp.repeat(k, g, axis=2)
        vv = jnp.repeat(v, g, axis=2)
        s_ = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * dh ** -0.5
        i = jnp.arange(q.shape[1])[:, None]
        j = jnp.arange(k.shape[1])[None, :]
        ok = j <= i
        if window is not None:
            ok &= (i - j) < window
        s_ = jnp.where(ok[None, None], s_, -jnp.inf)
        w = jax.nn.softmax(s_, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w, vv)

    for window in (None, 16):
        out = causal_attention(q, k, v, window=window, chunk_q=16, chunk_kv=32)
        ref = naive(q, k, v, window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
