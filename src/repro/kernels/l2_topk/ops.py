"""Public wrapper: padding (base padded rows get +inf distance) + backend."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import l2_topk_pallas
from .ref import l2_topk_ref


@functools.partial(jax.jit, static_argnames=("k", "tile_b", "tile_n", "backend"))
def l2_topk(queries: jnp.ndarray, base: jnp.ndarray, k: int,
            tile_b: int = 8, tile_n: int = 512, backend: str = "auto"):
    """Exact k smallest squared-L2 distances of each query against `base`.

    returns (dists (B, k) ascending, ids (B, k)); padded/absent entries get
    dist=+inf, id=-1.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return l2_topk_ref(queries, base, k)
    b, d = queries.shape
    n = base.shape[0]
    pb = (-b) % tile_b
    pn = (-n) % tile_n
    q = jnp.pad(queries, ((0, pb), (0, 0)))
    # pad base with a huge-norm sentinel so padded rows never enter top-k
    # 1e17 keeps ||x||^2 ~ 1e34*d finite in f32 while dominating any real row
    x = jnp.pad(base, ((0, pn), (0, 0)), constant_values=1e17)
    vals, ids = l2_topk_pallas(q, x, k, tile_b=tile_b, tile_n=tile_n,
                               interpret=(backend == "interpret"))
    vals = jnp.where(ids >= n, jnp.inf, vals)
    ids = jnp.where(ids >= n, -1, ids)
    return vals[:b], ids[:b]


@jax.jit
def sq_l2_rowwise(queries: jnp.ndarray, bases: jnp.ndarray,
                  valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Per-row exact squared L2: queries (B, D) vs bases (B, C, D) -> (B, C).

    The scoring core of `l2_topk_rowwise` without the top-k selection --
    used where the caller keeps its own pool (the batched build frontier
    merges all C scores, not just the best k).  Invalid entries get +inf.
    """
    diff = bases.astype(jnp.float32) - queries.astype(jnp.float32)[:, None, :]
    d = jnp.sum(diff * diff, axis=-1)
    if valid is not None:
        d = jnp.where(valid, d, jnp.inf)
    return d


@functools.partial(jax.jit, static_argnames=("k",))
def l2_topk_rowwise(queries: jnp.ndarray, bases: jnp.ndarray, k: int,
                    valid: jnp.ndarray | None = None):
    """Per-row exact re-rank: each query against its *own* candidate set.

    queries (B, D); bases (B, C, D); valid (B, C) bool or None.
    Returns (dists (B, k) ascending, idx (B, k)) where idx indexes into C
    (not a shared corpus -- map back through your candidate id array).
    Invalid / absent entries get dist=+inf.  Used by the batched serving
    engine, where every query reranks the raw vectors of its private pool
    (the shared-base Pallas kernel above cannot express per-row bases).
    """
    d = sq_l2_rowwise(queries, bases, valid)               # (B, C)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx
