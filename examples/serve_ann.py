"""Batched ANN serving: scatter-gather over sharded BAMG sub-indexes.

    PYTHONPATH=src python examples/serve_ann.py

The distributed serving pattern of DESIGN.md §4: the corpus is partitioned
into S sub-corpora (one per model-parallel shard at scale); each shard
builds its own BAMG sub-index independently (elastic: add/remove shards =
rebuild only the moved partitions); a query batch fans out as ONE batched
`repro.serve.ann_engine` call per shard and the per-shard top-k merge to a
global top-k in a single pass -- the TPU analogue of the paper's "every
I/O pays for itself", with per-query Python overhead amortized over the
whole batch.  The old per-query host loop is kept as the baseline.

Since the runtime refactor the fan-out is a *compiled instruction stream*
(SCATTER / RUN / GATHER / MERGE) interpreted over a placed shard fleet;
the tail of this demo prints the program and drives the continuous-
batching scheduler over an open-loop arrival timeline (p50/p99 vs SLO).
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.distances import recall_at_k  # noqa: E402
from repro.core.engine import BAMGParams  # noqa: E402
from repro.data.synthetic import make_vector_dataset  # noqa: E402
from repro.serve import (EngineConfig, Scheduler,  # noqa: E402
                         SchedulerConfig, ShardedFrontend, make_requests,
                         summarize)


def main() -> None:
    n_shards = 4
    k = 10
    ds = make_vector_dataset("serve", n=4000, d=64, nq=32, k_gt=10, seed=0)
    params = BAMGParams(alpha=3, beta=1.05, r=16, l_build=32, knn_k=16)

    t0 = time.time()
    frontend = ShardedFrontend.build(ds.base, n_shards, params=params,
                                     config=EngineConfig(l=24, max_hops=24))
    print(f"{n_shards} BAMG sub-indexes built in {time.time()-t0:.0f}s "
          f"(independent -> elastic scale-out)")

    # --- batched path: one engine call per shard, one global merge ---------
    frontend.search_batch(ds.queries, k=k)        # compile + warm
    t0 = time.time()
    ids, _ = frontend.search_batch(ds.queries, k=k)
    batched_s = time.time() - t0
    n_q = len(ds.queries)
    print(f"batched: recall@{k}={recall_at_k(ids, ds.gt, k):.3f}, "
          f"{batched_s/n_q*1e3:.2f} ms/query "
          f"({n_q/batched_s:.0f} qps, one call per shard per batch)")

    # --- host baseline: per-query per-shard Python loop ---------------------
    tops = []
    nio = 0
    t0 = time.time()
    for q in ds.queries:
        cand_ids, cand_d = [], []
        for vids, idx in zip(frontend.shard_vids, frontend.host_indexes):
            r = idx.search(q, k=k, l=24)
            cand_ids.append(vids[r.ids])
            cand_d.append(r.dists)
            nio += r.nio
        all_ids = np.concatenate(cand_ids)
        all_d = np.concatenate(cand_d)
        tops.append(all_ids[np.argsort(all_d)[:k]])
    host_s = time.time() - t0
    print(f"host loop: recall@{k}={recall_at_k(np.stack(tops), ds.gt, k):.3f}, "
          f"NIO/query (summed over shards)={nio/n_q:.1f}, "
          f"{host_s/n_q*1e3:.1f} ms/query -> batched speedup "
          f"{host_s/batched_s:.1f}x")

    # --- the runtime underneath: compiled program + request scheduler ------
    rt = frontend.runtime
    prog = " ".join(f"{ins.op.name}({ins.shard})" if ins.shard >= 0
                    else ins.op.name for ins in rt.program)
    print(f"\ncompiled serving program ({rt.n_shards} shards, "
          f"{rt.health()['n_workers']} worker(s)): {prog}")

    slo = 0.5
    sched = Scheduler(rt, SchedulerConfig(k=k, max_batch=16, slo=slo))
    reqs = make_requests(ds.queries, qps=100.0, slo=slo, n=96, seed=0)
    s = summarize(sched.run(reqs))
    print(f"scheduler @100 qps offered, SLO={slo*1e3:.0f}ms: "
          f"p50={s['p50_ms']:.1f}ms p99={s['p99_ms']:.1f}ms "
          f"deadline_hit={s['deadline_hit']:.2f} "
          f"shrunk_frac={s['shrunk_frac']:.2f} "
          f"({s['achieved_qps']:.0f} qps achieved)")


if __name__ == "__main__":
    main()
