"""Distribution correctness: sharded paths vs single-device oracles.

These run in *subprocesses* so they can set
XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax initializes
(the main test session keeps the real single-device view).
"""
import os
import subprocess
import sys

import pytest

FLAGS = "--xla_force_host_platform_device_count=8"


def _run(snippet: str, timeout=900):
    env = dict(os.environ, XLA_FLAGS=FLAGS, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=timeout,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.utils.sharding import make_mesh_compat
from repro.models.transformer import (LMConfig, ShardCtx, init_lm_params,
    lm_loss, serve_prefill, decode_step, init_cache, lm_param_specs,
    cache_specs)
mesh = make_mesh_compat((2, 4), ("data", "model"))
ctx, ctx0 = ShardCtx(mesh=mesh), ShardCtx(mesh=None)
def put(tree, specs):
    return jax.tree.map(lambda x, s: jax.device_put(
        x, NamedSharding(mesh, s if s is not None else P())), tree, specs)
toks = jnp.asarray(np.random.default_rng(0).integers(0, 256, (4, 16)), jnp.int32)
labels = jnp.roll(toks, -1, axis=1)
td = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
"""


def test_dense_tp_loss_matches_unsharded():
    _run(PRELUDE + """
cfg = LMConfig(name="tp", n_layers=2, d_model=64, n_heads=8, n_kv_heads=4,
               d_head=16, d_ff=128, vocab=256, remat="none", loss_chunks=2,
               dtype="float32")
params = init_lm_params(cfg, jax.random.PRNGKey(0))
ps = put(params, lm_param_specs(cfg, ctx))
ls, _ = jax.jit(lambda p, t, l: lm_loss(p, cfg, t, l, ctx))(ps, td, labels)
lr, _ = jax.jit(lambda p, t, l: lm_loss(p, cfg, t, l, ctx0))(params, toks, labels)
np.testing.assert_allclose(float(ls), float(lr), rtol=2e-5)
print("dense TP ok")
""")


def test_fsdp_specs_loss_matches():
    _run(PRELUDE + """
cfg = LMConfig(name="f", n_layers=2, d_model=64, n_heads=8, n_kv_heads=4,
               d_head=16, d_ff=128, vocab=256, remat="full", loss_chunks=2,
               dtype="float32")
params = init_lm_params(cfg, jax.random.PRNGKey(0))
ps = put(params, lm_param_specs(cfg, ctx, fsdp_axis="data"))
ls, _ = jax.jit(lambda p, t, l: lm_loss(p, cfg, t, l, ctx))(ps, td, labels)
lr, _ = jax.jit(lambda p, t, l: lm_loss(p, cfg, t, l, ctx0))(params, toks, labels)
np.testing.assert_allclose(float(ls), float(lr), rtol=2e-5)
print("fsdp ok")
""")


def test_moe_shard_map_matches_local_oracle():
    _run(PRELUDE + """
from repro.models.moe import MoEConfig
mcfg = LMConfig(name="m", n_layers=2, d_model=64, n_heads=8, n_kv_heads=4,
                d_head=16, d_ff=0, vocab=256, remat="none", loss_chunks=2,
                dtype="float32",
                moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                              n_shared=1, d_ff_shared=64, pad_multiple=4,
                              capacity_factor=8.0,
                              expert_capacity_factor=8.0, groups=2))
mp = init_lm_params(mcfg, jax.random.PRNGKey(1))
mps = put(mp, lm_param_specs(mcfg, ctx))
ls, _ = jax.jit(lambda p, t, l: lm_loss(p, mcfg, t, l, ctx))(mps, td, labels)
lr, _ = jax.jit(lambda p, t, l: lm_loss(p, mcfg, t, l, ctx0))(mp, toks, labels)
np.testing.assert_allclose(float(ls), float(lr), rtol=2e-5)
g = jax.jit(jax.grad(lambda p: lm_loss(p, mcfg, td, labels, ctx)[0]))(mps)
gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
assert np.isfinite(gn) and gn > 0
print("moe ok")
""")


def test_seq_sharded_decode_matches_local():
    _run(PRELUDE + """
dcfg = LMConfig(name="d", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
                d_head=16, d_ff=128, vocab=256, remat="none", dtype="float32")
dp = init_lm_params(dcfg, jax.random.PRNGKey(2))
lg0, (ck, cv), lens = jax.jit(lambda p, t: serve_prefill(p, dcfg, t, ctx0))(dp, toks)
ck0, cv0, _ = init_cache(dcfg, 4, 32, dtype=jnp.float32)
ck0 = ck0.at[:, :, :16].set(ck); cv0 = cv0.at[:, :, :16].set(cv)
pos = jnp.asarray([16]*4, jnp.int32)
ref, _ = jax.jit(lambda p, t, q, c: decode_step(p, dcfg, t, q, c, ctx0, "local"))(
    dp, toks[:, :1], pos, (ck0, cv0, lens))
dps = put(dp, lm_param_specs(dcfg, ctx))
for mode in ("seq", "seq_all"):
    cs_k, cs_v, cs_l = cache_specs(dcfg, ctx, mode)
    cc = (jax.device_put(ck0, NamedSharding(mesh, cs_k)),
          jax.device_put(cv0, NamedSharding(mesh, cs_v)),
          jax.device_put(lens, NamedSharding(mesh, cs_l)))
    lg, nc = jax.jit(lambda p, t, q, c: decode_step(p, dcfg, t, q, c, ctx, mode))(
        dps, toks[:, :1], pos, cc)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)
    assert int(nc[2][0]) == 17
print("decode ok")
""")


def test_manual_dp_compressed_convergence():
    _run(PRELUDE + """
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import make_manual_dp_step, make_train_step, init_train_state
from repro.data.synthetic import lm_batch
mesh1 = make_mesh_compat((8,), ("data",))
cfg = LMConfig(name="c", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
               d_head=8, d_ff=64, vocab=64, remat="none", loss_chunks=2,
               dtype="float32")
ocfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=100)
ctx_n = ShardCtx(mesh=None)
def loss_fn(p, b):
    return lm_loss(p, cfg, b["tokens"], b["labels"], ctx_n)
def bf(s):
    t, l = lm_batch(s, 16, 8, cfg.vocab, seed=0)
    return {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
params = init_lm_params(cfg, jax.random.PRNGKey(0))
ref_step = make_train_step(loss_fn, ocfg, donate=False)
st = init_train_state(params, ocfg)
for i in range(10):
    st, m_ref = ref_step(st, bf(i))
st8 = init_train_state(params, ocfg, ef=True)
dp_step = make_manual_dp_step(loss_fn, ocfg, mesh1, compression="int8_ef")
for i in range(10):
    st8, m_c = dp_step(st8, bf(i))
assert abs(float(m_ref["loss"]) - float(m_c["loss"])) < 0.05
print("manual dp ok")
""")


def test_sharded_embedding_lookup_matches():
    _run(PRELUDE + """
from repro.models.recsys.embedding import sharded_lookup
table = jnp.asarray(np.random.default_rng(3).normal(size=(64, 6)), jnp.float32)
ids = jnp.asarray(np.random.default_rng(4).integers(0, 64, (4, 5)), jnp.int32)
tput = jax.device_put(table, NamedSharding(mesh, P("model", None)))
out = jax.jit(lambda t, i: sharded_lookup(t, i, mesh, "model", ("data",)))(
    tput, jax.device_put(ids, NamedSharding(mesh, P("data", None))))
np.testing.assert_allclose(np.asarray(out), np.asarray(table)[np.asarray(ids)],
                           rtol=1e-6)
print("embedding ok")
""")
