"""olmo-1b [arXiv:2402.00838; hf]: 16L d=2048 16H (kv=16) ff=8192
vocab=50304, non-parametric LayerNorm, SwiGLU, untied head."""
from repro.models.transformer import LMConfig

from .base import LM_SHAPES

ARCH_ID = "olmo-1b"
FAMILY = "lm"
SHAPES = LM_SHAPES
TRAIN_ACCUM = 2  # microbatches for train_4k (memory lever)


def model_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(name=ARCH_ID + "-smoke", n_layers=2, d_model=128,
                        n_heads=4, n_kv_heads=4, d_head=32, d_ff=256,
                        vocab=512, norm="nonparam_ln", remat="none",
                        loss_chunks=2, dtype="float32")
    return LMConfig(
        name=ARCH_ID, n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_head=128, d_ff=8192, vocab=50304, norm="nonparam_ln",
        activation="silu", remat="full", loss_chunks=64)
