"""GNN substrate: segment message passing, SO(3) machinery, equivariance
property tests, sampler, triplets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import molecules_batch, random_graph
from repro.models.gnn.common import (bessel_rbf, degree, edge_vectors,
                                     scatter_to_nodes)
from repro.models.gnn.sampler import (csr_from_edges, expected_sizes,
                                      padded_sample, sample_subgraph)
from repro.models.gnn.so3 import (_random_rotations, allowed_paths, real_cg,
                                  real_sph_harm_np, wigner_d_real_np)


# --- segment ops ------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_scatter_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    n, e, d = 20, 60, 4
    src = rng.integers(-1, n, e).astype(np.int32)   # -1 = padding
    dst = rng.integers(0, n, e).astype(np.int32)
    msg = rng.normal(size=(e, d)).astype(np.float32)
    out = np.asarray(scatter_to_nodes(jnp.asarray(msg), jnp.asarray(dst),
                                      n, jnp.asarray(src >= 0)))
    ref = np.zeros((n, d), np.float32)
    for i in range(e):
        if src[i] >= 0:
            ref[dst[i]] += msg[i]
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_scatter_mean_and_max():
    msg = jnp.asarray([[1.0], [3.0], [5.0]])
    dst = jnp.asarray([0, 0, 1], jnp.int32)
    mask = jnp.asarray([True, True, True])
    mean = scatter_to_nodes(msg, dst, 2, mask, agg="mean")
    mx = scatter_to_nodes(msg, dst, 2, mask, agg="max")
    np.testing.assert_allclose(np.asarray(mean)[:, 0], [2.0, 5.0])
    np.testing.assert_allclose(np.asarray(mx)[:, 0], [3.0, 5.0])


def test_degree_counts():
    dst = jnp.asarray([0, 0, 1, -1], jnp.int32)
    deg = degree(dst, 3)
    np.testing.assert_allclose(np.asarray(deg), [2, 1, 0])


def test_edge_vectors_unit_norm():
    pos = jnp.asarray(np.random.default_rng(0).normal(size=(10, 3)),
                      jnp.float32)
    src = jnp.asarray([0, 1, 2], jnp.int32)
    dst = jnp.asarray([3, 4, 5], jnp.int32)
    u, r = edge_vectors(pos, src, dst)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(u), axis=1), 1.0,
                               rtol=1e-5)
    assert (np.asarray(r) > 0).all()


def test_bessel_rbf_cutoff():
    r = jnp.asarray([0.5, 4.9, 5.1, 10.0])
    rbf = np.asarray(bessel_rbf(r, 4, 5.0))
    assert np.abs(rbf[2:]).max() < 1e-3   # beyond cutoff ~ 0


# --- SO(3) -------------------------------------------------------------------
def test_sph_harm_orthonormal():
    rng = np.random.default_rng(0)
    v = rng.normal(size=(200000, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    for l in (0, 1, 2):
        y = real_sph_harm_np(l, v)
        gram = 4 * np.pi * (y.T @ y) / len(v)
        np.testing.assert_allclose(gram, np.eye(2 * l + 1), atol=0.05)


def test_wigner_d_composition():
    rots = _random_rotations(2, seed=3)
    r12 = rots[0] @ rots[1]
    for l in (1, 2):
        d1 = wigner_d_real_np(l, rots[0])
        d2 = wigner_d_real_np(l, rots[1])
        d12 = wigner_d_real_np(l, r12)
        np.testing.assert_allclose(d1 @ d2, d12, atol=1e-6)


def test_cg_equivariance_all_paths():
    for (l1, l2, l3) in allowed_paths(2):
        c = real_cg(l1, l2, l3)
        assert c is not None
        for rr in _random_rotations(2, seed=17):
            d1, d2, d3 = (wigner_d_real_np(l, rr) for l in (l1, l2, l3))
            lhs = np.einsum("kij,ia,jb->kab", c, d1, d2)
            rhs = np.einsum("kl,lab->kab", d3, c)
            np.testing.assert_allclose(lhs, rhs, atol=1e-6)


# --- model-level equivariance -------------------------------------------------
@pytest.fixture(scope="module")
def mol_batch():
    mol, gid = molecules_batch(3, 10, 24, seed=2)
    return {"species": jnp.asarray(np.abs(mol.labels) % 8, jnp.int32),
            "pos": jnp.asarray(mol.pos),
            "edge_src": jnp.asarray(mol.edge_src),
            "edge_dst": jnp.asarray(mol.edge_dst),
            "graph_ids": jnp.asarray(gid),
            "energy": jnp.asarray(np.zeros(3), jnp.float32)}


@pytest.mark.parametrize("which", ["nequip", "mace"])
def test_energy_invariance_under_rotation_translation(which, mol_batch):
    if which == "nequip":
        from repro.models.gnn.nequip import NequIPConfig, forward_energy, init_params
        cfg = NequIPConfig(n_layers=2, channels=8)
    else:
        from repro.models.gnn.mace import MACEConfig, forward_energy, init_params
        cfg = MACEConfig(n_layers=1, channels=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    e0 = forward_energy(params, cfg, mol_batch)
    r = _random_rotations(1, seed=9)[0]
    shift = jnp.asarray([1.7, -0.3, 2.2], jnp.float32)
    rot = dict(mol_batch)
    rot["pos"] = mol_batch["pos"] @ jnp.asarray(r.T, jnp.float32) + shift
    e1 = forward_energy(params, cfg, rot)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1), rtol=2e-4,
                               atol=1e-4)


def test_nequip_force_equivariance(mol_batch):
    from repro.models.gnn.nequip import NequIPConfig, forces_fn, init_params
    cfg = NequIPConfig(n_layers=2, channels=8)
    params = init_params(cfg, jax.random.PRNGKey(1))
    f = forces_fn(params, cfg, mol_batch)
    r = jnp.asarray(_random_rotations(1, seed=11)[0], jnp.float32)
    rot = dict(mol_batch)
    rot["pos"] = mol_batch["pos"] @ r.T
    f_rot = forces_fn(params, cfg, rot)
    np.testing.assert_allclose(np.asarray(f_rot), np.asarray(f @ r.T),
                               rtol=1e-3, atol=1e-4)


# --- sampler + triplets --------------------------------------------------------
def test_sampler_subgraph_valid():
    g = random_graph(500, 5000, d_feat=4, seed=5)
    csr = csr_from_edges(500, g.edge_src, g.edge_dst)
    rng = np.random.default_rng(0)
    seeds = rng.choice(500, 32, replace=False)
    nodes, es, ed = sample_subgraph(csr, seeds, [5, 3], rng)
    assert len(set(nodes.tolist())) == len(nodes)
    assert es.max() < len(nodes) and ed.max() < len(nodes)
    # every sampled edge exists in the original graph
    eset = set(zip(g.edge_src.tolist(), g.edge_dst.tolist()))
    for s_, d_ in zip(es.tolist()[:50], ed.tolist()[:50]):
        assert (int(nodes[d_]), int(nodes[s_])) in eset \
            or (int(nodes[s_]), int(nodes[d_])) in eset


def test_padded_sample_fixed_shape_and_determinism():
    g = random_graph(400, 4000, d_feat=6, seed=6)
    csr = csr_from_edges(400, g.edge_src, g.edge_dst)
    mn, me = expected_sizes(16, [4, 2])
    a = padded_sample(csr, g.node_feat, g.labels, 16, [4, 2], step=3,
                      max_nodes=mn, max_edges=me, seed=1)
    b = padded_sample(csr, g.node_feat, g.labels, 16, [4, 2], step=3,
                      max_nodes=mn, max_edges=me, seed=1)
    np.testing.assert_array_equal(a["edge_src"], b["edge_src"])
    assert a["node_feat"].shape == (mn, 6)


def test_triplets_share_pivot_node():
    mol, _ = molecules_batch(1, 12, 30, seed=3)
    from repro.models.gnn.dimenet import build_triplets
    ti, to = build_triplets(mol.edge_src, mol.edge_dst)
    for a, b in zip(ti.tolist()[:100], to.tolist()[:100]):
        if a < 0:
            continue
        # edge_in (k->j) ends where edge_out (j->i) starts
        assert mol.edge_dst[a] == mol.edge_src[b]
        # no immediate backtrack k == i
        assert mol.edge_src[a] != mol.edge_dst[b]
