"""Checkpointing: atomic, async, elastic-reshard on restore.

Format: one .npz per checkpoint, keyed by jax tree paths
("['params']['layers']['wq']"), plus a JSON manifest {step, shapes,
dtypes}.  Writes are atomic (tmp file + os.replace), so a preemption
mid-save never corrupts the latest checkpoint; `latest_step` scans the
directory.

Elastic restore: arrays come back as host numpy and are device_put with
*whatever sharding the new mesh dictates* -- restarting on a different
device count / mesh shape reshards transparently (tests/test_train.py).

Async: `AsyncCheckpointer` snapshots to host (device_get, the only
step-blocking part) and serializes/writes in a daemon thread off the
critical path.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree):
    """[(path_str, leaf)] with a stable, unambiguous path encoding."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomic checkpoint write. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    host = {k: np.asarray(v) for k, v in _leaf_paths(tree)}
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **host)
    os.replace(tmp, path)
    manifest = {"step": step,
                "leaves": {k: [list(v.shape), str(v.dtype)]
                           for k, v in host.items()}}
    mtmp = os.path.join(ckpt_dir, "manifest.json.tmp")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(ckpt_dir, "manifest.json"))
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f[5:13]) for f in os.listdir(ckpt_dir)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None):
    """Load a checkpoint into the *structure* of `like` (host numpy leaves).

    Leaf set must match exactly -- a changed model structure is an error,
    not a silent partial restore.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    paths = [k for k, _ in _leaf_paths(like)]
    missing = [k for k in paths if k not in flat]
    extra = [k for k in flat if k not in set(paths)]
    if missing or extra:
        raise ValueError(f"checkpoint/model mismatch: missing={missing[:5]} "
                         f"extra={extra[:5]}")
    leaves = [flat[k] for k in paths]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def restore_sharded(ckpt_dir: str, like: Any, shardings,
                    step: Optional[int] = None):
    """Elastic restore: device_put each leaf with the *new* sharding tree
    (mesh / device count may differ from the run that saved)."""
    host, step = restore(ckpt_dir, like, step)
    out = jax.tree.map(
        lambda h, s: jax.device_put(h, s) if s is not None else jax.device_put(h),
        host, shardings)
    return out, step


class AsyncCheckpointer:
    """Snapshot on the main thread (device_get), write in the background."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot

        def write():
            save(self.ckpt_dir, step, host)
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(int(f[5:13]) for f in os.listdir(self.ckpt_dir)
                       if f.startswith("ckpt_") and f.endswith(".npz"))
        for s in steps[:-self.keep]:
            try:
                os.remove(os.path.join(self.ckpt_dir, f"ckpt_{s:08d}.npz"))
            except OSError:
                pass
