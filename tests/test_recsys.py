"""DIN + embedding substrate: bag pooling, learning, retrieval cascade."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import din_batch
from repro.models.recsys.din import (DINConfig, forward_scores, init_params,
                                     loss_fn, retrieval_step,
                                     target_attention)
from repro.models.recsys.embedding import embedding_bag

CFG = DINConfig(n_items=3000, n_cates=32, seq_len=16, embed_dim=8,
                attn_mlp=(16, 8), mlp=(32, 16), rerank_k=32)


def _batch(step=0, b=32):
    hi, hc, hl, ti, tc, y = din_batch(step, b, CFG.seq_len, CFG.n_items,
                                      CFG.n_cates)
    return {k: jnp.asarray(v) for k, v in
            zip(("hist_items", "hist_cates", "hist_len", "target_item",
                 "target_cate", "label"), (hi, hc, hl, ti, tc, y))}


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 5000))
def test_embedding_bag_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    table = rng.normal(size=(50, 6)).astype(np.float32)
    ids = rng.integers(-1, 50, 40).astype(np.int32)
    segs = rng.integers(0, 8, 40).astype(np.int32)
    for mode in ("sum", "mean"):
        out = np.asarray(embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                                       jnp.asarray(segs), 8, mode=mode))
        ref = np.zeros((8, 6), np.float32)
        cnt = np.zeros(8)
        for i, s in zip(ids, segs):
            if i >= 0:
                ref[s] += table[i]
                cnt[s] += 1
        if mode == "mean":
            ref /= np.maximum(cnt, 1)[:, None]
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_target_attention_masks_padding():
    params = init_params(CFG, jax.random.PRNGKey(0))
    e_hist = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, CFG.seq_len, CFG.d_feat)), jnp.float32)
    e_tgt = jnp.asarray(np.random.default_rng(1).normal(
        size=(2, CFG.d_feat)), jnp.float32)
    full = target_attention(params, e_hist, e_tgt,
                            jnp.asarray([CFG.seq_len, 4], jnp.int32))
    # changing masked positions must not change user 1's interest
    e2 = e_hist.at[1, 10:].set(99.0)
    full2 = target_attention(params, e2, e_tgt,
                             jnp.asarray([CFG.seq_len, 4], jnp.int32))
    np.testing.assert_allclose(np.asarray(full)[1], np.asarray(full2)[1],
                               rtol=1e-5)


def test_din_learns():
    params = init_params(CFG, jax.random.PRNGKey(0))

    @jax.jit
    def step(p, b):
        l, g = jax.value_and_grad(lambda p: loss_fn(p, CFG, b))(p)
        return jax.tree.map(lambda x, gg: x - 0.5 * gg, p, g), l

    losses = []
    for i in range(40):
        params, l = step(params, _batch(i, 128))
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.02


def test_retrieval_cascade_shapes_and_ranking():
    params = init_params(CFG, jax.random.PRNGKey(0))
    b = _batch(0, 8)
    s, ids = jax.jit(lambda p, bb: retrieval_step(p, CFG, bb, 1024, k=7))(
        params, b)
    assert s.shape == (8, 7) and ids.shape == (8, 7)
    # scores returned in descending order
    assert (np.diff(np.asarray(s), axis=1) <= 1e-5).all()
    assert (np.asarray(ids) < 1024).all()


def test_forward_scores_deterministic():
    params = init_params(CFG, jax.random.PRNGKey(0))
    b = _batch(1, 16)
    s1 = forward_scores(params, CFG, b)
    s2 = forward_scores(params, CFG, b)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
