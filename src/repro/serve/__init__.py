"""TPU-native batched serving layer for BAMG (fixed-shape, jit-compiled).

Four pieces:

- `ann_engine.BatchedANNEngine` -- whole-batch beam search over one BAMG
  sub-index: batched ADC entry scoring through the `pq_adc` kernel, a
  `(B, L)` candidate pool maintained by vectorized insert-sort, fixed-hop
  beam expansion with masked gathers over the padded adjacency matrix, and
  exact re-rank through `l2_topk_rowwise`.
- `runtime.ServeRuntime` -- the distributed mesh serving runtime: shard
  replica groups placed onto `repro.launch.mesh` workers
  (`ShardPlacement`/`MeshWorker`), a static SCATTER/RUN/GATHER/MERGE
  instruction stream compiled per fleet topology, and a
  continuous-batching `Scheduler` (open-loop arrivals, EDF micro-batches,
  per-query adaptive beam width against a p99 SLO).
- `frontend.ShardedFrontend` -- thin compatibility shim over the runtime:
  the legacy scatter-gather API, bit-identical answers, served through
  the instruction stream; dead shards are masked (degraded mode) and
  tracked by `health()`.
- `deploy.DeploymentManager` / `deploy.BlueGreenEngine` -- versioned
  checksummed index builds with an atomic ACTIVE pointer: publish ->
  verify -> validate (recall smoke) -> promote, plus rollback; the engine
  hot-swaps on `refresh()` without ever serving a partial index.

Everything is fixed-shape so a (batch, k) signature compiles once and is
reused for the lifetime of the server; see `ann_engine` for the shape
contract and `runtime.scheduler` for how micro-batches are padded to it.
"""
from .ann_engine import BatchedANNEngine, EngineConfig  # noqa: F401
from .deploy import (BlueGreenEngine, DeploymentManager,  # noqa: F401
                     IndexManifest)
from .frontend import ServeStatus, ShardedFrontend, ShardHealth  # noqa: F401
from .runtime import (BeamTier, Completion, Request,  # noqa: F401
                      RequestQueue, Scheduler, SchedulerConfig,
                      ServeRuntime, build_shard_fleet, make_requests,
                      open_loop_arrivals, summarize)
