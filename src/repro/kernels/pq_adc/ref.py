"""Pure-jnp oracle for PQ asymmetric distance computation (ADC).

est[b, n] = sum_m tables[b, m, codes[n, m]]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_adc_ref(tables: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """tables (B, M, K) f32; codes (N, M) uint8/int32 -> (B, N) f32."""
    codes = codes.astype(jnp.int32)
    # gather form: for each (b, n, m) pick tables[b, m, codes[n, m]]
    g = jnp.take_along_axis(
        tables[:, None, :, :],                       # (B, 1, M, K)
        codes[None, :, :, None].astype(jnp.int32),   # (1, N, M, 1)
        axis=3,
    )  # (B, N, M, 1)
    return g[..., 0].sum(-1)
