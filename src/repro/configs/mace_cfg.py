"""mace [arXiv:2206.07697]: higher-order E(3)-equivariant message passing,
2 layers, 128 channels, l_max=2, correlation order 3, n_rbf=8."""
from repro.models.gnn.mace import MACEConfig

from .base import GNN_SHAPES

ARCH_ID = "mace"
FAMILY = "gnn"
SHAPES = GNN_SHAPES


def model_config(reduced: bool = False) -> MACEConfig:
    if reduced:
        return MACEConfig(name=ARCH_ID + "-smoke", n_layers=1, channels=8,
                          l_max=2, correlation=3, n_rbf=4)
    return MACEConfig(name=ARCH_ID, n_layers=2, channels=128, l_max=2,
                      correlation=3, n_rbf=8, cutoff=5.0)
