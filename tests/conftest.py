import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_corpus():
    """Clustered corpus + queries + exact ground truth (session-cached)."""
    import sys
    sys.path.insert(0, "src")
    from repro.data.synthetic import make_vector_dataset
    return make_vector_dataset("test", n=600, d=24, nq=12, k_gt=10,
                               n_clusters=12, seed=0)


@pytest.fixture(scope="session")
def tiny_points():
    rng = np.random.default_rng(42)
    return rng.normal(size=(40, 6)).astype(np.float32)
