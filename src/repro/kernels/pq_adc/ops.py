"""Public jit'd wrapper for the PQ ADC kernel: padding + backend switch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import pq_adc_pallas, pq_adc_rowwise_pallas
from .ref import pq_adc_ref, pq_adc_rowwise_ref


def _pad_to(x: jnp.ndarray, mult: int, axis: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_b", "backend"))
def pq_adc(tables: jnp.ndarray, codes: jnp.ndarray, tile_n: int = 256,
           tile_b: int = 8, backend: str = "auto") -> jnp.ndarray:
    """ADC distance estimates.

    tables: (B, M, K) float32 -- per-query per-subspace centroid distances
    codes:  (N, M) uint8/int32 -- PQ codes of the corpus
    returns (B, N) float32

    backend: "pallas" (TPU), "interpret" (CPU-validated kernel), or "ref"
    (pure jnp); "auto" = pallas on TPU else ref.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return pq_adc_ref(tables, codes)
    tables_p, b0 = _pad_to(tables, tile_b, 0)
    codes_p, n0 = _pad_to(codes, tile_n, 0)
    out = pq_adc_pallas(tables_p, codes_p, tile_n=tile_n, tile_b=tile_b,
                        interpret=(backend == "interpret"))
    return out[:b0, :n0]


@functools.partial(jax.jit, static_argnames=("tile_b", "backend"))
def pq_adc_rowwise(tables: jnp.ndarray, cand_codes: jnp.ndarray,
                   tile_b: int = 8, backend: str = "auto") -> jnp.ndarray:
    """Per-row ADC estimates (the beam hop-loop form of `pq_adc`).

    tables:     (B, M, K) float32 -- per-query centroid distance tables
    cand_codes: (B, R, M) uint8/int32 -- each row's gathered neighbor codes
    returns (B, R) float32

    Same backend matrix as `pq_adc`: "pallas" (TPU), "interpret"
    (CPU-validated kernel), "ref" (pure jnp, bit-identical to the
    historical take_along_axis path); "auto" = pallas on TPU else ref.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return pq_adc_rowwise_ref(tables, cand_codes)
    tables_p, b0 = _pad_to(tables, tile_b, 0)
    codes_p, _ = _pad_to(cand_codes, tile_b, 0)
    out = pq_adc_rowwise_pallas(tables_p, codes_p, tile_b=tile_b,
                                interpret=(backend == "interpret"))
    return out[:b0]
