"""The fixed-shape (B, L) insert-sort candidate pool.

Consumed by the batched serving engine (`repro.serve.ann_engine`): a
sorted (ids, dists, expanded) pool per row, merging new candidates with
two stable argsorts -- no Python heaps, one compilation for the lifetime
of the process.  The construction frontier (`repro.build.frontier`) keeps
the same pool *shape* but inlines a leaner merge (single top_k; its
(B, N) seen mask already guarantees candidates are distinct and unseen,
which the serve path cannot assume).

`pool_merge_ranked` is the sort-free formulation of the same merge: the
pool is already sorted, so each entry's post-merge slot is its *merge
rank* (old index + number of strictly-closer candidates; candidates rank
after every pool tie, preserving the stable concat order).  It is
bit-identical to `pool_merge` (tests/test_beam_fused.py sweeps dups,
ties, all-padded rows) but replaces the two (B, L+R) stable argsorts
with elementwise rank comparisons and one scatter -- the form the fused
Pallas serve kernel (`repro.kernels.beam_fused`) inlines as one-hot
matmuls, and measurably faster under XLA on CPU as well.
"""
from __future__ import annotations

import jax.numpy as jnp


def pool_merge(pool_ids, pool_d, pool_exp, cand_ids, cand_d, l: int):
    """Vectorized insert-sort of candidates into the sorted (B, L) pool.

    Duplicate ids collapse to the incumbent pool entry (stable sort by id
    keeps the lower concat index first, and the pool occupies indices
    0..L-1), so expanded flags survive re-insertion and a node is not
    re-expanded *while it stays in the pool*.  A node evicted past L loses
    its flag; if the beam later re-encounters it as a best unexpanded
    candidate it is re-expanded -- the price of a fixed-shape pool vs the
    host engine's unbounded `explored` set.  In practice eviction means L
    closer candidates exist, so re-expansion is rare and costs only a hop,
    never correctness.  Returns the new (ids, dists, expanded), sorted
    ascending by dist with invalid entries (+inf, id=-1) at the tail.
    """
    sentinel = jnp.iinfo(jnp.int32).max
    ids = jnp.concatenate([pool_ids, cand_ids.astype(jnp.int32)], axis=1)
    d = jnp.concatenate([pool_d, cand_d], axis=1)
    exp = jnp.concatenate(
        [pool_exp, jnp.zeros(cand_ids.shape, bool)], axis=1)
    d = jnp.where(ids < 0, jnp.inf, d)
    key = jnp.where(ids < 0, sentinel, ids)
    order = jnp.argsort(key, axis=1, stable=True)
    sid = jnp.take_along_axis(key, order, axis=1)
    ids_s = jnp.take_along_axis(ids, order, axis=1)
    d_s = jnp.take_along_axis(d, order, axis=1)
    exp_s = jnp.take_along_axis(exp, order, axis=1)
    dup = jnp.pad(sid[:, 1:] == sid[:, :-1], ((0, 0), (1, 0)))
    ids_s = jnp.where(dup, -1, ids_s)
    d_s = jnp.where(dup, jnp.inf, d_s)
    exp_s = jnp.where(dup, False, exp_s)
    o2 = jnp.argsort(d_s, axis=1, stable=True)[:, :l]
    return (jnp.take_along_axis(ids_s, o2, axis=1),
            jnp.take_along_axis(d_s, o2, axis=1),
            jnp.take_along_axis(exp_s, o2, axis=1))


def pool_merge_ranked(pool_ids, pool_d, pool_exp, cand_ids, cand_d, l: int):
    """Sort-free `pool_merge`: merge ranks instead of two stable argsorts.

    Requires the invariant every `pool_merge`/`pool_merge_ranked` output
    satisfies (and the serve/build loops maintain): the pool is sorted
    ascending by (distance, id) -- `pool_merge`'s id-sort-then-dist-sort
    orders equal-distance entries by ascending id -- valid ids are
    unique, and invalid entries are exactly (id=-1, d=+inf, exp=False).
    Candidates carry no such contract: they may duplicate the pool, each
    other, or be -1 padded.

    Equivalence to the concat-sort, piece by piece: a candidate
    duplicating a pool id is dropped (the incumbent wins, keeping its
    expanded flag); a candidate duplicating an earlier candidate is
    dropped; surviving entries land at their merge rank under the same
    (distance, id) lexicographic key -- old index + #{strictly smaller
    candidates} for pool entries, #{pool entries with key at most theirs}
    + #{candidates ranked earlier} for candidates.  Invalid entries all
    carry the identical key (+inf, -1, False), so their mutual order is
    immaterial; ranks >= l fall off the end.  Returns (ids, dists,
    expanded) of shape (B, l)."""
    sentinel = jnp.iinfo(jnp.int32).max
    pids = pool_ids.astype(jnp.int32)
    cids = cand_ids.astype(jnp.int32)
    cd = jnp.where(cids < 0, jnp.inf, cand_d)

    dup_pool = ((pids[:, None, :] == cids[:, :, None])
                & (cids[:, :, None] >= 0)).any(axis=2)          # (B, R)
    j = jnp.arange(cids.shape[1])
    earlier = j[None, :, None] > j[None, None, :]               # j' < j
    dup_cand = ((cids[:, :, None] == cids[:, None, :])
                & (cids[:, :, None] >= 0) & earlier).any(axis=2)
    valid = (cids >= 0) & ~dup_pool & ~dup_cand
    cd = jnp.where(valid, cd, jnp.inf)
    cids = jnp.where(valid, cids, -1)

    # lexicographic (dist, id) merge ranks; -1 ids rank as id=+sentinel
    pkid = jnp.where(pids < 0, sentinel, pids)
    ckid = jnp.where(cids < 0, sentinel, cids)
    c_lt_p = ((cd[:, :, None] < pool_d[:, None, :])             # (B, R, L)
              | ((cd[:, :, None] == pool_d[:, None, :])
                 & (ckid[:, :, None] < pkid[:, None, :])))
    pos_p = jnp.arange(pids.shape[1])[None, :] + c_lt_p.sum(axis=1)
    # pool_i lex<= cand_j  <=>  not (cand_j lex< pool_i): the keys form a
    # total order, so the <=-count is the negated transpose of c_lt_p
    c_lt_c = ((cd[:, :, None] > cd[:, None, :])                 # cd_j' < cd_j
              | ((cd[:, :, None] == cd[:, None, :])
                 & (ckid[:, :, None] > ckid[:, None, :]))
              | ((cd[:, :, None] == cd[:, None, :])
                 & (ckid[:, :, None] == ckid[:, None, :]) & earlier))
    pos_c = (~c_lt_p).sum(axis=2) + c_lt_c.sum(axis=2)

    # merge ranks of surviving entries are distinct, so each output slot
    # has at most one writer: place by slot-match sums (XLA CPU scatters
    # serialize; this stays elementwise, and is the exact form the fused
    # Pallas kernel uses).  Ranks >= l match no slot and fall away.
    slot = jnp.arange(l)
    mask_p = pos_p[:, :, None] == slot                          # (B, L, l)
    mask_c = pos_c[:, :, None] == slot                          # (B, R, l)
    ids_o = (jnp.where(mask_p, pids[:, :, None], 0).sum(axis=1)
             + jnp.where(mask_c, cids[:, :, None], 0).sum(axis=1))
    d_o = (jnp.where(mask_p, pool_d[:, :, None], 0).sum(axis=1)
           + jnp.where(mask_c, cd[:, :, None], 0).sum(axis=1))
    wrote = mask_p.any(axis=1) | mask_c.any(axis=1)             # (B, l)
    exp_o = (mask_p & pool_exp[:, :, None]).any(axis=1)
    return (jnp.where(wrote, ids_o, -1),
            jnp.where(wrote, d_o, jnp.inf),
            exp_o)
