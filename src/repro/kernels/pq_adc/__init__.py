from .ops import pq_adc, pq_adc_rowwise  # noqa: F401
from .ref import pq_adc_ref, pq_adc_rowwise_ref  # noqa: F401
