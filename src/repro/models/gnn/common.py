"""Message-passing substrate: segment ops over padded edge lists.

JAX sparse is BCOO-only, so message passing is built from
jax.ops.segment_sum / segment_max over (edge_src, edge_dst) index arrays
(kernel_taxonomy §GNN).  Edges are padded with -1 (src/dst) -- padded
messages are zeroed and scattered to a dump row.

Distribution: edge arrays shard over the batch axes (edge parallelism);
node tensors stay replicated inside the gather/scatter and shard over
nodes for the dense MLP transforms (GSPMD inserts the partial-scatter +
all-reduce).  See DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from ..layers import act_fn, dense_init


# ---------------------------------------------------------------------------
# MLP helper
# ---------------------------------------------------------------------------
def init_mlp(key, sizes: Sequence[int], dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, len(sizes) - 1)
    return {
        "w": [dense_init(ks[i], sizes[i], sizes[i + 1], dtype)
              for i in range(len(sizes) - 1)],
        "b": [jnp.zeros((sizes[i + 1],), dtype) for i in range(len(sizes) - 1)],
    }


def mlp_apply(p: dict, x: jnp.ndarray, activation: str = "silu",
              final_act: bool = False) -> jnp.ndarray:
    n = len(p["w"])
    for i in range(n):
        # params stay f32; compute follows the activation dtype (bf16 for
        # the large full-graph cells)
        x = x @ p["w"][i].astype(x.dtype) + p["b"][i].astype(x.dtype)
        if i < n - 1 or final_act:
            x = act_fn(activation)(x)
    return x


def mlp_specs(p: dict):
    """Replicated specs matching init_mlp output."""
    from jax.sharding import PartitionSpec as P
    return jax.tree.map(lambda _: P(), p)


# ---------------------------------------------------------------------------
# Padded segment message passing
# ---------------------------------------------------------------------------
def edge_mask(edge_src: jnp.ndarray) -> jnp.ndarray:
    return (edge_src >= 0)


def gather_src_dst(node_feat: jnp.ndarray, edge_src, edge_dst):
    """(N, d) -> ((E, d), (E, d)); padded edges gather row 0 (masked later)."""
    s = jnp.clip(edge_src, 0, node_feat.shape[0] - 1)
    d = jnp.clip(edge_dst, 0, node_feat.shape[0] - 1)
    return node_feat[s], node_feat[d]


def scatter_to_nodes(messages: jnp.ndarray, edge_dst: jnp.ndarray,
                     n_nodes: int, mask: jnp.ndarray | None = None,
                     agg: str = "sum") -> jnp.ndarray:
    """(E, d) messages -> (N, d) aggregated at edge_dst.  agg: sum|mean|max."""
    if mask is None:
        mask = edge_mask(edge_dst)
    dst = jnp.where(mask, edge_dst, n_nodes)  # dump row for padding
    if agg == "max":
        m = jnp.where(mask[:, None], messages, -jnp.inf)
        out = jax.ops.segment_max(m, dst, num_segments=n_nodes + 1)
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    else:
        m = jnp.where(mask[:, None], messages, 0.0)
        out = jax.ops.segment_sum(m, dst, num_segments=n_nodes + 1)
        if agg == "mean":
            cnt = jax.ops.segment_sum(mask.astype(messages.dtype), dst,
                                      num_segments=n_nodes + 1)
            out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out[:n_nodes]


def degree(edge_dst: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    mask = edge_mask(edge_dst)
    dst = jnp.where(mask, edge_dst, n_nodes)
    return jax.ops.segment_sum(mask.astype(jnp.float32), dst,
                               num_segments=n_nodes + 1)[:n_nodes]


# ---------------------------------------------------------------------------
# Geometry helpers (radius/molecular graphs)
# ---------------------------------------------------------------------------
def edge_vectors(pos: jnp.ndarray, edge_src, edge_dst, eps: float = 1e-9):
    """Returns (unit r_ij (E,3), |r_ij| (E,)) for edges src->dst."""
    ps, pd = gather_src_dst(pos, edge_src, edge_dst)
    d = pd - ps
    r = jnp.sqrt(jnp.maximum(jnp.sum(d * d, -1), eps))
    return d / r[:, None], r


def bessel_rbf(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """Radial Bessel basis sin(n pi r / c) / r with cosine envelope (E, n)."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rb = jnp.sqrt(2.0 / cutoff) * jnp.sin(n[None, :] * jnp.pi * r[:, None]
                                          / cutoff) / jnp.maximum(r[:, None], 1e-9)
    env = cosine_cutoff(r, cutoff)[:, None]
    return rb * env


def cosine_cutoff(r: jnp.ndarray, cutoff: float) -> jnp.ndarray:
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    return 0.5 * (jnp.cos(jnp.pi * x) + 1.0)
