"""On-disk layouts (paper Fig. 3) over the block-device simulator.

Two layouts, all byte-accounted against the 4 KB block size:

1. `CoupledStorage` -- the DiskANN / Starling layout: each node record holds
   [raw vector (d*4 B) | degree (4 B) | R neighbor ids (4 B each)], packed
   nodes-per-block = block_size // record_bytes (>=1; large records span
   ceil(record/block) blocks, each read costing that many I/Os).  The node
   order is a permutation: identity for DiskANN, BNF-shuffled for Starling.

2. `DecoupledStorage` -- the paper's BAMG layout: graph blocks hold only
   [OID | VID | degree | neighbor OIDs], so capacity c is much larger; raw
   vectors live in a *separate* region, packed per graph block in contiguous
   blocks ordered by slot, so a vector's location is computable from its OID
   (no in-memory map -- §4.2).

Payloads are numpy structs (not raw bytes) for speed; byte sizes are
computed exactly and validated against the block size.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.utils.faults import FaultPlan, RetryPolicy

from .io_sim import (BLOCK_SIZE, READ_FAILED, BlockDevice, CachePolicy,
                     CostModel, IOScheduler)


# ---------------------------------------------------------------------------
# Coupled layout (DiskANN / Starling baselines)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CoupledRecord:
    vids: np.ndarray   # (npb,) int32, -1 pad
    vecs: np.ndarray   # (npb, d) float32
    nbrs: np.ndarray   # (npb, R) int32 neighbor VIDs, -1 pad


class CoupledStorage:
    """DiskANN/Starling node-record layout on the simulator."""

    def __init__(self, x: np.ndarray, adj: np.ndarray, order: np.ndarray | None = None,
                 block_size: int = BLOCK_SIZE, cache_blocks: int = 256,
                 policy: str | CachePolicy = "lru",
                 cost: CostModel | None = None,
                 faults: FaultPlan | None = None,
                 retry: RetryPolicy | None = None):
        n, d = x.shape
        r = adj.shape[1]
        self.n, self.d, self.r = n, d, r
        self.record_bytes = 4 * d + 4 + 4 * r
        self.blocks_per_record = max(1, -(-self.record_bytes // block_size))
        if self.record_bytes <= block_size:
            self.npb = block_size // self.record_bytes  # nodes per block
        else:
            self.npb = 1  # one (multi-block) record per logical slot
        order = np.arange(n, dtype=np.int64) if order is None else np.asarray(order, np.int64)
        assert len(order) == n
        self.layout = order                  # slot -> vid
        self.pos = np.empty(n, np.int64)     # vid -> slot
        self.pos[order] = np.arange(n)

        m = -(-n // self.npb)
        payloads: list[CoupledRecord] = []
        for b in range(m):
            sl = order[b * self.npb: (b + 1) * self.npb]
            vids = -np.ones(self.npb, np.int32)
            vids[: len(sl)] = sl
            vecs = np.zeros((self.npb, d), np.float32)
            vecs[: len(sl)] = x[sl]
            nb = -np.ones((self.npb, r), np.int32)
            nb[: len(sl)] = adj[sl]
            payloads.append(CoupledRecord(vids=vids, vecs=vecs, nbrs=nb))
        # multi-block records: the payload lives at the first block id of the
        # span; the extra span blocks are placeholders (None) that still cost
        # one read each via read_node.
        dev_blocks: list = []
        self._payload_block = np.empty(m, np.int64)
        for b, p in enumerate(payloads):
            self._payload_block[b] = len(dev_blocks)
            dev_blocks.append(p)
            for _ in range(self.blocks_per_record - 1):
                dev_blocks.append(None)
        self.device = BlockDevice(dev_blocks, block_size, cache_blocks,
                                  kind="graph", policy=policy, faults=faults)
        self.scheduler = IOScheduler(cost, retry)

    @property
    def n_blocks(self) -> int:
        return len(self.device)

    def block_of(self, vid: int) -> int:
        return int(self.pos[vid]) // self.npb

    def reset(self, drop_cache: bool = True) -> None:
        self.device.reset(drop_cache)
        self.scheduler.reset()

    def read_node_block(self, vid: int, prefetch=()) -> CoupledRecord:
        """Read the block(s) containing vid's record; returns the payload.

        Multi-block records go down as one batched submission (their span is
        known up front); `prefetch` adds speculative logical-block hints
        (timing only -- see io_sim.IOScheduler).  Under fault injection a
        record any of whose span blocks could not be delivered is
        `READ_FAILED` (the caller degrades, it does not crash).
        """
        b = self.block_of(vid)
        first = int(self._payload_block[b])
        span = list(range(first, first + self.blocks_per_record))
        pf: list[int] = []
        for lb in prefetch:
            f = int(self._payload_block[lb])
            pf.extend(range(f, f + self.blocks_per_record))
        payloads = self.scheduler.submit(self.device, span, prefetch=pf)
        if any(p is READ_FAILED for p in payloads):
            return READ_FAILED
        return payloads[0]

    def slot_in_block(self, vid: int) -> int:
        return int(self.pos[vid]) % self.npb


# ---------------------------------------------------------------------------
# Decoupled layout (BAMG, §4.2 / Fig. 3)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class GraphBlock:
    oids: np.ndarray   # (c,) int32, -1 pad
    vids: np.ndarray   # (c,) int32, -1 pad
    nbrs: np.ndarray   # (c, R) int32 neighbor OIDs, -1 pad


class DecoupledStorage:
    """Graph blocks (neighbor OIDs only) + separate contiguous vector region.

    OID = block_id * capacity + slot.  Vector region: per graph block, the
    vectors of its members are packed in slot order into contiguous blocks,
    *aligned* so no vector straddles a block boundary (vectors_per_block =
    floor(block / vec) when vec <= block; unused tail space left empty --
    the paper's "remaining space is left empty").  Vectors larger than one
    block get ceil(vec/block) dedicated aligned blocks.  Alignment costs a
    few % of space and halves rerank I/Os for near-block-sized vectors
    (measured: GIST-like d=960 went from ~1.55 to 1.0 reads/vector).
    """

    def __init__(self, x: np.ndarray, adj: np.ndarray, blocks: np.ndarray,
                 members: np.ndarray, block_size: int = BLOCK_SIZE,
                 cache_blocks: int = 256, vec_cache_blocks: int = 256,
                 policy: str | CachePolicy = "lru",
                 vec_policy: str | CachePolicy | None = None,
                 pinned_gblocks=(), cost: CostModel | None = None,
                 faults: FaultPlan | None = None,
                 retry: RetryPolicy | None = None):
        n, d = x.shape
        r = adj.shape[1]
        m, c = members.shape
        self.n, self.d, self.r = n, d, r
        self.m, self.capacity = m, c
        self.block_size = block_size
        # --- graph region ----------------------------------------------------
        self.record_bytes = 4 + 4 + 4 + 4 * r  # OID + VID + degree + R nbr OIDs
        need = c * self.record_bytes
        if need > block_size:
            raise ValueError(
                f"graph block overflow: c={c} * record={self.record_bytes}B "
                f"= {need}B > {block_size}B; lower capacity or max degree")
        self.vid2oid = -np.ones(n, np.int64)
        for b in range(m):
            row = members[b]
            for s, v in enumerate(row[row >= 0].tolist()):
                self.vid2oid[v] = b * c + s
        assert (self.vid2oid >= 0).all(), "every node must be assigned a slot"
        self.oid2vid = -np.ones(m * c, np.int64)
        self.oid2vid[self.vid2oid] = np.arange(n)

        payloads: list[GraphBlock] = []
        for b in range(m):
            row = members[b]
            mem = row[row >= 0]
            oids = -np.ones(c, np.int32)
            vids = -np.ones(c, np.int32)
            nb = -np.ones((c, r), np.int32)
            oids[: len(mem)] = (b * c + np.arange(len(mem))).astype(np.int32)
            vids[: len(mem)] = mem
            for s, v in enumerate(mem.tolist()):
                nn = adj[v]
                nn = nn[nn >= 0]
                nb[s, : len(nn)] = self.vid2oid[nn]
            payloads.append(GraphBlock(oids=oids, vids=vids, nbrs=nb))
        self.graph_dev = BlockDevice(payloads, block_size, cache_blocks,
                                     kind="graph", policy=policy,
                                     pinned=pinned_gblocks, faults=faults)
        self.scheduler = IOScheduler(cost, retry)

        # --- vector region ---------------------------------------------------
        self.vec_bytes = 4 * d
        if self.vec_bytes <= block_size:
            self.vecs_per_vblock = block_size // self.vec_bytes
            self.vblocks_per_vec = 1
            self.vblocks_per_gblock = -(-c // self.vecs_per_vblock)
        else:
            self.vecs_per_vblock = 1
            self.vblocks_per_vec = -(-self.vec_bytes // block_size)
            self.vblocks_per_gblock = c * self.vblocks_per_vec
        vec_payloads: list[np.ndarray] = []
        floats_per_block = block_size // 4
        for b in range(m):
            row = members[b]
            mem = row[row >= 0]
            region = np.zeros(self.vblocks_per_gblock * floats_per_block, np.float32)
            for s, v in enumerate(mem.tolist()):
                off = self._vec_offset_floats(s, floats_per_block)
                region[off: off + d] = x[v]
            for vb in range(self.vblocks_per_gblock):
                vec_payloads.append(region[vb * floats_per_block: (vb + 1) * floats_per_block])
        self.vector_dev = BlockDevice(
            vec_payloads, block_size, vec_cache_blocks, kind="vector",
            policy=vec_policy if vec_policy is not None else policy,
            faults=faults)

    def _vec_offset_floats(self, slot: int, floats_per_block: int) -> int:
        """Float offset of slot's vector inside its graph block's region."""
        if self.vblocks_per_vec == 1:
            vb, s_in = divmod(slot, self.vecs_per_vblock)
            return vb * floats_per_block + s_in * (self.vec_bytes // 4)
        return slot * self.vblocks_per_vec * floats_per_block

    # --- addressing ---------------------------------------------------------
    def gblock_of_oid(self, oid: int) -> int:
        return oid // self.capacity

    def read_graph_block(self, gblock: int, prefetch=()) -> GraphBlock:
        """Fetch one graph block; `prefetch` hints further graph blocks for
        the same batched submission (timing only, never accounting).  Under
        fault injection an undeliverable block is `READ_FAILED`."""
        return self.scheduler.submit(self.graph_dev, [gblock],
                                     prefetch=prefetch)[0]

    def _vec_block_span(self, oid: int) -> tuple[int, int]:
        """(first vector-device block, float offset within it) for an OID."""
        b, s = divmod(oid, self.capacity)
        floats_per_block = self.block_size // 4
        off = self._vec_offset_floats(s, floats_per_block)
        first = b * self.vblocks_per_gblock + off // floats_per_block
        return first, off % floats_per_block

    def read_vector(self, oid: int) -> np.ndarray | None:
        """Fetch a raw vector by OID -- location computed, no map (§4.2).
        None when the block could not be delivered (fault injection)."""
        return self.read_vectors([oid], batched=False)[0]

    def read_vectors(self, oids, batched: bool = True) -> list[np.ndarray]:
        """Fetch raw vectors for `oids` (in order).

        `batched=True` issues all the underlying vector-block reads as one
        scheduler submission (the re-rank phase knows its whole read set up
        front); `batched=False` submits them one by one.  Both produce the
        same reads in the same order, so NIO and cache state are identical
        -- only the modeled service time differs.

        Under fault injection a vector any of whose blocks could not be
        delivered comes back as None (the re-rank degrades per-candidate,
        the other vectors of the batch are unaffected).
        """
        spans = [self._vec_block_span(int(o)) for o in oids]
        nb = self.vblocks_per_vec
        if batched:
            demand: list[int] = []
            for first, _ in spans:
                demand.extend(range(first, first + nb))
            payloads = self.scheduler.submit(self.vector_dev, demand)
        else:
            payloads = []
            for first, _ in spans:
                for vb in range(first, first + nb):
                    payloads.append(self.scheduler.read(self.vector_dev, vb))
        out: list[np.ndarray | None] = []
        for i, (_, local) in enumerate(spans):
            chunks = payloads[i * nb: (i + 1) * nb]
            if any(c is READ_FAILED for c in chunks):
                out.append(None)
                continue
            flat = np.concatenate(chunks) if nb > 1 else chunks[0]
            out.append(flat[local: local + self.d])
        return out

    # --- stats ----------------------------------------------------------------
    @property
    def graph_bytes(self) -> int:
        return self.graph_dev.total_bytes

    @property
    def vector_bytes(self) -> int:
        return self.vector_dev.total_bytes

    def reset(self, drop_cache: bool = True) -> None:
        self.graph_dev.reset(drop_cache)
        self.vector_dev.reset(drop_cache)
        self.scheduler.reset()


def max_capacity_for(r: int, block_size: int = BLOCK_SIZE) -> int:
    """Largest c such that c * (12 + 4R) <= block_size (decoupled layout)."""
    return max(1, block_size // (12 + 4 * r))


def coupled_nodes_per_block(d: int, r: int, block_size: int = BLOCK_SIZE) -> int:
    rec = 4 * d + 4 + 4 * r
    return max(1, block_size // rec) if rec <= block_size else 1
