"""Pure-jnp oracle for the fused beam-hop loop (score + merge + select).

One hop = exactly the unfused `serve.ann_engine.batched_search` step:
pop the best unexpanded pool entry per row, gather its padded adjacency
row, score the neighbors, merge into the sorted (B, L) pool, count the
hop.  Scoring comes in the two flavors the two consumers need:

- ``mode="adc"``: PQ table lookups over gathered neighbor codes, the
  serving engine's estimate (`pq_adc_rowwise_ref`, bit-identical to the
  historical `_adc_gather` take_along_axis path);
- ``mode="l2"``: exact squared L2 in dot form with precomputed corpus
  norms and a >=0 clamp, bit-identical to the construction frontier's
  ``score`` (`repro.build.frontier`), so the batched build can run the
  same hop (width=1) as the server.

The merge is `pool_merge_ranked` -- bit-identical to the serve engine's
`pool_merge` but sort-free, which is the form the Pallas kernel inlines
(and already ~2x cheaper than the concat-double-argsort under XLA CPU).
This oracle anchors *both* Pallas execution modes: the VMEM-resident
program and the HBM-streaming program gather identical slab contents in
identical order, so resident == streaming == ref on every output.
Beyond the final pool, every hop emits its frontier pick (the trace the
build frontier returns as its visited set), and the loop ends with the
*next* frontier pick and a done mask, so callers chain hop programs
without re-deriving frontier state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.build.pool import pool_merge_ranked
from repro.kernels.pq_adc.ref import pq_adc_rowwise_ref


@functools.partial(jax.jit, static_argnames=("max_hops", "mode"))
def beam_hops_ref(adj, pool_ids, pool_d, pool_exp, max_hops: int,
                  mode: str = "adc", tables=None, codes=None,
                  x=None, n2=None, queries=None):
    """Run `max_hops` fused beam hops over a seeded pool.

    adj (N, R) int32 with -1 pad; pool_ids/pool_d/pool_exp (B, L) the
    seeded sorted pool (the `pool_merge` invariant: ascending (dist, id),
    invalid = (-1, +inf, False)).  mode="adc" takes tables (B, M, K) and
    codes (N, M) int32; mode="l2" takes x (N, D) f32, n2 (N,) squared
    norms and queries (B, D) f32.

    Returns (pool_ids, pool_d, pool_exp, hops (B,) int32,
    trace_ids (B, max_hops) int32, trace_d (B, max_hops) f32,
    next_id (B,) int32, done (B,) bool): the final pool, per-hop frontier
    picks (-1 / +inf where a row had no frontier left), the next
    frontier pick after the last hop, and whether the beam is exhausted.
    """
    b, l = pool_ids.shape
    rows = jnp.arange(b)
    if mode == "adc":
        codes_i = codes.astype(jnp.int32)
    else:
        q = queries.astype(jnp.float32)
        qn = jnp.sum(q * q, axis=1)

    def score(nbrs):
        if mode == "adc":
            nd = pq_adc_rowwise_ref(tables, codes_i[jnp.clip(nbrs, 0)])
            return jnp.where(nbrs >= 0, nd, jnp.inf)
        vecs = x[jnp.clip(nbrs, 0)]                       # (B, R, D)
        d = (n2[jnp.clip(nbrs, 0)]
             - 2.0 * jnp.einsum("bcd,bd->bc", vecs, q) + qn[:, None])
        return jnp.where(nbrs >= 0, jnp.maximum(d, 0.0), jnp.inf)

    def pick(pool_ids, pool_d, pool_exp):
        frontier_d = jnp.where(pool_exp | (pool_ids < 0), jnp.inf, pool_d)
        j = jnp.argmin(frontier_d, axis=1)                # (B,)
        has = jnp.isfinite(frontier_d[rows, j])
        return j, has

    def step(state, _):
        pool_ids, pool_d, pool_exp, hops = state
        j, has = pick(pool_ids, pool_d, pool_exp)
        v = jnp.where(has, pool_ids[rows, j], 0)
        vd = jnp.where(has, pool_d[rows, j], jnp.inf)
        pool_exp = pool_exp.at[rows, j].set(pool_exp[rows, j] | has)
        nbrs = jnp.where(has[:, None], adj[v], -1)        # (B, R)
        pool_ids, pool_d, pool_exp = pool_merge_ranked(
            pool_ids, pool_d, pool_exp, nbrs, score(nbrs), l)
        trace = (jnp.where(has, v, -1).astype(jnp.int32), vd)
        return (pool_ids, pool_d, pool_exp, hops + has), trace

    (pool_ids, pool_d, pool_exp, hops), (tid, td) = jax.lax.scan(
        step, (pool_ids, pool_d, pool_exp, jnp.zeros(b, jnp.int32)),
        None, length=max_hops)
    j, has = pick(pool_ids, pool_d, pool_exp)
    next_id = jnp.where(has, pool_ids[rows, j], -1).astype(jnp.int32)
    return (pool_ids, pool_d, pool_exp, hops,
            jnp.moveaxis(tid, 0, 1), jnp.moveaxis(td, 0, 1), next_id, ~has)
