"""dimenet [arXiv:2003.03123]: directional message passing, 6 blocks,
d_hidden=128, n_bilinear=8, n_spherical=7, n_radial=6."""
from repro.models.gnn.dimenet import DimeNetConfig

from .base import GNN_SHAPES

ARCH_ID = "dimenet"
FAMILY = "gnn"
SHAPES = GNN_SHAPES


def model_config(reduced: bool = False) -> DimeNetConfig:
    if reduced:
        return DimeNetConfig(name=ARCH_ID + "-smoke", n_blocks=2,
                             d_hidden=16, n_bilinear=4, n_spherical=4,
                             n_radial=4)
    return DimeNetConfig(name=ARCH_ID, n_blocks=6, d_hidden=128,
                         n_bilinear=8, n_spherical=7, n_radial=6, cutoff=5.0)
