"""LM substrate: decode-vs-prefill parity, SWA ring buffer, MoE routing,
PQ codec, navigation properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import MoEConfig, route
from repro.models.transformer import (LMConfig, ShardCtx, decode_step,
                                      init_cache, init_lm_params, lm_loss,
                                      serve_prefill)

CTX = ShardCtx(mesh=None)
RNG = np.random.default_rng(0)


def _cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_head=16, d_ff=128, vocab=128, remat="none", loss_chunks=2,
                dtype="float32")
    base.update(kw)
    return LMConfig(**base)


def _greedy_decode(cfg, params, prompt, n_new, cache_size):
    """Prefill then n_new greedy decode steps; returns generated ids."""
    b, s = prompt.shape
    logits, (ck, cv), lens = serve_prefill(params, cfg, prompt, CTX)
    ck0, cv0, _ = init_cache(cfg, b, cache_size, dtype=ck.dtype)
    sc = ck.shape[2]
    ck0 = ck0.at[:, :, :sc].set(ck)
    cv0 = cv0.at[:, :, :sc].set(cv)
    caches = (ck0, cv0, lens)
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((b,), s, jnp.int32)
    for i in range(n_new):
        out.append(tok)
        logits, caches = decode_step(params, cfg, tok, pos + i, caches, CTX,
                                     "local")
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, 1)


def test_decode_matches_teacher_forced_prefill():
    """Greedy decode token t must equal argmax of a fresh prefill over the
    extended sequence (KV-cache path == full-attention path)."""
    cfg = _cfg()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    gen = _greedy_decode(cfg, params, prompt, 4, cache_size=32)
    seq = prompt
    for i in range(4):
        logits, _, _ = serve_prefill(params, cfg, seq, CTX)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(nxt), np.asarray(gen[:, i:i+1]))
        seq = jnp.concatenate([seq, nxt], 1)


def test_swa_ring_buffer_matches_window_attention():
    """SWA decode through the O(window) ring cache must reproduce the full
    windowed-attention computation (teacher-forced prefill reference)."""
    win = 8
    cfg = _cfg(sliding_window=win)
    params = init_lm_params(cfg, jax.random.PRNGKey(1))
    prompt = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 20)), jnp.int32)
    # ring cache really is window-sized
    _, (ck, _), _ = serve_prefill(params, cfg, prompt, CTX)
    assert ck.shape[2] == win
    gen = _greedy_decode(cfg, params, prompt, 3, cache_size=win)
    seq = prompt
    for i in range(3):
        logits, _, _ = serve_prefill(params, cfg, seq, CTX)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(nxt),
                                      np.asarray(gen[:, i:i + 1]))
        seq = jnp.concatenate([seq, nxt], 1)


def test_moe_routing_normalized_and_padded_experts_dead():
    cfg = MoEConfig(n_experts=6, top_k=2, d_ff_expert=8, pad_multiple=8)
    x = jnp.asarray(RNG.normal(size=(32, 16)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(16, 6)), jnp.float32)
    gates, eids, aux = route(x, w, cfg)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(eids.max()) < 6            # dead padded experts never chosen
    assert float(aux) > 0


def test_moe_lm_vs_dense_equal_when_one_expert():
    """1 expert top-1 MoE == dense FFN with the same weights."""
    moe_cfg = _cfg(d_ff=0, n_kv_heads=4,
                   moe=MoEConfig(n_experts=1, top_k=1, d_ff_expert=128,
                                 pad_multiple=1, capacity_factor=4.0,
                                 expert_capacity_factor=4.0,
                                 aux_loss_weight=0.0))
    dense_cfg = _cfg(n_kv_heads=4)
    mp = init_lm_params(moe_cfg, jax.random.PRNGKey(2))
    dp = init_lm_params(dense_cfg, jax.random.PRNGKey(2))
    # copy expert weights into the dense slots
    dp["layers"]["w_gate"] = mp["layers"]["we_gate"][:, 0]
    dp["layers"]["w_in"] = mp["layers"]["we_in"][:, 0]
    dp["layers"]["w_out"] = mp["layers"]["we_out"][:, 0]
    for k2 in ("attn_norm", "mlp_norm", "wq", "wk", "wv", "wo"):
        dp["layers"][k2] = mp["layers"][k2]
    dp["embed"], dp["final_norm"] = mp["embed"], mp["final_norm"]
    dp["lm_head"] = mp["lm_head"]
    toks = jnp.asarray(RNG.integers(0, 128, (2, 8)), jnp.int32)
    labels = jnp.roll(toks, -1, 1)
    lm_m, _ = lm_loss(mp, moe_cfg, toks, labels, CTX)
    lm_d, _ = lm_loss(dp, dense_cfg, toks, labels, CTX)
    np.testing.assert_allclose(float(lm_m), float(lm_d), rtol=1e-5)


def test_pq_codec_roundtrip_error_shrinks_with_m():
    from repro.core.pq import train_pq
    x = RNG.normal(size=(600, 32)).astype(np.float32)
    errs = []
    for m in (2, 8, 16):
        codec = train_pq(x, m=m, k=64, iters=6)
        rec = codec.decode(codec.encode(x))
        errs.append(float(((rec - x) ** 2).sum(1).mean()))
    assert errs[0] > errs[1] > errs[2]


def test_pq_adc_orders_near_true_distance():
    from repro.core.pq import train_pq
    x = RNG.normal(size=(500, 16)).astype(np.float32)
    codec = train_pq(x, m=8, k=64, iters=8)
    codes = codec.encode(x)
    q = x[0] + 0.01 * RNG.normal(size=16).astype(np.float32)
    est = codec.estimate(codec.adc_table(q), codes)
    true = ((x - q) ** 2).sum(1)
    # top-10 by ADC should heavily overlap top-10 true
    a = set(np.argsort(est)[:10].tolist())
    t = set(np.argsort(true)[:10].tolist())
    assert len(a & t) >= 5


def test_nonparam_ln_and_gemma_norm():
    from repro.models.layers import apply_norm, norm_param
    x = jnp.asarray(RNG.normal(size=(4, 16)) * 3 + 1, jnp.float32)
    y = apply_norm("nonparam_ln", x, None)
    np.testing.assert_allclose(np.asarray(y.mean(-1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y.std(-1)), 1.0, atol=1e-2)
    p = norm_param("rmsnorm_gemma", 16)
    assert p is not None and float(p.sum()) == 0.0  # (1+w) convention
    y2 = apply_norm("rmsnorm_gemma", x, p)
    assert np.isfinite(np.asarray(y2)).all()
