"""Roofline summary from the dry-run artifact (deliverable g).

Reads dryrun_results.json (produced by `python -m repro.launch.dryrun`) and
emits one row per (arch x shape x mesh) with the three roofline terms.
Skipped gracefully when the dry-run has not been executed yet.
"""
import json
import os

from . import common


def run(path: str = "dryrun_results.json") -> None:
    if not os.path.exists(path):
        common.emit("roofline.skipped", 0, f"no {path}; run repro.launch.dryrun")
        return
    with open(path) as f:
        results = json.load(f)
    for key, rec in sorted(results.items()):
        if rec.get("status") != "ok":
            common.emit(f"roofline.{key}.FAILED", -1,
                        rec.get("error", "")[:80])
            continue
        rl = rec["roofline"]
        mem = rec["mem"]["total_bytes"] / 2 ** 30
        common.emit(
            f"roofline.{key}", round(rl["t_bound_s"] * 1e6, 1),
            f"bneck={rl['bottleneck']};mfu={rl['mfu_bound']:.3f};"
            f"useful={rl['useful_ratio']:.2f};mem={mem:.1f}GiB;"
            f"fits={rec['mem']['fits_hbm']}")


if __name__ == "__main__":
    run()
