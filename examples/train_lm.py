"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpoint/restart (deliverable b).

    PYTHONPATH=src python examples/train_lm.py --steps 300

~100M params: 8 layers, d_model=512, 8 heads, d_ff=2048, vocab=32000.
On the CPU container this runs a reduced step count by default; pass
--steps 300 for the full demo.  Restart safety: kill it mid-run and rerun
-- it resumes from the latest checkpoint and the loss curve continues
exactly (stateless step-indexed data, train/ft.py).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.data.synthetic import lm_batch
    from repro.models.transformer import (LMConfig, ShardCtx, init_lm_params,
                                          lm_loss)
    from repro.train import checkpoint as ckpt
    from repro.train.ft import FTConfig, run_loop, resume_or_init
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import init_train_state, make_train_step

    cfg = LMConfig(name="lm100m", n_layers=8, d_model=512, n_heads=8,
                   n_kv_heads=8, d_head=64, d_ff=2048, vocab=32000,
                   remat="none", loss_chunks=8, dtype="float32")
    ctx = ShardCtx(mesh=None)
    opt = AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps)

    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch["tokens"], batch["labels"], ctx)

    def batch_fn(step):
        t, l = lm_batch(step, args.batch, args.seq, cfg.vocab, seed=0)
        return {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}

    def init_fn():
        params = init_lm_params(cfg, jax.random.PRNGKey(0))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        print(f"params: {n/1e6:.1f}M")
        return init_train_state(params, opt)

    ft = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=25)
    state = resume_or_init(init_fn, ft)
    start = int(state["step"])
    if start:
        print(f"resumed from step {start}")
    step_fn = make_train_step(loss_fn, opt, donate=False)
    t0 = time.time()
    state, logs = run_loop(state, step_fn, batch_fn, args.steps, ft,
                           log_every=10)
    for s, m in logs:
        print(f"step {s:4d}  loss {m['loss']:.4f}  gnorm {m['grad_norm']:.2f}")
    dt = (time.time() - t0) / max(args.steps - start, 1)
    print(f"done ({dt*1e3:.0f} ms/step); checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
