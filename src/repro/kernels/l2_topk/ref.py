"""Pure-jnp oracle: exact squared-L2 k-nearest over a candidate set."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_topk_ref(queries: jnp.ndarray, base: jnp.ndarray, k: int):
    """queries (B, D), base (N, D) -> (dists (B, k), ids (B, k)), ascending."""
    q = queries.astype(jnp.float32)
    x = base.astype(jnp.float32)
    d = (jnp.sum(q * q, 1, keepdims=True) + jnp.sum(x * x, 1)[None, :]
         - 2.0 * q @ x.T)
    d = jnp.maximum(d, 0.0)
    neg, ids = jax.lax.top_k(-d, k)
    return -neg, ids
