"""Sharded scatter-gather serving front-end (compatibility shim).

Historically this module *was* the serving layer: a single-process Python
loop over sub-index engines.  That loop now lives in
`repro.serve.runtime` as a placed, instruction-stream runtime
(`ServeRuntime`: ShardPlacement -> SCATTER/RUN/GATHER/MERGE ->
deadline scheduler); `ShardedFrontend` survives as a thin shim so every
existing caller -- and every existing test -- exercises the new path with
the old API and bit-identical results.

The corpus is partitioned into S sub-corpora; each shard owns an
independently built BAMG sub-index wrapped in a `BatchedANNEngine`
(elastic: adding/removing a shard rebuilds only the moved partition).
A query batch makes ONE batched engine call per shard -- not a Python loop
over queries -- and the per-shard local top-k are mapped to global ids and
merged with a single top-k pass.

Degraded mode: a shard whose engine raises is marked down and its
RUN/GATHER instructions masked -- the merge proceeds over the surviving
shards and the answer is a partial top-k (flagged via
`ServeStatus.degraded` when `search_batch(..., with_status=True)`).
`health()` snapshots per-shard state; `mark_up()` restores a shard after
repair (e.g. a blue/green re-deploy of the failed sub-index).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.engine import BAMGIndex, BAMGParams

from .ann_engine import BatchedANNEngine, EngineConfig
from .runtime import ServeRuntime, ServeStatus, ShardHealth  # noqa: F401
from .runtime import build_shard_fleet
# legacy private names, still imported by tests and benchmarks
from .runtime.instructions import merge_topk as _merge_topk  # noqa: F401
from .runtime.instructions import pad_cols as _pad_cols  # noqa: F401


class ShardedFrontend:
    """Scatter-gather over S `BatchedANNEngine` sub-indexes.

    `shard_vids[s]` maps shard-local row ids back to global corpus ids.
    All serving flows through a `ServeRuntime` (the compiled instruction
    stream); this class only adapts the legacy constructor/attribute
    surface.  Pass `mesh` / `n_replicas` to place the fleet on a device
    mesh with replicated shards.
    """

    def __init__(self, shard_vids: Sequence[np.ndarray],
                 engines: Sequence[BatchedANNEngine],
                 host_indexes: Optional[Sequence[BAMGIndex]] = None,
                 mesh=None, n_replicas: int = 1):
        self.runtime = ServeRuntime(shard_vids, engines,
                                    host_indexes=host_indexes,
                                    mesh=mesh, n_replicas=n_replicas)

    @classmethod
    def build(cls, x: np.ndarray, n_shards: int,
              params: Optional[BAMGParams] = None,
              config: Optional[EngineConfig] = None) -> "ShardedFrontend":
        """Round-robin partition + per-shard BAMG build."""
        vids, engines, indexes = build_shard_fleet(x, n_shards,
                                                   params=params,
                                                   config=config)
        return cls(vids, engines, host_indexes=indexes)

    # --- legacy attribute surface (delegates to the runtime) ----------------
    @property
    def shard_vids(self) -> list[np.ndarray]:
        return self.runtime.shard_vids

    @property
    def engines(self) -> list[BatchedANNEngine]:
        return self.runtime.engines

    @property
    def host_indexes(self):
        return self.runtime.host_indexes

    @property
    def _lut(self) -> list[np.ndarray]:
        return self.runtime._lut

    @property
    def _health(self) -> list[ShardHealth]:
        return self.runtime.placement.shard_health

    @property
    def n_shards(self) -> int:
        return self.runtime.n_shards

    # --- shard health -------------------------------------------------------
    def mark_down(self, shard: int, reason: str = "marked down") -> None:
        self.runtime.mark_down(shard, reason)

    def mark_up(self, shard: int) -> None:
        self.runtime.mark_up(shard)

    def health(self) -> dict:
        """Snapshot: overall up/down counts plus per-shard state."""
        return self.runtime.health()

    def search_batch(self, queries: np.ndarray, k: int,
                     with_status: bool = False, exclude=None):
        """(B, D) queries -> global (ids (B, k) int64, dists (B, k)).

        One walk of the runtime's compiled program: scatter, one batched
        call per live shard, local->global gather, single top-k merge.
        Marked-down shards are skipped by instruction masking; a shard
        whose engine raises is auto-marked down (skip-and-continue, never
        crash).  With every shard down the answer is all -1/+inf.
        `with_status=True` additionally returns a `ServeStatus` whose
        `degraded` flags mark answers that missed at least one shard.
        `exclude` forwards global tombstoned ids to the runtime.
        """
        return self.runtime.serve_batch(queries, k, with_status=with_status,
                                        exclude=exclude)
