"""Production mesh construction (DESIGN.md §4).

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") --
            the pod axis carries cross-pod data parallelism (compressed
            gradient exchange, train/compression.py).

A function, not a module constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax

from repro.utils.sharding import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model: int = 1, data: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = data if data is not None else n // model
    return make_mesh_compat((data, model), ("data", "model"))
