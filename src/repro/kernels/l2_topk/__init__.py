from .ops import l2_topk, l2_topk_rowwise  # noqa: F401
from .ref import l2_topk_ref  # noqa: F401
