"""Sharded scatter-gather serving front-end.

The corpus is partitioned into S sub-corpora; each shard owns an
independently built BAMG sub-index wrapped in a `BatchedANNEngine`
(elastic: adding/removing a shard rebuilds only the moved partition).
A query batch makes ONE batched engine call per shard -- not a Python loop
over queries -- and the per-shard local top-k are mapped to global ids and
merged with a single top-k pass.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.engine import BAMGIndex, BAMGParams
from .ann_engine import BatchedANNEngine, EngineConfig


class ShardedFrontend:
    """Scatter-gather over S `BatchedANNEngine` sub-indexes.

    `shard_vids[s]` maps shard-local row ids back to global corpus ids.
    """

    def __init__(self, shard_vids: Sequence[np.ndarray],
                 engines: Sequence[BatchedANNEngine],
                 host_indexes: Optional[Sequence[BAMGIndex]] = None):
        assert len(shard_vids) == len(engines)
        self.shard_vids = [np.asarray(v, np.int64) for v in shard_vids]
        self.engines = list(engines)
        # host BAMGIndex per shard (comparisons / persistence); None when
        # the frontend was assembled from bare engine arrays
        self.host_indexes = list(host_indexes) if host_indexes else None
        # -1 (absent) local ids pass through as global -1 via a sentinel row
        self._lut = [np.concatenate([v, [-1]]) for v in self.shard_vids]

    @classmethod
    def build(cls, x: np.ndarray, n_shards: int,
              params: Optional[BAMGParams] = None,
              config: EngineConfig = EngineConfig()) -> "ShardedFrontend":
        """Round-robin partition + per-shard BAMG build."""
        params = params or BAMGParams()
        owner = np.arange(len(x)) % n_shards
        vids, engines, indexes = [], [], []
        if len(x) < 3 * n_shards:
            raise ValueError(
                f"n_shards={n_shards} leaves <3 points per shard for a "
                f"{len(x)}-point corpus; a graph sub-index needs >=3 points")
        for s in range(n_shards):
            ids = np.nonzero(owner == s)[0]
            ns = len(ids)
            # small shards: graph-build degree/knn params cannot exceed n-1
            # (same clamp as navgraph's recursive layer builds)
            p = dataclasses.replace(
                params, seed=s, r=min(params.r, ns - 1),
                knn_k=min(params.knn_k, ns - 1),
                l_build=min(params.l_build, max(4, ns)))
            idx = BAMGIndex.build(x[ids], p)
            vids.append(ids)
            indexes.append(idx)
            engines.append(BatchedANNEngine.from_index(idx, config))
        return cls(vids, engines, host_indexes=indexes)

    @property
    def n_shards(self) -> int:
        return len(self.engines)

    def search_batch(self, queries: np.ndarray, k: int):
        """(B, D) queries -> global (ids (B, k) int64, dists (B, k)).

        Scatter: one batched call per shard.  Gather: map local->global ids
        and merge the (B, S*k) candidates with a single top-k.
        """
        queries = np.atleast_2d(queries)
        all_ids, all_d = [], []
        for lut, eng in zip(self._lut, self.engines):
            # a shard smaller than k contributes what it has, padded --
            # the global merge still sees plenty from the other shards
            ks = min(k, eng.rerank_capacity)
            ids_s, d_s = eng.search_batch(queries, ks)     # (B, ks) local
            if ks < k:
                b = len(ids_s)
                ids_s = np.concatenate(
                    [ids_s, np.full((b, k - ks), -1, ids_s.dtype)], axis=1)
                d_s = np.concatenate(
                    [d_s, np.full((b, k - ks), np.inf, d_s.dtype)], axis=1)
            all_ids.append(lut[ids_s])                     # -1 -> global -1
            all_d.append(d_s)
        ids = np.concatenate(all_ids, axis=1)              # (B, S*k)
        d = np.concatenate(all_d, axis=1)
        gd, gi = _merge_topk(d, k)
        gids = np.take_along_axis(ids, gi, axis=1)
        return np.where(np.isfinite(gd), gids, -1), gd


def _merge_topk(dists: np.ndarray, k: int):
    """Host-side (B, S*k) -> ascending (B, k); tiny, so plain numpy."""
    part = np.argpartition(dists, k - 1, axis=1)[:, :k]
    pd = np.take_along_axis(dists, part, axis=1)
    o = np.argsort(pd, axis=1, kind="stable")
    return np.take_along_axis(pd, o, axis=1), np.take_along_axis(part, o, axis=1)
