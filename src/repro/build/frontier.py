"""Batched construction frontier: whole-batch beam candidate collection.

Vamana and NSG construction both run, for every node p, a beam search from
the medoid to collect the candidate pool that RobustPrune consumes.  The
host implementation (`repro.core.graph_build.greedy_search`) is a Python
heapq loop per node; this module runs the beam for a whole node batch at
once with only fixed-shape array ops, using the (B, L) sorted-pool pattern
of the serving engine (`repro.serve.ann_engine`) tuned for the build side:

- each hop expands the `width` best unexpanded candidates of every row at
  once (DiskANN-style beam width), cutting the sequential hop count by
  `width` for the same number of expansions;
- a (B, N) `seen` bitmask (the host's `seen` set) filters re-proposed
  nodes *before* the merge truncates -- in clustered corpora the
  neighborhoods of one hop's expansions overlap heavily, and truncating
  before deduplication would collapse the pool to a handful of distinct
  ids (build batches are a few hundred rows over a bounded corpus, so the
  mask is cheap; shard the build before it isn't);
- neighbor scoring is exact squared L2 in dot form,
  ``||w||^2 - 2 q.w + ||q||^2`` with precomputed corpus norms -- one
  batched einsum per hop (the candidate *pools* only order the beam; the
  pruner re-derives its distances, `repro.build.prune`);
- the merge is one `top_k` by distance over (B, L + width*R): candidates
  are already distinct and disjoint from the pool, so no sort-based
  dedupe is needed.

Termination differs from the host loop: the host stops when the best heap
candidate exceeds the worst of `ef` expanded results, the batch runs a
fixed hop count so every row's shape is static.  Like the host, the pool
it returns is the *expanded* (visited) set, ascending by distance.

`frontier_pools(backend="fused*")` instead runs the hops through the
fused beam-hop kernel (`repro.kernels.beam_fused`, exact-L2 mode) -- the
same VMEM-resident program the serving engine uses, at width 1 with a
`pool_merge`-invariant pool instead of the seen-mask merge.  Its per-hop
frontier trace *is* the visited set.  The pool semantics differ slightly
(the ranked merge dedupes against the live pool only, where the seen mask
dedupes against everything ever proposed), so the two backends agree
exactly when the pool is large enough that nothing useful is evicted --
the regime the 1.5x pool slack targets -- and remain recall-equivalent
otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .chunking import map_chunks
from .pool import pool_merge

# frontier_pools backend -> the beam_hops backend the fused path pins
# (the `fused_stream*` modes run the HBM-streaming double-buffered
# program, for corpora whose resident footprint exceeds the VMEM budget)
_FUSED = {"fused": "auto", "fused_pallas": "pallas",
          "fused_interpret": "interpret", "fused_ref": "ref",
          "fused_stream": "stream", "fused_stream_interpret":
          "stream_interpret"}


@functools.partial(jax.jit, static_argnames=("ef", "max_hops", "width"))
def _frontier_batch(x, n2, adj, entries, queries,
                    ef: int, max_hops: int, width: int):
    """One jitted beam for a query batch over a padded graph.

    x (N, D) f32; n2 (N,) precomputed squared norms; adj (N, R) int32 with
    -1 pad; entries (E,) int32 shared seed ids; queries (B, D).  Returns
    (ids (B, max_hops*width) int32 with -1 pad, dists ascending): every
    node the beam *expanded*, the analog of greedy_search's visited set
    (which the host prune consumes in full, not just the best ef).
    """
    b = queries.shape[0]
    n, r = adj.shape
    q = queries.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=1)                             # (B,)
    rows = jnp.arange(b)
    sentinel = jnp.iinfo(jnp.int32).max
    # beam pool slack: the host heap never forgets a pushed candidate, so
    # it can expand nodes ranked past ef once closer ones exhaust; a
    # 1.5x pool keeps those reachable instead of evicting them
    pl = ef + ef // 2

    def score(ids):
        """Exact squared L2 of each row's query to corpus ids (B, C)."""
        vecs = x[jnp.clip(ids, 0)]                          # (B, C, D)
        d = (n2[jnp.clip(ids, 0)] - 2.0 * jnp.einsum("bcd,bd->bc", vecs, q)
             + qn[:, None])
        return jnp.where(ids >= 0, jnp.maximum(d, 0.0), jnp.inf)

    def merge(pool_ids, pool_d, pool_exp, cand_ids, cand_d):
        """top-pl of pool + candidates by distance (candidates are already
        distinct and unseen, so no dedupe pass is needed)."""
        ids = jnp.concatenate([pool_ids, cand_ids], axis=1)
        d = jnp.concatenate([pool_d, cand_d], axis=1)
        exp = jnp.concatenate(
            [pool_exp, jnp.zeros(cand_ids.shape, bool)], axis=1)
        neg, o = jax.lax.top_k(-d, pl)                      # ascending d
        return (jnp.take_along_axis(ids, o, axis=1), -neg,
                jnp.take_along_axis(exp, o, axis=1))

    # --- seed the pool with the shared entries
    seen = jnp.zeros((b, n), bool).at[:, entries].set(True)
    seed_ids = jnp.broadcast_to(entries[None, :],
                                (b, entries.shape[0])).astype(jnp.int32)
    pool_ids = jnp.full((b, pl), -1, jnp.int32)
    pool_d = jnp.full((b, pl), jnp.inf, jnp.float32)
    pool_exp = jnp.zeros((b, pl), bool)
    pool_ids, pool_d, pool_exp = merge(pool_ids, pool_d, pool_exp,
                                       seed_ids, score(seed_ids))

    def step(state, _):
        pool_ids, pool_d, pool_exp, seen = state
        frontier_d = jnp.where(pool_exp | (pool_ids < 0), jnp.inf, pool_d)
        neg, jidx = jax.lax.top_k(-frontier_d, width)       # (B, W)
        has = jnp.isfinite(neg)
        v = jnp.where(has, jnp.take_along_axis(pool_ids, jidx, axis=1), 0)
        pool_exp = pool_exp.at[rows[:, None], jidx].max(has)
        nbrs = jnp.where(has[:, :, None], adj[v], -1)       # (B, W, R)
        nbrs = nbrs.reshape(b, width * r)
        # within-hop dedupe by id, then drop already-seen nodes (the pool
        # is a subset of seen, so candidates never duplicate pool entries)
        key = jnp.where(nbrs < 0, sentinel, nbrs)
        o = jnp.argsort(key, axis=1)
        key_s = jnp.take_along_axis(key, o, axis=1)
        ids_s = jnp.take_along_axis(nbrs, o, axis=1)
        dup = jnp.pad(key_s[:, 1:] == key_s[:, :-1], ((0, 0), (1, 0)))
        new = ((ids_s >= 0) & ~dup
               & ~seen[rows[:, None], jnp.clip(ids_s, 0)])
        cand = jnp.where(new, ids_s, -1)
        seen = seen.at[rows[:, None], jnp.clip(cand, 0)].max(new)
        pool_ids, pool_d, pool_exp = merge(pool_ids, pool_d, pool_exp,
                                           cand, score(cand))
        visited = (jnp.where(has, v, -1), jnp.where(has, -neg, jnp.inf))
        return (pool_ids, pool_d, pool_exp, seen), visited

    _, (vis_ids, vis_d) = jax.lax.scan(
        step, (pool_ids, pool_d, pool_exp, seen), None, length=max_hops)
    # visited (hops, B, W) -> (B, hops*W), ascending by distance: every
    # expanded node is returned even if later evicted from the beam pool
    # (greedy_search's visited dict has the same no-forgetting property)
    vis_ids = jnp.moveaxis(vis_ids, 0, 1).reshape(b, max_hops * width)
    vis_d = jnp.moveaxis(vis_d, 0, 1).reshape(b, max_hops * width)
    o = jnp.argsort(vis_d, axis=1, stable=True)
    return (jnp.take_along_axis(vis_ids, o, axis=1),
            jnp.take_along_axis(vis_d, o, axis=1))


@functools.partial(jax.jit, static_argnames=("ef", "max_hops", "backend"))
def _frontier_batch_fused(x, n2, adj, entries, queries,
                          ef: int, max_hops: int, backend: str):
    """Width-1 beam for a query batch through the fused hop kernel.

    Same operands and return contract as `_frontier_batch` with width=1:
    seed a (B, pl) `pool_merge`-invariant pool with the shared entries,
    run `max_hops` fused hops (`repro.kernels.beam_fused`, exact-L2
    scoring -- bit-identical to `_frontier_batch`'s `score`), and return
    the per-hop frontier trace stable-sorted ascending by distance.
    """
    # deferred: repro.build <-> repro.kernels.beam_fused import cycle
    # (beam_fused.ref consumes repro.build.pool)
    from repro.kernels.beam_fused.ops import beam_hops
    b = queries.shape[0]
    q = queries.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=1)
    pl = ef + ef // 2                                    # same beam slack
    seed_ids = jnp.broadcast_to(entries[None, :],
                                (b, entries.shape[0])).astype(jnp.int32)
    vecs = x[jnp.clip(seed_ids, 0)]
    sd = (n2[jnp.clip(seed_ids, 0)]
          - 2.0 * jnp.einsum("bcd,bd->bc", vecs, q) + qn[:, None])
    sd = jnp.where(seed_ids >= 0, jnp.maximum(sd, 0.0), jnp.inf)
    pool_ids = jnp.full((b, pl), -1, jnp.int32)
    pool_d = jnp.full((b, pl), jnp.inf, jnp.float32)
    pool_exp = jnp.zeros((b, pl), bool)
    pool_ids, pool_d, pool_exp = pool_merge(
        pool_ids, pool_d, pool_exp, seed_ids, sd, pl)
    _, _, _, _, tid, td, _, _ = beam_hops(
        adj, pool_ids, pool_d, pool_exp, max_hops,
        x=x, n2=n2, queries=q, backend=backend)
    o = jnp.argsort(td, axis=1, stable=True)
    return (jnp.take_along_axis(tid, o, axis=1),
            jnp.take_along_axis(td, o, axis=1))


def default_hops(ef: int, width: int) -> int:
    """Hop count giving ~ef + 2*width expansions -- the host loop expands
    ~ef nodes before its bound check fires."""
    return -(-ef // width) + 2


def frontier_pools(
    x: np.ndarray,
    adj: np.ndarray,
    entries,
    node_ids: np.ndarray,
    ef: int,
    max_hops: int | None = None,
    batch: int = 256,
    width: int = 8,
    device_arrays: tuple | None = None,
    backend: str = "batched",
) -> tuple[np.ndarray, np.ndarray]:
    """Candidate pools for a set of build nodes, chunked over fixed batches.

    Runs the batched beam from `entries` toward x[node_ids] and returns
    (ids (n, max_hops*width) int32 with -1 pad, dists ascending) -- each
    row is the beam's expanded/visited set, the host prune's candidate
    source.  The last chunk is padded up to `batch` so one compilation
    serves the whole build; independent chunks are pipelined two-deep.
    `device_arrays` optionally carries preloaded `(x, n2, adj)` jnp arrays
    so repeated calls (the Vamana batch loop) skip the host->device upload
    of x.

    backend: "batched" (the seen-mask beam above) or one of
    "fused"/"fused_pallas"/"fused_interpret"/"fused_ref"/"fused_stream"/
    "fused_stream_interpret" -- the fused beam-hop kernel at width 1
    (`width` is ignored; hop count defaults to the width-1
    `default_hops`, so pass `max_hops` to bound it).  The `fused_stream*`
    modes run the HBM-streaming double-buffered program, for build
    corpora whose resident footprint exceeds the VMEM budget.
    """
    if backend != "batched" and backend not in _FUSED:
        raise ValueError(f"frontier backend must be 'batched' or one of "
                         f"{sorted(_FUSED)}, got {backend!r}")
    node_ids = np.asarray(node_ids, np.int64)
    entries = np.asarray(entries, np.int32).ravel()
    width = max(1, min(width, ef)) if backend == "batched" else 1
    if max_hops is None:
        max_hops = default_hops(ef, width)
    if device_arrays is not None:
        xj, n2, adjj = device_arrays
    else:
        xj = jnp.asarray(x, jnp.float32)
        n2 = jnp.sum(xj * xj, axis=1)
        adjj = jnp.asarray(adj, jnp.int32)
    ej = jnp.asarray(entries)
    out_w = max_hops * width
    out_ids = np.empty((len(node_ids), out_w), np.int32)
    out_d = np.empty((len(node_ids), out_w), np.float32)

    def run(s):
        chunk = node_ids[s : s + batch]
        pad = batch - len(chunk)
        qs = x[chunk]
        if pad:
            qs = np.concatenate([qs, np.zeros((pad, x.shape[1]), x.dtype)], 0)
        if backend == "batched":
            ids, d = _frontier_batch(xj, n2, adjj, ej,
                                     jnp.asarray(qs, jnp.float32),
                                     ef=ef, max_hops=max_hops, width=width)
        else:
            ids, d = _frontier_batch_fused(xj, n2, adjj, ej,
                                           jnp.asarray(qs, jnp.float32),
                                           ef=ef, max_hops=max_hops,
                                           backend=_FUSED[backend])
        out_ids[s : s + len(chunk)] = np.asarray(ids)[: len(chunk)]
        out_d[s : s + len(chunk)] = np.asarray(d)[: len(chunk)]

    map_chunks(list(range(0, len(node_ids), batch)), run)
    return out_ids, out_d
