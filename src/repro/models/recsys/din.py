"""DIN: Deep Interest Network [arXiv:1706.06978].

Assigned config: embed_dim=18, behavior seq_len=100, attention MLP 80-40,
final MLP 200-80, target attention interaction.

Per-behavior feature = [item_emb || cate_emb] (2*18=36).  Target attention
scores each history behavior against the candidate with
MLP([e_h, e_t, e_h - e_t, e_h * e_t]) (80-40-1, unnormalized weights as in
the paper), producing the user-interest vector; final MLP
(interest || target || sum-pooled history) -> 200 -> 80 -> 1 -> sigmoid.

Four serving shapes:
  train_batch / serve_p99 / serve_bulk -- the scoring step below.
  retrieval_cand -- 1 query vs 10^6 candidates: scored as a cascade:
    (a) interest-vector vs candidate-embedding distances via the fused
        l2_topk kernel (the paper's exact workload -- the BAMG engine
        serves the same query in examples/din_retrieval.py), then
    (b) full DIN re-rank of the top candidates.

Embedding tables are row-sharded over `model`
(models/recsys/embedding.py); batch shards over data axes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..layers import dense_init
from .embedding import embedding_bag, sharded_lookup


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)
    n_items: int = 1_000_000
    n_cates: int = 1024
    rerank_k: int = 1024     # cascade width for retrieval_cand

    @property
    def d_feat(self) -> int:
        return 2 * self.embed_dim  # item || cate


def init_params(cfg: DINConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 12)
    d = cfg.d_feat
    attn_sizes = (4 * d,) + tuple(cfg.attn_mlp) + (1,)
    mlp_sizes = (3 * d,) + tuple(cfg.mlp) + (1,)
    return {
        "item_emb": jax.random.normal(ks[0], (cfg.n_items, cfg.embed_dim)) * 0.05,
        "cate_emb": jax.random.normal(ks[1], (cfg.n_cates, cfg.embed_dim)) * 0.05,
        "attn": {"w": [dense_init(ks[2 + i], attn_sizes[i], attn_sizes[i + 1])
                       for i in range(len(attn_sizes) - 1)],
                 "b": [jnp.zeros((attn_sizes[i + 1],))
                       for i in range(len(attn_sizes) - 1)]},
        "mlp": {"w": [dense_init(ks[6 + i], mlp_sizes[i], mlp_sizes[i + 1])
                      for i in range(len(mlp_sizes) - 1)],
                "b": [jnp.zeros((mlp_sizes[i + 1],))
                      for i in range(len(mlp_sizes) - 1)]},
    }


def param_specs(cfg: DINConfig, mesh: Optional[Mesh], model_axis="model"):
    if mesh is None:
        return jax.tree.map(lambda _: None, jax.eval_shape(
            lambda: init_params(cfg, jax.random.PRNGKey(0))))
    rep = P()
    return {
        "item_emb": P(model_axis, None),
        "cate_emb": P(model_axis, None),
        "attn": {"w": [rep] * (len(cfg.attn_mlp) + 1),
                 "b": [rep] * (len(cfg.attn_mlp) + 1)},
        "mlp": {"w": [rep] * (len(cfg.mlp) + 1),
                "b": [rep] * (len(cfg.mlp) + 1)},
    }


def _mlp(p, x, final_sigmoid=False):
    n = len(p["w"])
    for i in range(n):
        x = x @ p["w"][i].astype(x.dtype) + p["b"][i].astype(x.dtype)
        if i < n - 1:
            x = jax.nn.relu(x)
    return jax.nn.sigmoid(x) if final_sigmoid else x


def _behavior_embed(params, cfg, items, cates, mesh, model_axis, batch_axes):
    ei = sharded_lookup(params["item_emb"], items, mesh, model_axis, batch_axes)
    ec = sharded_lookup(params["cate_emb"], cates, mesh, model_axis, batch_axes)
    return jnp.concatenate([ei, ec], axis=-1)         # (..., 2*embed)


def target_attention(params, e_hist, e_tgt, hist_len):
    """e_hist (B, S, d), e_tgt (B, d) -> interest (B, d).

    Unnormalized attention weights (paper); invalid positions masked to 0."""
    b, s, d = e_hist.shape
    et = jnp.broadcast_to(e_tgt[:, None, :], (b, s, d))
    feats = jnp.concatenate([e_hist, et, e_hist - et, e_hist * et], -1)
    w = _mlp(params["attn"], feats)[..., 0]           # (B, S)
    mask = jnp.arange(s)[None, :] < hist_len[:, None]
    w = jnp.where(mask, w, 0.0)
    return jnp.einsum("bs,bsd->bd", w, e_hist)


def forward_scores(params, cfg: DINConfig, batch, mesh=None,
                   model_axis="model", batch_axes=()) -> jnp.ndarray:
    """CTR logits (B,). batch: hist_items/hist_cates (B, S), hist_len (B,),
    target_item/target_cate (B,)."""
    e_hist = _behavior_embed(params, cfg, batch["hist_items"],
                             batch["hist_cates"], mesh, model_axis, batch_axes)
    e_tgt = _behavior_embed(params, cfg, batch["target_item"],
                            batch["target_cate"], mesh, model_axis, batch_axes)
    interest = target_attention(params, e_hist, e_tgt, batch["hist_len"])
    # sum-pooled history via embedding_bag (take + segment_sum)
    b, s = batch["hist_items"].shape
    seg = jnp.repeat(jnp.arange(b, dtype=jnp.int32), s)
    mask_ids = jnp.where(
        jnp.arange(s)[None, :] < batch["hist_len"][:, None],
        batch["hist_items"], -1).reshape(-1)
    pooled_i = embedding_bag(params["item_emb"], mask_ids, seg, b, mode="mean")
    mask_cates = jnp.where(
        jnp.arange(s)[None, :] < batch["hist_len"][:, None],
        batch["hist_cates"], -1).reshape(-1)
    pooled_c = embedding_bag(params["cate_emb"], mask_cates, seg, b, mode="mean")
    pooled = jnp.concatenate([pooled_i, pooled_c], -1)
    x = jnp.concatenate([interest, e_tgt, pooled], -1)
    return _mlp(params["mlp"], x)[..., 0]             # logits


def loss_fn(params, cfg: DINConfig, batch, mesh=None, model_axis="model",
            batch_axes=()) -> jnp.ndarray:
    logits = forward_scores(params, cfg, batch, mesh, model_axis, batch_axes)
    y = batch["label"].astype(jnp.float32)
    logits = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ---------------------------------------------------------------------------
# Retrieval cascade (retrieval_cand shape)
# ---------------------------------------------------------------------------
def user_interest_vector(params, cfg: DINConfig, batch, mesh=None,
                         model_axis="model", batch_axes=()) -> jnp.ndarray:
    """Query-side vector for ANN retrieval: mean-pooled behavior embedding
    (target-independent -- usable against an item-embedding index)."""
    e_hist = _behavior_embed(params, cfg, batch["hist_items"],
                             batch["hist_cates"], mesh, model_axis, batch_axes)
    s = e_hist.shape[1]
    mask = (jnp.arange(s)[None, :] < batch["hist_len"][:, None])
    pooled = jnp.sum(jnp.where(mask[..., None], e_hist, 0.0), 1)
    return pooled / jnp.maximum(batch["hist_len"], 1)[:, None].astype(pooled.dtype)


def retrieval_step(params, cfg: DINConfig, batch, n_candidates: int,
                   k: int = 100, mesh=None, model_axis="model",
                   batch_axes=(), backend: str = "auto"):
    """Score 1..B queries against the first `n_candidates` rows of the item
    table: L2 shortlist in embedding space (fused l2_topk kernel, candidate
    rows stay model-sharded -- the matmul is fully local per shard) ->
    full DIN re-rank of the top rerank_k.

    This is exactly the paper's ANN workload; examples/din_retrieval.py
    serves the same query through the BAMG index instead of brute force.
    Returns (scores (B, k), item ids (B, k)).
    """
    from ...kernels.l2_topk import l2_topk
    # query = mean item-embedding of the history (item space, not concat --
    # the candidate side must live in the same space as the table rows)
    e_hist_items = sharded_lookup(params["item_emb"], batch["hist_items"],
                                  mesh, model_axis, batch_axes)
    s = e_hist_items.shape[1]
    hmask = jnp.arange(s)[None, :] < batch["hist_len"][:, None]
    q = (jnp.sum(jnp.where(hmask[..., None], e_hist_items, 0.0), 1)
         / jnp.maximum(batch["hist_len"], 1)[:, None].astype(e_hist_items.dtype))
    cand_table = (params["item_emb"] if n_candidates == params["item_emb"].shape[0]
                  else params["item_emb"][:n_candidates])   # model-sharded rows
    kk = min(cfg.rerank_k, n_candidates)
    _, short = l2_topk(q, cand_table, kk, backend=backend)  # (B, kk)
    b = q.shape[0]
    short_items = jnp.clip(short, 0, n_candidates - 1).astype(jnp.int32)

    def rerank_one(hist_i, hist_c, hlen, items_b):
        sub = {"hist_items": jnp.broadcast_to(hist_i, (kk,) + hist_i.shape),
               "hist_cates": jnp.broadcast_to(hist_c, (kk,) + hist_c.shape),
               "hist_len": jnp.broadcast_to(hlen, (kk,)),
               "target_item": items_b,
               "target_cate": (items_b % cfg.n_cates).astype(jnp.int32)}
        return forward_scores(params, cfg, sub, mesh=None)  # local rerank

    scores = jax.vmap(rerank_one)(batch["hist_items"], batch["hist_cates"],
                                  batch["hist_len"], short_items)  # (B, kk)
    top_s, top_i = jax.lax.top_k(scores, k)
    ids = jnp.take_along_axis(short_items, top_i, axis=1)
    return top_s, ids
