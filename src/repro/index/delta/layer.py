"""The in-memory delta layer: insert graph + tombstones over a frozen base.

FreshDiskANN's central idea, adapted to BAMG: the disk-resident index
never mutates.  Writes land in a small in-memory overlay --

- **Inserts** get a global id past the frozen corpus (`n_base + slot`)
  and are wired into the graph by incremental RobustPrune: a beam search
  over the *overlay* graph collects candidates, `robust_prune_inc`
  selects the new point's out-edges, and reverse edges are added to
  copy-on-write copies of the neighbors' adjacency rows (the frozen rows
  are never touched -- an overridden row shadows its base row only in
  the overlay).  Overflowing reverse rows are re-pruned with the same
  rule, so overlay degrees stay bounded by R like the base graph.
- **Deletes** are tombstones.  A tombstoned node stays fully navigable
  (removing it would sever monotonic paths through it); it is masked
  from results by every search path and physically removed at
  consolidation.

The overlay is exact-distance and RAM-resident by design: it holds the
write traffic of one consolidation epoch, not the corpus.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Optional

import heapq

import numpy as np

from repro.build.prune import robust_prune_inc

_LOG = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class DeltaParams:
    """Knobs of the overlay insert graph."""
    r: int = 32                # max overlay out-degree (default: base R)
    ef: int = 64               # beam width of the insert candidate search
    prune_alpha: float = 1.2   # RobustPrune slack for insert wiring
    max_steps: Optional[int] = None   # insert-beam hop cap (None = none)
    grow: float = 1.5          # geometric growth factor of the vector buffer
    # overlay pressure guard: warn (once per crossing) when the write
    # traffic -- inserts + tombstones -- exceeds this fraction of the
    # frozen base, the signal that a consolidation epoch is overdue
    warn_fraction: float = 0.25


class DeltaLayer:
    """Copy-on-write graph overlay + tombstone set over a frozen BAMGIndex.

    Global id space: base rows keep their ids `0..n_base-1`; the i-th
    inserted point is `n_base + i`.  `overrides` maps any id (base or
    delta) to its overlay adjacency row; ids without an override resolve
    to the frozen base row.
    """

    def __init__(self, base_index, params: Optional[DeltaParams] = None):
        base_x = np.asarray(base_index.x, np.float32)
        self.n_base, self.d = base_x.shape
        p = params or DeltaParams(r=base_index.params.r)
        self.params = p
        self._base_adj = np.asarray(base_index.graph.adj)
        self._base_blocks = np.asarray(base_index.graph.blocks)
        self._base_members = np.asarray(base_index.graph.members)
        self.entry = int(base_index.graph.entry)
        # growing vector buffer: base copy + delta appends (geometric)
        self._x = np.empty((int(self.n_base * p.grow) + 8, self.d), np.float32)
        self._x[:self.n_base] = base_x
        self._n = self.n_base
        self.overrides: dict[int, np.ndarray] = {}
        self.tombstones: set[int] = set()
        self._pressure_warned = False

    # --- structure ----------------------------------------------------------
    @property
    def n_total(self) -> int:
        """Ids in the global space (base + delta, tombstones included)."""
        return self._n

    @property
    def n_delta(self) -> int:
        return self._n - self.n_base

    def delta_ids(self) -> np.ndarray:
        """All delta-layer ids, tombstoned or not."""
        return np.arange(self.n_base, self._n, dtype=np.int64)

    def live_delta_ids(self) -> np.ndarray:
        ids = self.delta_ids()
        if not self.tombstones:
            return ids
        return ids[~np.isin(ids, np.fromiter(self.tombstones, np.int64,
                                             len(self.tombstones)))]

    def vector(self, vid: int) -> np.ndarray:
        return self._x[vid]

    def vectors(self, vids) -> np.ndarray:
        return self._x[np.asarray(vids, np.int64)]

    def neighbors(self, vid: int) -> np.ndarray:
        """Overlay adjacency row of `vid` (int64, no -1 padding)."""
        row = self.overrides.get(vid)
        if row is not None:
            return row
        nn = self._base_adj[vid]
        return nn[nn >= 0].astype(np.int64)

    def memory_bytes(self) -> int:
        ov = sum(r.nbytes for r in self.overrides.values())
        return self._x[:self._n].nbytes + ov + 8 * len(self.tombstones)

    @property
    def overlay_fraction(self) -> float:
        """Write traffic held by the overlay as a fraction of the frozen
        base: (inserts + tombstones) / n_base.  The overlay is sized for
        one consolidation epoch; past `params.warn_fraction` its exact-
        distance RAM search starts to dominate and freshness claims the
        blue/green consolidation was supposed to bound stop holding."""
        return (self.n_delta + len(self.tombstones)) / max(1, self.n_base)

    @property
    def overlay_pressure(self) -> bool:
        """Whether the overlay exceeds the configured pressure fraction."""
        return self.overlay_fraction > self.params.warn_fraction

    def _check_pressure(self) -> None:
        """Warn once per crossing (re-arms if the overlay shrinks, i.e.
        after consolidation swaps in a fresh layer)."""
        if not self.overlay_pressure:
            self._pressure_warned = False
            return
        if not self._pressure_warned:
            self._pressure_warned = True
            _LOG.warning(
                "delta overlay holds %d inserts + %d tombstones = %.1f%% of "
                "the %d-point base (warn_fraction=%.0f%%); consolidate soon",
                self.n_delta, len(self.tombstones),
                100.0 * self.overlay_fraction, self.n_base,
                100.0 * self.params.warn_fraction)

    # --- writes -------------------------------------------------------------
    def _grow_to(self, n: int) -> None:
        if n <= len(self._x):
            return
        cap = max(n, int(len(self._x) * self.params.grow) + 8)
        nx = np.empty((cap, self.d), np.float32)
        nx[:self._n] = self._x[:self._n]
        self._x = nx

    def insert(self, vec: np.ndarray) -> int:
        return int(self.insert_batch(np.asarray(vec)[None, :])[0])

    def insert_batch(self, vecs: np.ndarray) -> np.ndarray:
        """Wire a batch of new points into the overlay; returns their ids.

        Each point: beam-search the overlay for candidates, RobustPrune
        them into the point's out-edges, then add the reverse edges
        (copy-on-write; overflowing rows re-pruned).  Points of the same
        batch see their already-inserted batch-mates -- the overlay grows
        like a Vamana insert stream.
        """
        vecs = np.atleast_2d(np.asarray(vecs, np.float32))
        if vecs.shape[1] != self.d:
            raise ValueError(f"insert dim {vecs.shape[1]} != corpus {self.d}")
        p = self.params
        out = np.empty(len(vecs), np.int64)
        self._grow_to(self._n + len(vecs))
        for i, v in enumerate(vecs):
            vid = self._n
            self._x[vid] = v
            self._n += 1
            cand_ids, _ = self._beam(v, ef=p.ef, max_steps=p.max_steps)
            kept = robust_prune_inc(v, cand_ids, self._x[cand_ids],
                                    r=p.r, alpha=p.prune_alpha)
            self.overrides[vid] = kept
            for u in kept.tolist():
                row = self.neighbors(u)
                if vid in row:
                    continue
                row = np.append(row, vid)
                if len(row) > p.r:
                    row = robust_prune_inc(self._x[u], row, self._x[row],
                                           r=p.r, alpha=p.prune_alpha)
                self.overrides[u] = row
            out[i] = vid
        self._check_pressure()
        return out

    def delete(self, vid: int) -> None:
        """Tombstone an id (base or delta).  Navigability is preserved;
        the point just can never surface in a result again."""
        if not (0 <= vid < self._n):
            raise KeyError(f"delete: id {vid} not in [0, {self._n})")
        self.tombstones.add(int(vid))
        self._check_pressure()

    def delete_batch(self, vids) -> None:
        for v in np.asarray(vids, np.int64).tolist():
            self.delete(v)

    # --- reads --------------------------------------------------------------
    def _beam(self, q: np.ndarray, ef: int,
              max_steps: Optional[int] = None,
              entries: Optional[list] = None):
        """Block-aware best-first beam over the overlay with exact distances.

        Returns (visited_ids, visited_dists) in visit order -- the same
        contract as `repro.core.graph_build.greedy_search`, but (a)
        adjacency resolves through the copy-on-write overlay, so delta
        points are reachable and overridden base rows take effect, and
        (b) visiting a *base* node also expands its block siblings,
        matching Alg-4's block-first semantics: the refined BAMG
        adjacency is deliberately sparse because a block read scores
        every member for free, and a beam that ignores siblings loses
        the navigability the block layout provides.
        """
        seeds = entries if entries else [self.entry]
        cand: list[tuple[float, int]] = []
        seen = set()
        for e in seeds:
            dv = self._x[e] - q
            d0 = float(np.dot(dv, dv))
            if e not in seen:
                heapq.heappush(cand, (d0, int(e)))
                seen.add(int(e))
        visited: dict[int, float] = {}
        results: list[tuple[float, int]] = []   # max-heap via negation
        steps = 0
        while cand:
            d, v = heapq.heappop(cand)
            if len(results) >= ef and d > -results[0][0]:
                break
            visited[v] = d
            heapq.heappush(results, (-d, v))
            if len(results) > ef:
                heapq.heappop(results)
            nn = self.neighbors(v).tolist()
            if v < self.n_base:         # block siblings ride along (Alg-4)
                sib = self._base_members[self._base_blocks[v]]
                nn += [int(u) for u in sib[sib >= 0] if u != v]
            fresh = [u for u in nn if u not in seen]
            if fresh:
                diff = self._x[fresh] - q[None, :]
                dd = np.einsum("nd,nd->n", diff, diff)
                for u, du in zip(fresh, dd.tolist()):
                    seen.add(u)
                    heapq.heappush(cand, (float(du), u))
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        ids = np.fromiter(visited.keys(), np.int64, len(visited))
        ds = np.fromiter(visited.values(), np.float64, len(visited))
        return ids, ds

    def search(self, q: np.ndarray, k: int, ef: Optional[int] = None):
        """Top-k over the overlay graph (exact distances), tombstones
        masked.  Returns (ids (k,), dists (k,)) ascending -- may include
        *base* ids (the overlay contains the base graph), which the
        unified engine dedupes at merge."""
        q = np.asarray(q, np.float32)
        ids, ds = self._beam(q, ef=ef or max(self.params.ef, k))
        if self.tombstones:
            live = ~np.isin(ids, np.fromiter(self.tombstones, np.int64,
                                             len(self.tombstones)))
            ids, ds = ids[live], ds[live]
        o = np.argsort(ds, kind="stable")[:k]
        return ids[o], ds[o]
