"""Paper Table 2: intra / cross / total average out-degrees."""
from . import common


def run(regimes=("sift-like", "gist-like")) -> None:
    for regime in regimes:
        for name, idx in (("bamg", common.default_bamg(regime)),
                          ("starling", common.starling_index(regime))):
            d = idx.degree_stats()
            common.emit(f"table2_deg.{regime}.{name}", round(d["total"], 2),
                        f"in={d['intra']:.2f};out={d['cross']:.2f}")


if __name__ == "__main__":
    run()
