"""Mutable index structures layered over the frozen BAMG artifact.

`repro.core` builds and serves *frozen* indexes; `repro.serve` scales the
read path.  This package holds the structures that make the corpus
mutable while those paths keep serving:

- `delta` -- streaming freshness: an in-memory insert graph + tombstone
  set over a frozen BAMG base (`DeltaLayer`), a unified base+delta
  searcher (`FreshBAMGEngine`), background consolidation back into a
  full block-aware build (`consolidate`), and the read-write service
  facade that publishes consolidated builds through the blue/green
  deployment lifecycle (`FreshService`).
"""
from .delta import (DeltaLayer, DeltaParams, FreshBAMGEngine,  # noqa: F401
                    FreshService, consolidate)
