"""Exact BMRNG construction (§3) -- the O(n^2 log n) reference oracle.

Used on small point sets to (a) validate Theorem 1 (existence of monotonic
I/O paths) by property tests and (b) serve as the gold standard that the
scalable BAMG (core/bamg.py) approximates.

Rule 1: within each block, the induced subgraph is an MRNG.
Rule 2: a cross-block edge (u,q) is occluded iff some kept neighbor v of u
  - Case 1 (same block as u): lies in lune_{u,q};
  - Case 2 (other block): admits a monotone (toward q) intra-block path in
    v's block ending at a node inside lune_{u,q} (l >= 1, so v itself counts).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .distances import pairwise_sq_l2
from .rng_rules import mrng_edges


@dataclasses.dataclass
class BMRNG:
    adj: np.ndarray          # (n, n) bool, directed
    blocks: np.ndarray       # (n,) int32 block assignment L(v)
    dist: np.ndarray         # (n, n) cached squared distances


def _lune_reachable_in_block(
    adj: np.ndarray, d: np.ndarray, blocks: np.ndarray, v: int, u: int, q: int
) -> bool:
    """Case 2 test (exact): is there a monotone-toward-q path inside block
    B_{L(v)} starting at v whose endpoint lies in lune_{u,q}?

    We BFS over intra-block edges restricted to strictly-decreasing distance
    to q; if any visited node (including v) is in the lune, return True.
    """
    duq = d[u, q]
    blk = blocks[v]
    if d[u, v] < duq and d[v, q] < duq:
        return True  # path of length l=1: [v]
    n = adj.shape[0]
    seen = np.zeros(n, bool)
    seen[v] = True
    stack = [v]
    while stack:
        a = stack.pop()
        for b in np.nonzero(adj[a])[0]:
            b = int(b)
            if seen[b] or blocks[b] != blk:
                continue
            if d[b, q] >= d[a, q]:  # must be strictly monotone toward q
                continue
            if d[u, b] < duq and d[b, q] < duq:
                return True
            seen[b] = True
            stack.append(b)
    return False


def build_bmrng(x: np.ndarray, blocks: np.ndarray) -> BMRNG:
    """Exact BMRNG per §3.1/§3.2. x: (n,d) float32, blocks: (n,) int."""
    n = len(x)
    d = pairwise_sq_l2(x, x)
    blocks = np.asarray(blocks, np.int32)
    adj = np.zeros((n, n), bool)

    # --- Rule 1: per-block induced MRNG -----------------------------------
    for b in np.unique(blocks):
        members = np.nonzero(blocks == b)[0]
        if len(members) <= 1:
            continue
        sub = mrng_edges(x[members], d[np.ix_(members, members)])
        for i, gi in enumerate(members):
            for j, gj in enumerate(members):
                if sub[i, j]:
                    adj[gi, gj] = True

    # --- Rule 2: cross-block edges, candidates in ascending distance ------
    order = np.argsort(d, axis=1)
    for u in range(n):
        for q in order[u]:
            q = int(q)
            if q == u or blocks[q] == blocks[u]:
                continue
            duq = d[u, q]
            occluded = False
            for v in np.nonzero(adj[u])[0]:
                v = int(v)
                if blocks[v] == blocks[u]:
                    # Case 1: v in lune_{u,q}
                    if d[u, v] < duq and d[v, q] < duq:
                        occluded = True
                        break
                else:
                    # Case 2: monotone intra-block path in B_{L(v)} ending in lune
                    if _lune_reachable_in_block(adj, d, blocks, v, u, q):
                        occluded = True
                        break
            if not occluded:
                adj[u, q] = True
    return BMRNG(adj=adj, blocks=blocks, dist=d)


# --- Definition 3 checkers --------------------------------------------------
def monotonic_io_path(
    adj: np.ndarray, d: np.ndarray, blocks: np.ndarray, u: int, q: int
) -> list[int] | None:
    """Find a monotonic I/O path u -> q per Definition 3, or None.

    Definition 3 constrains (a) consecutive nodes inside one block segment
    to strictly decrease distance to q and (b) the *end* nodes of
    consecutive block segments to strictly decrease -- the edge that enters
    a new block MAY increase distance (the paper's Theorem-1 proof relies
    on this: the occluding path starts at an arbitrary neighbor v and only
    its endpoint y must be in the lune).

    Search state: (current node, distance bound of the previous segment's
    end node).  Intra-block moves need delta(b,q) < delta(a,q); crossing
    blocks is allowed only when delta(a,q) < bound (a closes its segment),
    resetting the intra-segment constraint at the entry node.
    """
    if u == q:
        return [u]
    n = adj.shape[0]
    dq = d[:, q]
    # state: (node, bound_id) where bound_id indexes the node whose distance
    # bounds this segment's required end (n == +inf for the first segment)
    bounds = np.concatenate([dq, [np.inf]])
    seen = set()
    start = (u, n)
    parent: dict = {start: None}
    stack = [start]
    seen.add(start)
    goal = None
    while stack:
        state = stack.pop()
        a, bid = state
        if a == q:
            goal = state
            break
        for b in np.nonzero(adj[a])[0]:
            b = int(b)
            if blocks[b] == blocks[a]:
                if dq[b] >= dq[a]:
                    continue  # intra-segment steps strictly decrease
                nxt = (b, bid)
            else:
                if dq[a] >= bounds[bid]:
                    continue  # a cannot close the current segment
                nxt = (b, a)
            if nxt not in seen:
                seen.add(nxt)
                parent[nxt] = state
                stack.append(nxt)
    if goal is None:
        return None
    path = []
    s = goal
    while s is not None:
        path.append(s[0])
        s = parent[s]
    return path[::-1]


def io_length(path: list[int], blocks: np.ndarray) -> int:
    """Number of blocks along the path (counting revisits as new I/Os)."""
    ios = 1
    for a, b in zip(path, path[1:]):
        if blocks[a] != blocks[b]:
            ios += 1
    return ios
