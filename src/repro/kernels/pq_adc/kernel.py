"""Pallas TPU kernel: PQ ADC as one-hot @ LUT matmuls on the MXU.

TPU adaptation (DESIGN.md §2): GPUs/CPUs do ADC with an in-register gather
LUT; TPUs have no fast gather, but the MXU eats (TN, K) x (K, TB) matmuls.
We loop over the M subspaces, turning each code column into a one-hot
(TN, K) tile and accumulating one-hot @ table_m^T into the (TN, TB) output.

Grid: (N // TN, B // TB).  VMEM per step ~ TN*M*4 (codes) + TB*M*K*4
(tables) + TN*K*4 (one-hot scratch) + TN*TB*4 (out): with TN=256, TB=8,
M=16, K=256 that is ~16 KB + 128 KB + 256 KB + 8 KB -- well inside VMEM.
K=256 and TN multiples of 128 keep the MXU fully aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_kernel(codes_ref, tables_ref, out_ref, *, m_sub: int, k_cent: int):
    """codes (TN, M) int32 | tables (TB, M, K) f32 -> out (TN, TB) f32."""
    tn = codes_ref.shape[0]
    tb = tables_ref.shape[0]
    codes = codes_ref[...]                      # (TN, M)
    col = jax.lax.broadcasted_iota(jnp.int32, (tn, k_cent), 1)

    def body(m, acc):
        c_m = jax.lax.dynamic_slice_in_dim(codes, m, 1, axis=1)   # (TN, 1)
        onehot = (col == c_m).astype(jnp.float32)                 # (TN, K)
        t_m = jax.lax.dynamic_slice_in_dim(tables_ref[...], m, 1, axis=1)
        t_m = t_m.reshape(tb, k_cent)                             # (TB, K)
        return acc + jax.lax.dot_general(
            onehot, t_m, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                   # (TN, TB)

    acc = jnp.zeros((tn, tb), jnp.float32)
    out_ref[...] = jax.lax.fori_loop(0, m_sub, body, acc)


def _adc_rowwise_kernel(codes_ref, tables_ref, out_ref, *, m_sub: int,
                        k_cent: int):
    """codes (TB, R, M) int32 | tables (TB, M, K) f32 -> out (TB, R) f32."""
    tb, r, _ = codes_ref.shape
    codes = codes_ref[...]                          # (TB, R, M)
    col = jax.lax.broadcasted_iota(jnp.int32, (tb, r, k_cent), 2)

    def body(m, acc):
        c_m = jax.lax.dynamic_slice_in_dim(codes, m, 1, axis=2)   # (TB, R, 1)
        onehot = (col == c_m).astype(jnp.float32)                 # (TB, R, K)
        t_m = jax.lax.dynamic_slice_in_dim(tables_ref[...], m, 1, axis=1)
        t_m = t_m.reshape(tb, 1, k_cent)                          # (TB, 1, K)
        return acc + jnp.sum(onehot * t_m, axis=2)                # (TB, R)

    out_ref[...] = jax.lax.fori_loop(
        0, m_sub, body, jnp.zeros((tb, r), jnp.float32))


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def pq_adc_rowwise_pallas(tables: jnp.ndarray, cand_codes: jnp.ndarray,
                          tile_b: int = 8,
                          interpret: bool = False) -> jnp.ndarray:
    """tables (B, M, K) f32, cand_codes (B, R, M) int -> (B, R) f32.

    B must be a multiple of tile_b (ops.py pads).  One grid step scores a
    query tile's gathered candidate codes against its own tables -- the
    per-hop neighbor-scoring stage of the batched beam, kept VMEM-local
    (the one-hot * table form of the MXU trick in `_adc_kernel`, reduced
    on the VPU because each row has a private table).
    """
    b, m_sub, k_cent = tables.shape
    r = cand_codes.shape[1]
    assert b % tile_b == 0, (b, tile_b)
    cand_codes = cand_codes.astype(jnp.int32)

    return pl.pallas_call(
        functools.partial(_adc_rowwise_kernel, m_sub=m_sub, k_cent=k_cent),
        grid=(b // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, r, m_sub), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_b, m_sub, k_cent), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        interpret=interpret,
    )(cand_codes, tables)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_b", "interpret"))
def pq_adc_pallas(tables: jnp.ndarray, codes: jnp.ndarray,
                  tile_n: int = 256, tile_b: int = 8,
                  interpret: bool = False) -> jnp.ndarray:
    """tables (B, M, K) f32, codes (N, M) int -> (B, N) f32 estimates.

    B and N must be multiples of the tiles (ops.py pads).
    """
    b, m_sub, k_cent = tables.shape
    n = codes.shape[0]
    assert n % tile_n == 0 and b % tile_b == 0, (n, b, tile_n, tile_b)
    codes = codes.astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_adc_kernel, m_sub=m_sub, k_cent=k_cent),
        grid=(n // tile_n, b // tile_b),
        in_specs=[
            pl.BlockSpec((tile_n, m_sub), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_b, m_sub, k_cent), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        interpret=interpret,
    )(codes, tables)
    return out.T  # (B, N)
