"""Batched fixed-shape index construction (build-side counterpart of
`repro.serve`).

The host builders in `repro.core.graph_build` / `repro.core.bamg` walk the
graph one node at a time through Python heaps -- exact, but serial.  This
package routes the three expensive construction stages through jit'd
fixed-shape array programs:

- `frontier`: whole-batch beam candidate collection ((B, L) insert-sort
  pool, exact squared-L2 scoring).
- `prune`: vectorized masked RobustPrune / MRNG edge selection.
- `bamg_refine`: Algorithm 2 with all intra-block monotone probes
  ((v, q) pairs) evaluated in one padded gather loop.
- `builder.GraphBuilder`: the facade consumed by the engine layer, with
  `backend="host"` preserving the numpy reference oracle.
"""
from .builder import BuildConfig, GraphBuilder
from .frontier import frontier_pools
from .pool import pool_merge
from .prune import robust_prune_batch, robust_prune_inc

__all__ = [
    "BuildConfig",
    "GraphBuilder",
    "frontier_pools",
    "pool_merge",
    "robust_prune_batch",
    "robust_prune_inc",
]
