"""Shared chunk pipeline for the batched build stages.

Every stage runs fixed-shape jitted chunks over a host-side work list;
independent chunks are pipelined two-deep (XLA releases the GIL while a
chunk executes, so a second worker overlaps host staging with device
compute).
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Sequence

WORKERS = 2


def map_chunks(starts: Sequence[int], run: Callable[[int], None]) -> None:
    """Run `run(start)` for every chunk start, two-deep when >1 chunk.

    `run` must write its results into preallocated per-chunk slices (the
    chunks are disjoint, so concurrent writes never alias)."""
    if len(starts) > 1:
        with ThreadPoolExecutor(WORKERS) as ex:
            list(ex.map(run, starts))
    else:
        for s in starts:
            run(s)
