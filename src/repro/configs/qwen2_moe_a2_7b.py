"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d=2048 16H (kv=16),
MoE: 60 routed top-4 (d_ff 1408) + 4 shared experts (fused 5632),
vocab=151936.

60 routed experts do not divide tp=16: padded to 64 with dead experts
(router logits -inf) -- models/moe.py.  Expert parallelism over `model`
with sort-based all_to_all dispatch.
"""
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

from .base import LM_SHAPES

ARCH_ID = "qwen2-moe-a2.7b"
FAMILY = "lm"
SHAPES = LM_SHAPES
TRAIN_ACCUM = 2  # microbatches for train_4k (memory lever)


def model_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(name=ARCH_ID + "-smoke", n_layers=2, d_model=128,
                        n_heads=4, n_kv_heads=4, d_head=32, d_ff=0,
                        vocab=512, remat="none", loss_chunks=2,
                        dtype="float32",
                        moe=MoEConfig(n_experts=6, top_k=2, d_ff_expert=64,
                                      n_shared=1, d_ff_shared=128,
                                      pad_multiple=8, groups=2))
    return LMConfig(
        name=ARCH_ID, n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_head=128, d_ff=0, vocab=151936, norm="rmsnorm", activation="silu",
        remat="full", loss_chunks=64,
        moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408, n_shared=4,
                      d_ff_shared=5632, pad_multiple=16, groups=16))
