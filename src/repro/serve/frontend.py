"""Sharded scatter-gather serving front-end.

The corpus is partitioned into S sub-corpora; each shard owns an
independently built BAMG sub-index wrapped in a `BatchedANNEngine`
(elastic: adding/removing a shard rebuilds only the moved partition).
A query batch makes ONE batched engine call per shard -- not a Python loop
over queries -- and the per-shard local top-k are mapped to global ids and
merged with a single top-k pass.

Degraded mode: a shard whose engine raises is marked down and skipped --
the merge proceeds over the surviving shards and the answer is a partial
top-k (flagged via `ServeStatus.degraded` when
`search_batch(..., with_status=True)`).  `health()` snapshots per-shard
state; `mark_up()` restores a shard after repair (e.g. a blue/green
re-deploy of the failed sub-index).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.engine import BAMGIndex, BAMGParams
from .ann_engine import BatchedANNEngine, EngineConfig


@dataclasses.dataclass
class ShardHealth:
    """Mutable per-shard serving state (one entry per engine)."""
    up: bool = True
    errors: int = 0          # engine calls that raised
    last_error: str = ""


@dataclasses.dataclass
class ServeStatus:
    """Per-batch serving report returned by `with_status=True`."""
    degraded: np.ndarray                 # (B,) bool: answer missed >=1 shard
    shards_up: int
    shards_down: tuple                   # shard indices skipped this batch


class ShardedFrontend:
    """Scatter-gather over S `BatchedANNEngine` sub-indexes.

    `shard_vids[s]` maps shard-local row ids back to global corpus ids.
    """

    def __init__(self, shard_vids: Sequence[np.ndarray],
                 engines: Sequence[BatchedANNEngine],
                 host_indexes: Optional[Sequence[BAMGIndex]] = None):
        assert len(shard_vids) == len(engines)
        self.shard_vids = [np.asarray(v, np.int64) for v in shard_vids]
        self.engines = list(engines)
        # host BAMGIndex per shard (comparisons / persistence); None when
        # the frontend was assembled from bare engine arrays
        self.host_indexes = list(host_indexes) if host_indexes else None
        # -1 (absent) local ids pass through as global -1 via a sentinel row
        self._lut = [np.concatenate([v, [-1]]) for v in self.shard_vids]
        self._health = [ShardHealth() for _ in self.engines]

    @classmethod
    def build(cls, x: np.ndarray, n_shards: int,
              params: Optional[BAMGParams] = None,
              config: EngineConfig = EngineConfig()) -> "ShardedFrontend":
        """Round-robin partition + per-shard BAMG build."""
        params = params or BAMGParams()
        owner = np.arange(len(x)) % n_shards
        vids, engines, indexes = [], [], []
        if len(x) < 3 * n_shards:
            raise ValueError(
                f"n_shards={n_shards} leaves <3 points per shard for a "
                f"{len(x)}-point corpus; a graph sub-index needs >=3 points")
        for s in range(n_shards):
            ids = np.nonzero(owner == s)[0]
            ns = len(ids)
            # small shards: graph-build degree/knn params cannot exceed n-1
            # (same clamp as navgraph's recursive layer builds)
            p = dataclasses.replace(
                params, seed=s, r=min(params.r, ns - 1),
                knn_k=min(params.knn_k, ns - 1),
                l_build=min(params.l_build, max(4, ns)))
            idx = BAMGIndex.build(x[ids], p)
            vids.append(ids)
            indexes.append(idx)
            engines.append(BatchedANNEngine.from_index(idx, config))
        return cls(vids, engines, host_indexes=indexes)

    @property
    def n_shards(self) -> int:
        return len(self.engines)

    # --- shard health -------------------------------------------------------
    def mark_down(self, shard: int, reason: str = "marked down") -> None:
        h = self._health[shard]
        h.up, h.last_error = False, reason

    def mark_up(self, shard: int) -> None:
        self._health[shard].up = True

    def health(self) -> dict:
        """Snapshot: overall up/down counts plus per-shard state."""
        down = [s for s, h in enumerate(self._health) if not h.up]
        return {"n_shards": self.n_shards,
                "shards_up": self.n_shards - len(down),
                "shards_down": down,
                "per_shard": [dataclasses.asdict(h) for h in self._health]}

    def search_batch(self, queries: np.ndarray, k: int,
                     with_status: bool = False):
        """(B, D) queries -> global (ids (B, k) int64, dists (B, k)).

        Scatter: one batched call per shard.  Gather: map local->global ids
        and merge the (B, S*k) candidates with a single top-k.

        A shard that is marked down -- or whose engine raises during the
        scatter -- is skipped and auto-marked down; the merge runs over the
        surviving shards (skip-and-continue, never crash).  With every shard
        down the answer is all -1/+inf.  `with_status=True` additionally
        returns a `ServeStatus` whose `degraded` flags mark answers that
        missed at least one shard.
        """
        queries = np.atleast_2d(queries)
        b = len(queries)
        all_ids, all_d, down = [], [], []
        for s, (lut, eng) in enumerate(zip(self._lut, self.engines)):
            if not self._health[s].up:
                down.append(s)
                continue
            # a shard smaller than k contributes what it has, padded --
            # the global merge still sees plenty from the other shards
            ks = min(k, eng.rerank_capacity)
            try:
                ids_s, d_s = eng.search_batch(queries, ks)  # (B, ks) local
            except Exception as e:  # dead shard: degrade, don't crash
                h = self._health[s]
                h.up, h.errors, h.last_error = False, h.errors + 1, repr(e)
                down.append(s)
                continue
            if ks < k:
                ids_s = np.concatenate(
                    [ids_s, np.full((b, k - ks), -1, ids_s.dtype)], axis=1)
                d_s = np.concatenate(
                    [d_s, np.full((b, k - ks), np.inf, d_s.dtype)], axis=1)
            all_ids.append(lut[ids_s])                     # -1 -> global -1
            all_d.append(d_s)
        if all_ids:
            ids = np.concatenate(all_ids, axis=1)          # (B, S*k)
            d = np.concatenate(all_d, axis=1)
        else:                                              # every shard down
            ids = np.full((b, k), -1, np.int64)
            d = np.full((b, k), np.inf, np.float64)
        gd, gi = _merge_topk(d, k)
        ids = _pad_cols(ids, k, -1)                        # match merge pad
        gids = np.take_along_axis(ids, gi, axis=1)
        gids = np.where(np.isfinite(gd), gids, -1)
        if not with_status:
            return gids, gd
        status = ServeStatus(
            degraded=np.full(b, bool(down)),
            shards_up=self.n_shards - len(down), shards_down=tuple(down))
        return gids, gd, status


def _pad_cols(a: np.ndarray, k: int, fill) -> np.ndarray:
    """Pad (B, C) to at least k columns with `fill` (no-op when C >= k)."""
    if a.shape[1] >= k:
        return a
    pad = np.full((a.shape[0], k - a.shape[1]), fill, a.dtype)
    return np.concatenate([a, pad], axis=1)


def _merge_topk(dists: np.ndarray, k: int):
    """Host-side (B, C) -> ascending (B, k); tiny, so plain numpy.

    C is normally S*k but can drop below k when shards are down or the
    fleet is small -- pad with +inf so argpartition's kth stays in range
    (the caller pads its id matrix the same way).
    """
    dists = _pad_cols(dists, k, np.inf)
    part = np.argpartition(dists, k - 1, axis=1)[:, :k]
    pd = np.take_along_axis(dists, part, axis=1)
    o = np.argsort(pd, axis=1, kind="stable")
    return np.take_along_axis(pd, o, axis=1), np.take_along_axis(part, o, axis=1)
