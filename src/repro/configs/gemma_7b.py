"""gemma-7b [arXiv:2403.08295; hf]: 28L d=3072 16H (kv=16, head_dim=256),
GeGLU ff=24576, vocab=256000, tied embeddings, (1+w) RMSNorm, embeddings
scaled by sqrt(d).  Full attention: long_500k decode runs with the
sequence-sharded cache; its 500k *prefill* would be quadratic and is not
claimed (DESIGN.md §5).
"""
from repro.models.transformer import LMConfig

from .base import LM_SHAPES

ARCH_ID = "gemma-7b"
FAMILY = "lm"
SHAPES = LM_SHAPES
TRAIN_ACCUM = 4  # microbatches for train_4k (memory lever)


def model_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(name=ARCH_ID + "-smoke", n_layers=2, d_model=128,
                        n_heads=4, n_kv_heads=4, d_head=32, d_ff=256,
                        vocab=512, norm="rmsnorm_gemma",
                        activation="gelu_tanh", tie_embeddings=True,
                        embed_scale=True, remat="none", loss_chunks=2,
                        dtype="float32")
    return LMConfig(
        name=ARCH_ID, n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
        d_head=256, d_ff=24576, vocab=256000, norm="rmsnorm_gemma",
        activation="gelu_tanh", tie_embeddings=True, embed_scale=True,
        rope_theta=10000.0, remat="full", loss_chunks=128)
