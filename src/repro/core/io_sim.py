"""Byte-accurate block-device simulator: pluggable caches, exact NIO, and a
pipelined I/O scheduler.

The container has no TPU and no SSD-under-test; the paper's primary I/O
metric (NIO = blocks read per query) is *exact* under simulation, and QPS is
reported through a calibrated cost model (DESIGN.md §2).  All three compared
systems (DiskANN, Starling-style, BAMG) run on this one simulator, so NIO
comparisons are apples-to-apples.

Two orthogonal metric domains (never mixed):

* **Accounting** (`IOStats`): NIO = blocks transferred from the device, plus
  cache hits.  Exact, deterministic, independent of queue depth or
  speculation.  This is the paper's headline number and the one every
  benchmark keys on; nothing in the timing domain may change it.
* **Timing** (`IOScheduler` + `CostModel`): simulated wall-clock.  A batched
  submission of b outstanding reads at queue depth `qd` (the io_uring-style
  knob) completes in ``ceil(b / qd) * read_us`` -- overlapped, not serial.
  The scheduler reports both `service_us` (pipelined) and `serial_us` (the
  strictly sequential cost of the same demand misses), so speedup is
  directly readable.  Speculative prefetches only fill otherwise-idle queue
  slots of a demand submission, so they can never make the pipelined time
  exceed the serial baseline, and they *never* touch the cache or the NIO
  counters -- when the speculation is right, the later demand read is free
  in the timing domain yet still counted as one NIO.

Cache policies (`CachePolicy`): `lru`, `fifo`, `clock` (second chance),
`2q` (A1in FIFO + A1out ghost + Am LRU), plus `PinnedCache`, a wrapper that
pins a fixed set of blocks (e.g. the navigation-graph entry blocks,
Starling-style) in memory forever; pins count against capacity.

Cost model (defaults match the paper's hardware: SATA SSD, 4 KB reads):
  t_query = NIO * t_read + t_cpu          (serial, qd=1)
  t_read  ~ 100 us per 4 KB random read (SATA SSD)

Resilience (beyond-paper; `repro.utils.faults`): a `BlockDevice` may carry a
seeded deterministic `FaultPlan` injecting read errors, dead blocks, torn
payloads, and latency spikes.  The scheduler then resolves every demand
miss through a *resilient read*: per-block CRC32 checksums catch torn
transfers, a bounded `RetryPolicy` (exponential backoff + jitter) retries
transient failures, `CostModel.timeout_us` abandons straggling attempts,
and `CostModel.hedge_us` races a duplicate (hedged) read against a spiking
one.  Accounting separation is preserved exactly: NIO still counts only
*successful* payload deliveries; wasted attempts land in the new `IOStats`
counters (`retries`, `read_errors`, `timeouts`, `checksum_failures`,
`hedges`, `hedge_wins`, `failed_reads`) and their time in the timing
domain.  With a zero-rate plan (or no plan) the resilient path is
bit-identical to the plain one -- same NIO, same cache state, same service
time (property-tested in tests/test_faults.py).  A block whose retry
budget is exhausted (or that is persistently dead) yields the
`READ_FAILED` sentinel instead of raising, so readers can degrade
(skip-and-continue) rather than crash.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Iterable, Optional, Sequence

from repro.utils.faults import (FaultPlan, RetryPolicy, corrupt_payload,
                                payload_checksum)

BLOCK_SIZE = 4096  # OS page / logical disk block

# Returned (never raised) for a block whose resilient read exhausted its
# retry budget or hit a persistently dead block: readers degrade, not crash.
READ_FAILED = object()

# Dedicated miss marker: a cached payload may legitimately be None (e.g. the
# placeholder span blocks of oversized coupled records), so None cannot mean
# "not cached".
_MISS = object()


@dataclasses.dataclass
class IOStats:
    """Per-query (or per-run) I/O accounting."""

    graph_reads: int = 0    # graph-index block fetches
    vector_reads: int = 0   # raw-vector block fetches (BAMG decoupled layout)
    cache_hits: int = 0
    # resilience counters (fault injection; all stay 0 on the clean path).
    # None of these enter `nio`: NIO counts only successful deliveries.
    retries: int = 0            # extra attempts beyond the first
    read_errors: int = 0        # attempts that failed outright
    timeouts: int = 0           # attempts abandoned at CostModel.timeout_us
    checksum_failures: int = 0  # torn payloads caught by the block checksum
    hedges: int = 0             # duplicate reads issued against stragglers
    hedge_wins: int = 0         # hedges that completed before the original
    failed_reads: int = 0       # reads that exhausted the retry budget

    @property
    def nio(self) -> int:
        """The paper's NIO: total data-block reads (graph + vector)."""
        return self.graph_reads + self.vector_reads

    @property
    def total_accesses(self) -> int:
        """Every read() call: device reads (misses) + cache hits."""
        return self.nio + self.cache_hits

    @property
    def hit_rate(self) -> float:
        t = self.total_accesses
        return self.cache_hits / t if t else 0.0

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)

    def add(self, other: "IOStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


# ---------------------------------------------------------------------------
# Cache policies
# ---------------------------------------------------------------------------
class CachePolicy:
    """Block-cache replacement policy.

    Contract: `get` returns the payload (updating recency state) or `_MISS`;
    `put` inserts after a miss, evicting per policy; `contains` is a pure
    lookup with NO side effects on recency (used by the scheduler to cost a
    submission without perturbing replacement order); `len(policy)` is the
    resident-block count and never exceeds `capacity`.
    """

    name = "base"

    def __init__(self, capacity: int):
        self.capacity = int(capacity)

    def get(self, key: int):
        raise NotImplementedError

    def put(self, key: int, value) -> None:
        raise NotImplementedError

    def contains(self, key: int) -> bool:
        raise NotImplementedError

    def clear(self) -> None:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def keys(self) -> list:
        """Resident block ids (diagnostics / property tests)."""
        raise NotImplementedError


class LRUCache(CachePolicy):
    """Evicts the least-recently-used block; hits refresh recency."""

    name = "lru"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._d: OrderedDict[int, object] = OrderedDict()

    def get(self, key: int):
        v = self._d.pop(key, _MISS)
        if v is _MISS:
            return _MISS
        self._d[key] = v  # most-recent position
        return v

    def put(self, key: int, value) -> None:
        if self.capacity <= 0:
            return
        if key in self._d:
            self._d.pop(key)
        self._d[key] = value
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def contains(self, key: int) -> bool:
        return key in self._d

    def clear(self) -> None:
        self._d.clear()

    def __len__(self) -> int:
        return len(self._d)

    def keys(self) -> list:
        return list(self._d.keys())


class FIFOCache(CachePolicy):
    """Evicts in insertion order; hits do not refresh."""

    name = "fifo"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._d: OrderedDict[int, object] = OrderedDict()

    def get(self, key: int):
        return self._d.get(key, _MISS)

    def put(self, key: int, value) -> None:
        if self.capacity <= 0:
            return
        if key in self._d:      # refresh payload, keep insertion position
            self._d[key] = value
            return
        self._d[key] = value
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def contains(self, key: int) -> bool:
        return key in self._d

    def clear(self) -> None:
        self._d.clear()

    def __len__(self) -> int:
        return len(self._d)

    def keys(self) -> list:
        return list(self._d.keys())


class ClockCache(CachePolicy):
    """CLOCK / second-chance: a circular buffer with one reference bit per
    resident block; the hand skips (and clears) referenced blocks."""

    name = "clock"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._d: dict[int, object] = {}
        self._ref: dict[int, bool] = {}
        self._ring: list[int] = []
        self._hand = 0

    def get(self, key: int):
        v = self._d.get(key, _MISS)
        if v is not _MISS:
            self._ref[key] = True
        return v

    def put(self, key: int, value) -> None:
        if self.capacity <= 0:
            return
        if key in self._d:
            self._d[key] = value
            self._ref[key] = True
            return
        if len(self._d) >= self.capacity:
            while True:
                k = self._ring[self._hand]
                if self._ref.get(k, False):
                    self._ref[k] = False
                    self._hand = (self._hand + 1) % len(self._ring)
                else:
                    del self._d[k]
                    del self._ref[k]
                    self._ring[self._hand] = key
                    self._hand = (self._hand + 1) % len(self._ring)
                    break
        else:
            self._ring.append(key)
        self._d[key] = value
        self._ref[key] = False  # newly inserted: one full sweep to earn a ref

    def contains(self, key: int) -> bool:
        return key in self._d

    def clear(self) -> None:
        self._d.clear()
        self._ref.clear()
        self._ring.clear()
        self._hand = 0

    def __len__(self) -> int:
        return len(self._d)

    def keys(self) -> list:
        return list(self._d.keys())


class TwoQCache(CachePolicy):
    """Simplified full-2Q: A1in (FIFO, ~25% of capacity) admits first-touch
    blocks; blocks evicted from A1in leave their id in the A1out ghost list
    (no payload, ~50% of capacity in ids); a miss whose id is ghosted is
    promoted into Am (LRU).  Scan-resistant: one-shot blocks die in A1in
    without disturbing the hot Am set."""

    name = "2q"

    def __init__(self, capacity: int, kin: float = 0.25, kout: float = 0.5):
        super().__init__(capacity)
        self._kin = max(1, int(round(capacity * kin))) if capacity > 0 else 0
        self._kout = max(1, int(round(capacity * kout))) if capacity > 0 else 0
        self._a1in: OrderedDict[int, object] = OrderedDict()
        self._a1out: OrderedDict[int, None] = OrderedDict()  # ghost ids only
        self._am: OrderedDict[int, object] = OrderedDict()

    def get(self, key: int):
        if key in self._am:
            v = self._am.pop(key)
            self._am[key] = v
            return v
        return self._a1in.get(key, _MISS)  # A1in hits keep FIFO position

    def put(self, key: int, value) -> None:
        if self.capacity <= 0:
            return
        if key in self._am:
            self._am.pop(key)
            self._am[key] = value
            return
        if key in self._a1in:
            self._a1in[key] = value
            return
        if key in self._a1out:               # reused after probation: hot
            self._a1out.pop(key)
            self._am[key] = value
        else:
            self._a1in[key] = value
        self._shrink()

    def _shrink(self) -> None:
        # Reclaim on demand (canonical 2Q): free slots mean no eviction;
        # under pressure, A1in over its target share yields the victim
        # (demoted to the A1out ghost), otherwise the coldest Am page goes.
        while len(self._a1in) + len(self._am) > self.capacity:
            if self._a1in and (len(self._a1in) > self._kin or not self._am):
                k, _ = self._a1in.popitem(last=False)
                self._a1out[k] = None
                while len(self._a1out) > self._kout:
                    self._a1out.popitem(last=False)
            else:
                self._am.popitem(last=False)

    def contains(self, key: int) -> bool:
        return key in self._am or key in self._a1in

    def clear(self) -> None:
        self._a1in.clear()
        self._a1out.clear()
        self._am.clear()

    def __len__(self) -> int:
        return len(self._a1in) + len(self._am)

    def keys(self) -> list:
        return list(self._a1in.keys()) + list(self._am.keys())


class PinnedCache(CachePolicy):
    """Wrapper pinning a fixed block set in memory forever (Starling-style
    in-memory navigation pinning).  Pins count against `capacity`; the
    remainder backs an inner policy for unpinned blocks.  Pinned payloads
    are loaded at device construction / reset (startup cost, amortized
    across queries -- not counted in per-query NIO)."""

    name = "pinned"

    def __init__(self, capacity: int, pins: Iterable[int],
                 inner: str | CachePolicy = "lru"):
        super().__init__(capacity)
        self.pins = frozenset(int(p) for p in pins)
        if len(self.pins) > capacity:
            raise ValueError(
                f"{len(self.pins)} pinned blocks exceed cache capacity "
                f"{capacity}")
        if isinstance(inner, CachePolicy):
            # rebuild at the clamped capacity so pins + inner residency never
            # exceed the total; mutating .capacity in place would leave
            # capacity-derived internals (2Q shares, CLOCK ring) stale
            self.inner = type(inner)(min(inner.capacity,
                                         max(0, capacity - len(self.pins))))
        else:
            self.inner = make_policy(inner, capacity - len(self.pins))
        self._pinned: dict[int, object] = {}

    def get(self, key: int):
        if key in self._pinned:
            return self._pinned[key]
        return self.inner.get(key)

    def put(self, key: int, value) -> None:
        if key in self.pins:
            self._pinned[key] = value
        else:
            self.inner.put(key, value)

    def contains(self, key: int) -> bool:
        return key in self._pinned or self.inner.contains(key)

    def clear(self) -> None:
        self._pinned.clear()
        self.inner.clear()

    def __len__(self) -> int:
        return len(self._pinned) + len(self.inner)

    def keys(self) -> list:
        return list(self._pinned.keys()) + self.inner.keys()


_POLICIES = {"lru": LRUCache, "fifo": FIFOCache, "clock": ClockCache,
             "2q": TwoQCache}


def make_policy(spec: str | CachePolicy, capacity: int,
                pins: Iterable[int] = ()) -> CachePolicy:
    """Instantiate a policy from its name ('lru'|'fifo'|'clock'|'2q'); any
    non-empty `pins` wraps it in a PinnedCache at the same total capacity."""
    pins = tuple(pins)
    if isinstance(spec, CachePolicy):
        return PinnedCache(capacity, pins, inner=spec) if pins else spec
    if spec.lower() not in _POLICIES:
        raise ValueError(f"unknown cache policy {spec!r}; "
                         f"choose from {sorted(_POLICIES)}")
    if pins:   # PinnedCache sizes the inner share (capacity - len(pins))
        return PinnedCache(capacity, pins, inner=spec.lower())
    return _POLICIES[spec.lower()](capacity)


# ---------------------------------------------------------------------------
# Block device
# ---------------------------------------------------------------------------
class BlockDevice:
    """A fixed-block-size device: a list of payload blocks + a pluggable
    block cache.

    `blocks` holds the serialized payload of each block (bytes or any
    immutable object whose serialized size is <= block_size; serialization
    size is validated by the storage layer, not here).  Reads go through a
    `CachePolicy` of `cache_blocks` entries; a miss costs one I/O.  `pinned`
    block ids are preloaded at construction and at every cache-dropping
    reset, and are never evicted (their load is startup cost, not NIO).

    `faults` attaches a seeded `FaultPlan`; fault resolution (retry,
    checksum verification, hedging) happens in `IOScheduler.submit` -- the
    plain `read` keeps its exact pre-fault contract and is what the
    scheduler calls to commit a verified delivery.  Block checksums are
    computed lazily per block (`checksum`/`verify`) so the no-fault path
    pays nothing.
    """

    def __init__(self, blocks: list, block_size: int = BLOCK_SIZE,
                 cache_blocks: int = 128, kind: str = "graph",
                 policy: str | CachePolicy = "lru",
                 pinned: Iterable[int] = (),
                 faults: Optional[FaultPlan] = None):
        self.blocks = blocks
        self.block_size = block_size
        self.kind = kind
        self.cache_blocks = cache_blocks
        self.faults = faults
        self._sums: dict[int, int] = {}
        self.pinned = tuple(sorted({int(p) for p in pinned}))
        for p in self.pinned:
            if p < 0 or p >= len(blocks):
                raise IndexError(f"pinned block {p} out of range")
        self.policy = make_policy(policy, cache_blocks, pins=self.pinned)
        self.stats = IOStats()
        self._preload_pins()

    def _preload_pins(self) -> None:
        for p in self.pinned:
            self.policy.put(p, self.blocks[p])

    def __len__(self) -> int:
        return len(self.blocks)

    @property
    def total_bytes(self) -> int:
        return len(self.blocks) * self.block_size

    def reset(self, drop_cache: bool = True) -> None:
        self.stats.reset()
        if drop_cache:
            self.policy.clear()
            self._preload_pins()

    def cached(self, block_id: int) -> bool:
        """Pure residency probe -- no recency side effects."""
        return self.policy.contains(block_id)

    def read(self, block_id: int):
        """Fetch one block; counts an I/O on cache miss."""
        if block_id < 0 or block_id >= len(self.blocks):
            raise IndexError(f"block {block_id} out of range [0,{len(self.blocks)})")
        hit = self.policy.get(block_id)
        if hit is not _MISS:
            self.stats.cache_hits += 1
            return hit
        payload = self.blocks[block_id]
        if self.kind == "graph":
            self.stats.graph_reads += 1
        else:
            self.stats.vector_reads += 1
        self.policy.put(block_id, payload)
        return payload

    def read_range(self, start: int, count: int) -> list:
        """Sequential multi-block read (each block still counted)."""
        return [self.read(b) for b in range(start, start + count)]

    # --- checksums + fault hooks (resilient reads; see IOScheduler) --------
    def checksum(self, block_id: int) -> int:
        """CRC32 of the block's true payload (memoized)."""
        s = self._sums.get(block_id)
        if s is None:
            s = payload_checksum(self.blocks[block_id])
            self._sums[block_id] = s
        return s

    def verify(self, block_id: int, payload=None) -> bool:
        """Does `payload` (default: the stored payload) match the block's
        recorded checksum?"""
        p = self.blocks[block_id] if payload is None else payload
        return payload_checksum(p) == self.checksum(block_id)

    def attempt_payload(self, block_id: int, corrupt: bool, salt: int = 0):
        """The payload one device transfer would deliver: the true payload,
        or (for a torn transfer) a deterministically perturbed copy.  Pure
        -- no accounting, no cache effects."""
        p = self.blocks[block_id]
        return corrupt_payload(p, salt) if corrupt else p


# ---------------------------------------------------------------------------
# Cost model + pipelined scheduler
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CostModel:
    """Calibrated wall-clock model for simulated QPS (DESIGN.md §2).

    Defaults approximate the paper's testbed (SATA SSD, o_direct 4 KB reads,
    8 search threads).  We report NIO (exact) as the primary metric and
    simulated QPS / service time as the derived ones.

    `qd` is the io_uring-style queue-depth knob: a batched submission of b
    reads completes in ceil(b/qd) serial read-times (plus `submit_us`
    syscall overhead per non-empty submission).  qd=1, submit_us=0
    reproduces the strictly serial model exactly.
    """

    read_us: float = 100.0      # per random 4 KB read
    dist_us: float = 0.05       # per full-precision distance computation
    pq_dist_us: float = 0.005   # per PQ ADC distance estimate
    threads: int = 8
    qd: int = 1                 # queue depth for batched submissions
    submit_us: float = 0.0      # per-submission overhead (io_uring ~1-2 us)
    # deadline accounting (fault injection; None disables either knob):
    timeout_us: Optional[float] = None  # abandon an attempt past this, retry
    hedge_us: Optional[float] = None    # issue a duplicate read at this age

    def submission_us(self, n_reads: int) -> float:
        """Service time of one batched submission of `n_reads` device reads."""
        if n_reads <= 0:
            return 0.0
        qd = max(1, int(self.qd))
        return -(-n_reads // qd) * self.read_us + self.submit_us

    def query_time_us(self, nio: int, n_dist: int, n_pq: int) -> float:
        return nio * self.read_us + n_dist * self.dist_us + n_pq * self.pq_dist_us

    def qps(self, nio: float, n_dist: float, n_pq: float) -> float:
        t = self.query_time_us(nio, n_dist, n_pq)
        return 1e6 * self.threads / max(t, 1e-9)

    def qps_from_io_us(self, io_us: float, n_dist: float, n_pq: float) -> float:
        """QPS when the I/O portion took `io_us` (e.g. pipelined service)."""
        t = io_us + n_dist * self.dist_us + n_pq * self.pq_dist_us
        return 1e6 * self.threads / max(t, 1e-9)


class IOScheduler:
    """Batched-submission front end over one or more `BlockDevice`s.

    The search layer hands the scheduler a *demand* list (blocks whose
    payloads it needs now) plus optional *prefetch* hints (blocks it guesses
    it will need next).  Demand reads go straight through `BlockDevice.read`
    -- cache behavior and NIO are bit-identical to issuing the reads one by
    one.  Prefetch hints are timing-domain only: they ride along in the
    queue slots the demand misses leave idle in the submission's last qd
    wave (so admitting them is free -- `service_us <= serial_us` is an
    invariant), and they are remembered so that a later demand read of a
    prefetched block costs zero *service* time while still counting one
    NIO.  At qd=1 there are never idle slots: no speculation, and batched
    timing degenerates exactly to the serial model.

    Accumulates per-reset:
      service_us -- pipelined wall-clock of all submissions (qd-overlapped)
      serial_us  -- what the same demand misses would cost strictly
                    serially, one submission each (so `submit_us` overhead
                    is charged per miss there vs once per batch here --
                    service_us <= serial_us holds for any submit_us >= 0)
      submissions / demand_reads / prefetches / prefetch_hits -- diagnostics
    """

    def __init__(self, cost: Optional[CostModel] = None,
                 retry: Optional[RetryPolicy] = None):
        self.cost = cost if cost is not None else CostModel()
        self.retry = retry if retry is not None else RetryPolicy()
        self.service_us = 0.0
        self.serial_us = 0.0
        self.submissions = 0
        self.demand_reads = 0
        self.prefetches = 0
        self.prefetch_hits = 0
        self._inflight: set[tuple[int, int]] = set()

    def reset(self) -> None:
        self.service_us = 0.0
        self.serial_us = 0.0
        self.submissions = 0
        self.demand_reads = 0
        self.prefetches = 0
        self.prefetch_hits = 0
        self._inflight.clear()

    def read(self, dev: BlockDevice, block_id: int):
        """Single demand read == submit([block_id])."""
        return self.submit(dev, [block_id])[0]

    def submit(self, dev: BlockDevice, block_ids: Sequence[int],
               prefetch: Sequence[int] = ()) -> list:
        """One batched submission; returns payloads for `block_ids` in order.

        Accounting (NIO, cache state) is exactly what serial per-block
        `dev.read` calls would produce; only the timing differs.

        When `dev.faults` is set, every demand miss runs the resilient read
        loop (checksum verify, bounded retry with backoff, timeout, hedging
        -- see `_read_resilient`); a block that cannot be delivered yields
        the `READ_FAILED` sentinel in its slot instead of raising.  Wasted
        attempts are charged as straggler time (they never overlap in the
        qd pipeline) and counted in the device's `IOStats` resilience
        fields; NIO and cache state still reflect only verified deliveries.
        """
        new_reads = 0
        payloads = []
        demand_set = set(int(b) for b in block_ids)
        for b in block_ids:
            b = int(b)
            key = (id(dev), b)
            was_cached = dev.cached(b)
            if was_cached or dev.faults is None:
                payload, ok, extra_us = dev.read(b), True, 0.0
            else:
                payload, ok, extra_us = self._read_resilient(dev, b)
            payloads.append(payload)
            if extra_us:
                # retries/backoff/spikes are stragglers: they serialize in
                # both timing views, preserving service_us <= serial_us
                self.service_us += extra_us
                self.serial_us += extra_us
            if was_cached:
                continue
            if not ok:
                # nothing was delivered: no NIO, no queue slot occupied;
                # the wasted attempts were charged above
                self._inflight.discard(key)
                continue
            self.demand_reads += 1
            # serial baseline: every miss is its own one-read submission
            self.serial_us += self.cost.read_us + self.cost.submit_us
            if key in self._inflight:
                # speculatively fetched earlier: overlapped, free *in time*;
                # the dev.read above still counted one NIO (data really moved)
                self._inflight.discard(key)
                self.prefetch_hits += 1
            else:
                new_reads += 1
        # speculation may only fill the idle queue slots of the demand
        # misses' last qd wave -- free in the timing domain, so the
        # pipelined service can never exceed the serial baseline
        qd = max(1, int(self.cost.qd))
        spec_budget = (-new_reads) % qd if new_reads else 0
        n_spec = 0
        for b in prefetch:
            if n_spec >= spec_budget:
                break
            b = int(b)
            if b < 0 or b >= len(dev.blocks) or b in demand_set:
                continue
            key = (id(dev), b)
            if dev.cached(b) or key in self._inflight:
                continue
            self._inflight.add(key)
            n_spec += 1
        self.prefetches += n_spec
        if new_reads:
            self.service_us += self.cost.submission_us(new_reads)
            self.submissions += 1
        return payloads

    # --- resilient read (fault-injected devices only) ----------------------
    _HEDGE_STREAM = 1 << 20  # attempt-index offset for hedge outcome draws

    def _read_resilient(self, dev: BlockDevice, b: int):
        """Resolve one demand miss under `dev.faults`.

        Returns ``(payload, ok, extra_us)``.  `extra_us` is the straggler
        time beyond the one base `read_us` the pipelined submission term
        charges for a successful miss: wasted attempts (errors, timeouts,
        torn transfers), backoff waits, and the hedge-capped remainder of a
        latency spike.  On success the delivery is committed through the
        plain `dev.read` (one NIO + cache fill), keeping accounting
        identical to the clean path; on failure nothing touches the cache
        or the NIO counters and `READ_FAILED` is returned.

        With a zero-rate plan every attempt resolves clean with no spike,
        so extra_us == 0 and the path is bit-identical to `dev.read`.
        """
        plan, cost, rp, st = dev.faults, self.cost, self.retry, dev.stats
        extra = 0.0

        def backoff(attempt: int) -> float:
            if attempt >= rp.budget:
                return 0.0  # budget exhausted: no further wait
            st.retries += 1
            return rp.backoff(attempt, plan.jitter(dev.kind, b, attempt))

        for attempt in range(rp.budget + 1):
            out = plan.outcome(dev.kind, b, attempt)
            if out.error:
                st.read_errors += 1
                extra += cost.read_us + backoff(attempt)
                continue
            # data transferred; resolve its latency (spike, hedge, timeout)
            lat = cost.read_us + out.spike_us
            corrupt = out.corrupt
            salt_attempt = attempt
            if cost.hedge_us is not None and lat > cost.hedge_us + cost.read_us:
                st.hedges += 1
                hout = plan.outcome(dev.kind, b, self._HEDGE_STREAM + attempt)
                if not hout.error:
                    hlat = cost.hedge_us + cost.read_us + hout.spike_us
                    if hlat < lat:   # the duplicate read wins the race
                        st.hedge_wins += 1
                        lat = hlat
                        corrupt = hout.corrupt
                        salt_attempt = self._HEDGE_STREAM + attempt
            if cost.timeout_us is not None and lat > cost.timeout_us:
                st.timeouts += 1
                extra += cost.timeout_us + backoff(attempt)
                continue
            if corrupt:
                # the checksum mechanism is load-bearing: really perturb the
                # payload and let verification catch it
                torn = dev.attempt_payload(
                    b, True, plan.corruption_salt(dev.kind, b, salt_attempt))
                if not dev.verify(b, torn):
                    st.checksum_failures += 1
                    extra += lat + backoff(attempt)
                    continue
                # payload had no bytes to tear (None placeholder): fall
                # through as a clean delivery
            # clean verified delivery: the base read_us is charged by the
            # pipelined submission term; only the remainder is a straggler
            extra += lat - cost.read_us
            return dev.read(b), True, extra

        st.failed_reads += 1
        return READ_FAILED, False, extra
