"""Shared shape-cell definitions (the assigned input shapes per family).

Every (arch x shape) pair is one dry-run cell: launch/cells.py turns
(arch module, ShapeSpec, mesh) into a concrete step function +
ShapeDtypeStruct inputs + shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                     # train | prefill | decode | serve | retrieval
    # --- LM ---
    seq_len: int = 0
    global_batch: int = 0
    accum: int = 1                # grad-accumulation microbatches (train)
    kv_mode: str = "auto"         # decode cache sharding: head | seq | seq_all
    # --- GNN ---
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_graphs: int = 0         # molecule cell
    batch_nodes: int = 0          # minibatch cell (seed nodes)
    fanout: tuple = ()
    # --- recsys ---
    batch: int = 0
    n_candidates: int = 0


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256,
                          accum=2),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768,
                             global_batch=32),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768,
                            global_batch=128),
    "long_500k": ShapeSpec("long_500k", "decode", seq_len=524288,
                           global_batch=1, kv_mode="seq_all"),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "train", n_nodes=2708,
                               n_edges=10556, d_feat=1433),
    "minibatch_lg": ShapeSpec("minibatch_lg", "train", n_nodes=232965,
                              n_edges=114615892, batch_nodes=1024,
                              fanout=(15, 10), d_feat=602),
    "ogb_products": ShapeSpec("ogb_products", "train", n_nodes=2449029,
                              n_edges=61859140, d_feat=100),
    "molecule": ShapeSpec("molecule", "train", n_nodes=30, n_edges=64,
                          batch_graphs=128),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", batch=65536),
    "serve_p99": ShapeSpec("serve_p99", "serve", batch=512),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", batch=262144),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval", batch=1,
                                n_candidates=1_000_000),
}


def pad_to_multiple(n: int, m: int) -> int:
    return -(-n // m) * m
