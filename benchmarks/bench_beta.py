"""Paper Fig. 9: effect of beta (pruning closeness margin)."""
from . import common


def run(regime: str = "sift-like",
        betas=(1.0, 1.05, 1.1, 1.15, 1.2)) -> None:
    for b in betas:
        idx = common.bamg_index(regime, beta=b)
        sw = common.sweep(idx, regime, ls=(48,))
        l, recall, nio, qps, g, v = sw[0]
        deg = idx.degree_stats()
        common.emit(f"fig9_beta.{regime}.b{b:.2f}", round(nio, 2),
                    f"recall={recall:.3f};qps={qps:.0f};"
                    f"deg={deg['total']:.1f};cross={deg['cross']:.1f}")


if __name__ == "__main__":
    run()
