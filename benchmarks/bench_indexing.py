"""Paper Fig. 6 + 7 (indexing time / index size) plus the construction
backend sweep: end-to-end build wall-clock and recall for
``backend="host"`` (per-node numpy reference) vs ``backend="batched"``
(`repro.build` jit'd fixed-shape pipeline) on the same corpus.

``REPRO_BENCH_BUILD_N`` overrides the sweep corpus size (default
``REPRO_BENCH_N``); the acceptance-scale comparison runs at n>=20k, where
the batched backend's fixed costs (jit compilation, padding) are
amortized.  Emits, per backend: build seconds, graph degree, recall@10 /
NIO of the built graph searched with identical engine parameters, plus
the host/batched speedup and the recall delta.

The sweep deliberately re-times the host build even though fig6 already
built the cached base graphs: a fair host-vs-batched comparison must run
both backends through the same `GraphBuilder` entry point back to back,
not stitch cached stage timings together.
"""
import os
import time

import numpy as np

from . import common


def _bamg_recall(x, graph, codec, codes, queries, gt, l: int = 48):
    """recall@10 / NIO of a BAMG graph under the standard host engine."""
    from repro.core.engine import BAMGIndex, BAMGParams
    from repro.core.storage import DecoupledStorage

    store = DecoupledStorage(x, graph.adj, graph.blocks, graph.members)
    idx = BAMGIndex(x, graph, codec, codes, store, None,
                    BAMGParams(r=common.R, use_nav=False))
    st = idx.search_batch(queries, k=10, l=l, gt=gt)
    return st.recall, st.mean_nio


def build_sweep(regime: str) -> dict:
    """Host-vs-batched BAMG + Vamana build sweep; returns the emitted rows."""
    from repro.build import BuildConfig, GraphBuilder
    from repro.core.pq import train_pq
    from repro.core.storage import max_capacity_for
    from repro.data.synthetic import PAPER_REGIMES, make_vector_dataset

    n = int(os.environ.get("REPRO_BENCH_BUILD_N", str(common.BENCH_N)))
    if n == common.BENCH_N:
        ds = common.dataset(regime)
    else:
        cfg = PAPER_REGIMES[regime]
        ds = make_vector_dataset(regime, n, cfg["d"], common.BENCH_NQ,
                                 k_gt=100, n_clusters=cfg["n_clusters"],
                                 seed=0)
    x = ds.base
    cap = max_capacity_for(common.R)
    codec = train_pq(x, m=16, seed=0)
    codes = codec.encode(x)

    out = {}
    for be in ("host", "batched"):
        gb = GraphBuilder(BuildConfig(backend=be))
        t0 = time.time()
        graph = gb.build_bamg(x, cap, alpha=3, beta=1.05, r=common.R,
                              l_build=common.L_BUILD, knn_k=common.R,
                              max_degree=common.R)
        t_bamg = time.time() - t0
        t0 = time.time()
        vam_adj, _ = gb.build_vamana(x, r=common.R, l_build=common.L_BUILD)
        t_vam = time.time() - t0
        rec, nio = _bamg_recall(x, graph, codec, codes, ds.queries, ds.gt)
        deg = float((graph.adj >= 0).sum(1).mean())
        out[be] = dict(t_bamg=t_bamg, t_vam=t_vam, recall=rec, nio=nio,
                       deg=deg)
        common.emit(f"build.{regime}.bamg_{be}_s", round(t_bamg, 2),
                    f"n={n};deg={deg:.1f}")
        common.emit(f"build.{regime}.vamana_{be}_s", round(t_vam, 2),
                    f"n={n}")
        common.emit(f"build.{regime}.recall_{be}", round(rec, 4),
                    f"l=48;nio={nio:.1f}")
    common.emit(f"build.{regime}.bamg_speedup",
                round(out["host"]["t_bamg"] / out["batched"]["t_bamg"], 2),
                "host_s/batched_s (>=5x on accelerator-class hardware)")
    common.emit(f"build.{regime}.vamana_speedup",
                round(out["host"]["t_vam"] / out["batched"]["t_vam"], 2),
                "host_s/batched_s")
    common.emit(f"build.{regime}.recall_delta",
                round(out["batched"]["recall"] - out["host"]["recall"], 4),
                "batched - host (acceptance: within +/-0.01)")
    return out


def run(regimes=("sift-like",)) -> None:
    for regime in regimes:
        b = common.base_graphs(regime)
        t0 = time.time()
        idx = common.bamg_index(regime)
        t_refine = time.time() - t0
        t_bamg = b["t"]["nsg"] + b["t"]["bnf"] + b["t"]["pq"] + t_refine
        common.emit(f"fig6_time.{regime}.bamg", round(t_bamg, 1),
                    f"nsg={b['t']['nsg']:.1f};bnf={b['t']['bnf']:.1f};"
                    f"refine+nav={t_refine:.1f};s")
        common.emit(f"fig6_time.{regime}.vamana_base",
                    round(b["t"]["vamana"], 1), "s (diskann/starling graph)")
        common.emit(f"fig7_size.{regime}.bamg",
                    round(idx.index_bytes() / 2 ** 20, 2),
                    f"graph={idx.store.graph_bytes/2**20:.1f}MiB;"
                    f"vec={idx.store.vector_bytes/2**20:.1f}MiB")
        common.emit(f"fig7_size.{regime}.starling",
                    round(common.starling_index(regime).index_bytes() / 2 ** 20, 2),
                    "MiB coupled")
        common.emit(f"fig7_size.{regime}.diskann",
                    round(common.diskann_index(regime).index_bytes() / 2 ** 20, 2),
                    "MiB coupled")
        build_sweep(regime)


if __name__ == "__main__":
    run()
