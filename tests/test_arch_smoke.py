"""Per-architecture smoke tests (deliverable f): reduced config, one
forward/train step on CPU, assert output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, all_cells, get_arch

RNG = np.random.default_rng(0)

LM_ARCHS = [a for a, m in ARCHS.items() if m.FAMILY == "lm"]
GNN_ARCHS = [a for a, m in ARCHS.items() if m.FAMILY == "gnn"]


def test_registry_covers_40_cells():
    cells = all_cells()
    assert len(cells) == 40
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_decode(arch):
    from repro.models.transformer import (LMConfig, ShardCtx, decode_step,
                                          init_cache, init_lm_params,
                                          lm_loss, serve_prefill)
    cfg = get_arch(arch).model_config(reduced=True)
    ctx = ShardCtx(mesh=None)
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    labels = jnp.roll(toks, -1, 1)
    loss, parts = jax.jit(lambda p, t, l: lm_loss(p, cfg, t, l, ctx))(
        params, toks, labels)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: lm_loss(p, cfg, toks, labels, ctx)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    # prefill + one decode step
    logits, (ck, cv), lens = jax.jit(
        lambda p, t: serve_prefill(p, cfg, t, ctx))(params, toks)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    ck0, cv0, _ = init_cache(cfg, 2, 32, dtype=ck.dtype)
    sc = ck.shape[2]
    ck0 = ck0.at[:, :, :sc].set(ck)
    cv0 = cv0.at[:, :, :sc].set(cv)
    lg, caches2 = jax.jit(
        lambda p, t, q, c: decode_step(p, cfg, t, q, c, ctx, "local"))(
        params, toks[:, :1], jnp.asarray([16, 16], jnp.int32),
        (ck0, cv0, lens))
    assert lg.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert int(caches2[2][0]) == 17


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    from repro.data.synthetic import molecules_batch, random_graph
    cfg = get_arch(arch).model_config(reduced=True)
    if arch == "graphcast":
        from repro.models.gnn import graphcast as m
        g = random_graph(60, 240, d_feat=cfg.d_feat, seed=1)
        batch = {"node_feat": jnp.asarray(g.node_feat),
                 "edge_src": jnp.asarray(g.edge_src),
                 "edge_dst": jnp.asarray(g.edge_dst),
                 "edge_feat": jnp.asarray(g.edge_feat),
                 "targets": jnp.asarray(RNG.normal(size=(60, cfg.n_vars)),
                                        jnp.float32)}
        params = m.init_params(cfg, jax.random.PRNGKey(0))
        loss = jax.jit(lambda p, b: m.loss_fn(p, cfg, b))(params, batch)
        fwd = m.forward(params, cfg, batch)
        assert fwd.shape == (60, cfg.n_vars)
    else:
        mol, gid = molecules_batch(3, 10, 24, seed=1)
        batch = {"species": jnp.asarray(np.abs(mol.labels) % 8, jnp.int32),
                 "pos": jnp.asarray(mol.pos),
                 "edge_src": jnp.asarray(mol.edge_src),
                 "edge_dst": jnp.asarray(mol.edge_dst),
                 "graph_ids": jnp.asarray(gid),
                 "energy": jnp.asarray(RNG.normal(size=3), jnp.float32)}
        if arch == "nequip":
            from repro.models.gnn import nequip as m
        elif arch == "mace":
            from repro.models.gnn import mace as m
        else:
            from repro.models.gnn import dimenet as m
            from repro.models.gnn.dimenet import build_triplets
            ti, to = build_triplets(np.asarray(mol.edge_src),
                                    np.asarray(mol.edge_dst),
                                    max_triplets=800)
            batch["tri_in"] = jnp.asarray(ti)
            batch["tri_out"] = jnp.asarray(to)
        params = m.init_params(cfg, jax.random.PRNGKey(0))
        loss = jax.jit(lambda p, b: m.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: m.loss_fn(p, cfg, batch))(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


def test_din_smoke_all_kinds():
    from repro.data.synthetic import din_batch
    from repro.models.recsys import din as m
    cfg = get_arch("din").model_config(reduced=True)
    params = m.init_params(cfg, jax.random.PRNGKey(0))
    hi, hc, hl, ti, tc, y = din_batch(0, 16, cfg.seq_len, cfg.n_items,
                                      cfg.n_cates)
    batch = {k: jnp.asarray(v) for k, v in
             zip(("hist_items", "hist_cates", "hist_len", "target_item",
                  "target_cate", "label"), (hi, hc, hl, ti, tc, y))}
    loss = jax.jit(lambda p, b: m.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    scores = m.forward_scores(params, cfg, batch)
    assert scores.shape == (16,)
    s, ids = jax.jit(lambda p, b: m.retrieval_step(p, cfg, b, 512, k=5))(
        params, batch)
    assert s.shape == (16, 5) and ids.shape == (16, 5)
    assert np.isfinite(np.asarray(s)).all()
    assert (np.asarray(ids) >= 0).all() and (np.asarray(ids) < 512).all()


@pytest.mark.parametrize("arch", list(ARCHS))
def test_full_config_constructible(arch):
    """Full configs instantiate (dataclasses only -- no allocation)."""
    cfg = get_arch(arch).model_config(reduced=False)
    assert cfg.name == arch
    if get_arch(arch).FAMILY == "lm":
        assert cfg.n_params() > 1e9
