"""Gradient compression for cross-pod data parallelism.

Two wire formats + error feedback:
  * bf16: 2x reduction, no state.
  * int8 + per-tensor scale + error feedback (1-bit-Adam-style residual):
    4x reduction; the quantization residual is carried in `err` and added
    back before the next quantization, so the *accumulated* gradient is
    unbiased and convergence matches fp32 asymptotically.

`compressed_psum` is the explicit collective used by the manual-DP trainer
mode (shard_map over the pod/data axes): quantize -> integer psum ->
dequantize.  Under pure-GSPMD training the backward all-reduce is emitted
by XLA and cannot be intercepted; manual-DP mode exists exactly to make
the cross-pod exchange explicit and compressible (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils.sharding import bound_axis_size


def compress_bf16(tree):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), tree)


def decompress_f32(tree):
    return jax.tree.map(lambda g: g.astype(jnp.float32), tree)


def quantize_int8(g: jnp.ndarray):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress(tree, err):
    """Error-feedback int8: quantize (g + err); new err = input - dequant."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return (q, s), x - deq
    flat_g, tdef = jax.tree.flatten(tree)
    flat_e = jax.tree.leaves(err)
    qs, errs = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    return jax.tree.unflatten(tdef, list(qs)), jax.tree.unflatten(tdef, list(errs))


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(tree, axis_names, method: str = "int8_ef",
                    err=None):
    """All-reduce-mean a gradient pytree over `axis_names` with compression.

    Call inside shard_map.  Returns (mean_grads_f32, new_err).
    int8 payloads psum as int32 (no overflow below ~2^23 replicas); the
    f32 per-tensor scales psum too (each replica applies its own scale
    before the sum -- implemented as scale-then-sum of the dequantized
    int32, which is exact because dequant is linear).
    """
    n = 1
    for ax in axis_names:
        n *= bound_axis_size(ax)

    if method == "none":
        return jax.tree.map(
            lambda g: jax.lax.psum(g.astype(jnp.float32), axis_names) / n,
            tree), err
    if method == "bf16":
        out = jax.tree.map(
            lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_names)
            .astype(jnp.float32) / n, tree)
        return out, err
    if method == "int8_ef":
        assert err is not None, "int8_ef needs error-feedback state"
        q_tree, new_err = ef_compress(tree, err)

        def reduce_one(qs):
            q, s = qs
            # scale locally (linear), then sum the scaled values in f32 --
            # wire payload is the int8 q (s is a scalar per tensor)
            return jax.lax.psum(q.astype(jnp.float32) * s, axis_names) / n
        flat, tdef = jax.tree.flatten(tree)
        q_flat = jax.tree.leaves(q_tree, is_leaf=lambda x: isinstance(x, tuple))
        out = jax.tree.unflatten(tdef, [reduce_one(q) for q in q_flat])
        return out, new_err
    raise ValueError(method)
