"""Batched serving engine vs the host BAMG engine (parity + shapes).

The batched engine explores the same monotonic graph with the same PQ
estimates; under an exhaustive configuration (pool holds the whole corpus,
hop budget covers it, full exact re-rank) it must return the *identical*
top-k ids as brute force -- and so must `BAMGIndex.search` with l=n.  At
practical settings the two engines only need to agree on recall within a
small tolerance.
"""
import numpy as np
import pytest

from repro.core.distances import exact_knn, recall_at_k
from repro.core.engine import BAMGIndex, BAMGParams
from repro.serve import BatchedANNEngine, EngineConfig, ShardedFrontend

K = 10


@pytest.fixture(scope="module")
def built(small_corpus):
    idx = BAMGIndex.build(small_corpus.base,
                          BAMGParams(alpha=3, beta=1.05, r=16, l_build=32,
                                     knn_k=16, seed=0))
    return small_corpus, idx


def test_exhaustive_rerank_identical_topk(built):
    """l = n, hops = n, full re-rank: batched ids == host ids == brute force."""
    ds, idx = built
    n = len(ds.base)
    eng = BatchedANNEngine.from_index(idx, EngineConfig(l=n, max_hops=n))
    ids, dists = eng.search_batch(ds.queries, K)
    gd, gi = exact_knn(ds.base, ds.queries, K)
    np.testing.assert_array_equal(ids, gi)
    np.testing.assert_allclose(dists, gd, rtol=1e-4, atol=1e-3)
    for qi, q in enumerate(ds.queries):
        r = idx.search(q, k=K, l=n)
        np.testing.assert_array_equal(ids[qi], r.ids)


def test_practical_settings_recall_parity(built):
    ds, idx = built
    eng = BatchedANNEngine.from_index(idx, EngineConfig(l=48, max_hops=32))
    ids, dists = eng.search_batch(ds.queries, K)
    assert ids.shape == (len(ds.queries), K)
    assert (np.diff(dists, axis=1) >= 0).all()        # ascending
    host = idx.search_batch(ds.queries, k=K, l=48, gt=ds.gt)
    assert recall_at_k(ids, ds.gt, K) >= host.recall - 0.05


def test_single_query_batch(built):
    ds, idx = built
    eng = BatchedANNEngine.from_index(idx, EngineConfig(l=32, max_hops=24))
    ids, dists = eng.search_batch(ds.queries[0], K)   # 1-D query promoted
    assert ids.shape == (1, K)
    assert np.isfinite(dists).all() and (ids >= 0).all()


def test_pool_capacity_exceeding_corpus_is_clamped(built):
    ds, idx = built
    n = len(ds.base)
    eng = BatchedANNEngine.from_index(idx, EngineConfig(l=10 * n, max_hops=8))
    ids, _ = eng.search_batch(ds.queries[:2], K)
    assert ids.shape == (2, K)


def test_max_hops_plumbed_through_host_engine(built):
    """BAMGIndex.search(max_hops=...) bounds the walk (satellite check)."""
    ds, idx = built
    r1 = idx.search(ds.queries[0], k=K, l=48, max_hops=1)
    rfull = idx.search(ds.queries[0], k=K, l=48)
    assert r1.hops == 1
    assert rfull.hops >= r1.hops


def test_frontend_shard_smaller_than_k(built):
    """A shard with fewer points than k contributes what it has; the global
    merge still returns k valid ids from the other shards."""
    ds, _ = built
    n = len(ds.base)
    # 8 shards of a 75-point prefix -> ~9 points per shard, k=10 > shard size
    small = ds.base[:75]
    fe = ShardedFrontend.build(
        small, n_shards=8,
        params=BAMGParams(alpha=3, beta=1.05, r=8, l_build=16, knn_k=8),
        config=EngineConfig(l=75, max_hops=75))
    ids, dists = fe.search_batch(ds.queries, K)
    assert ids.shape == (len(ds.queries), K)
    assert (ids >= 0).all() and np.isfinite(dists).all()
    _, gi = exact_knn(small, ds.queries, K)
    np.testing.assert_array_equal(ids, gi)


def _random_cfg(seed):
    """Randomized corpus/search shape for the parity sweep."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(60, 180))
    d = int(rng.integers(8, 28))
    k = int(rng.integers(1, 9))
    l = int(rng.integers(max(k, 8), n + 1))
    return n, d, k, l


def _check_host_engine_parity(seed):
    """Host `search_bamg` vs `BatchedANNEngine` under an exhaustive config
    (pool spans the corpus, full exact re-rank, identical entry seeds):
    identical top-k ids, and both identical to brute force."""
    from repro.core.search import search_bamg
    from repro.data.synthetic import make_vector_dataset
    n, d, k, _ = _random_cfg(seed)
    ds = make_vector_dataset(f"sweep{seed}", n=n, d=d, nq=6, k_gt=max(k, 1),
                             n_clusters=max(2, n // 50), seed=seed)
    idx = BAMGIndex.build(ds.base,
                          BAMGParams(alpha=2, beta=1.05, r=12, l_build=24,
                                     knn_k=12, seed=seed))
    # both sides seed from the full entry-candidate pool: on tiny random
    # graphs a node can be unreachable from a 4-seed subset, which would
    # test entry selection, not traversal/re-rank parity.  alpha=n makes the
    # intra-block BFS exhaustive too (a depth-truncated frontier is marked
    # checked without expansion, losing reachability the engine's pool-wide
    # beam keeps).
    cands = idx.batch_arrays(n_entry_cands=256)["entry_cands"]
    eng = BatchedANNEngine.from_index(
        idx, EngineConfig(l=n, max_hops=n, n_entry=len(cands)))
    ids, _ = eng.search_batch(ds.queries, k)
    gd, gi = exact_knn(ds.base, ds.queries, k)
    np.testing.assert_array_equal(ids, gi)
    for qi, q in enumerate(ds.queries):
        r = search_bamg(idx.store, idx.codes, idx.codec.adc_table(q), q,
                        cands.tolist(), k=k, l=n, alpha=n)
        np.testing.assert_array_equal(ids[qi], r.ids)


@pytest.mark.parametrize("seed", (0, 1, 2))
def test_parity_sweep_host_vs_batched_engine(seed):
    _check_host_engine_parity(seed)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hst

    @settings(max_examples=3, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(seed=hst.integers(min_value=10, max_value=10_000))
    def test_parity_sweep_host_vs_batched_engine_hyp(seed):
        _check_host_engine_parity(seed)
except ImportError:  # container without dev deps: seeded sweep still runs
    pass


@pytest.mark.parametrize("seed", (0, 1, 2))
def test_batched_submission_bit_identical_to_per_read(built, seed):
    """The pipelined batched-submission path (top-alpha frontier prefetch +
    one-shot re-rank submission, any queue depth) must return bit-identical
    ids/dists and identical NIO to the per-read path: the scheduler changes
    timing, never accounting."""
    ds, idx = built
    rng = np.random.default_rng(seed)
    l = int(rng.integers(16, 80))
    k = int(rng.integers(1, 10))
    try:
        for q in ds.queries:
            r0 = idx.search(q, k=k, l=l, batch_io=False)
            idx.configure_io(qd=int(rng.integers(2, 16)))
            r1 = idx.search(q, k=k, l=l, batch_io=True)
            np.testing.assert_array_equal(r0.ids, r1.ids)
            np.testing.assert_allclose(r0.dists, r1.dists)
            assert r0.nio == r1.nio
            assert r0.graph_reads == r1.graph_reads
            assert r0.vector_reads == r1.vector_reads
            assert r0.cache_hits == r1.cache_hits
            assert r0.serial_us == r1.serial_us      # accounting domain
            assert r1.service_us <= r1.serial_us + 1e-9   # qd>1 overlaps
    finally:
        idx.configure_io(qd=1)    # module-scoped fixture: restore defaults


def test_auto_backend_dispatch_pinned():
    """`EngineConfig.backend="auto"` resolution is load-bearing: CPU hosts
    must land on the unfused jnp path (zero behavior change without a
    TPU), TPU hosts on the fused loop -- VMEM-resident when the shard
    fits `beam_fused.vmem_bytes`, HBM-streaming when it does not -- and
    every resolution target must be dispatchable by `batched_search`."""
    from repro.kernels import beam_fused
    from repro.serve.ann_engine import (_FUSED_INNER, _STAGE_INNER,
                                        resolve_backend)
    shape = dict(n=4096, r=32, m=16, k=256, l=64, max_hops=32)
    # non-auto values pass through untouched
    for b in ("ref", "fused_ref", "fused_stream"):
        assert resolve_backend(b, **shape) == b
    # CPU/GPU hosts: the unfused jnp path, regardless of shard size
    assert resolve_backend("auto", platform="cpu", **shape) == "ref"
    assert resolve_backend("auto", platform="gpu",
                           **dict(shape, n=10**7)) == "ref"
    # TPU: resident fused when the estimator fits the budget...
    assert beam_fused.fits_vmem(4096, 32, m=16)
    assert resolve_backend("auto", platform="tpu", **shape) == "fused"
    # ...streaming when the shard exceeds it (by size or by budget)
    assert not beam_fused.fits_vmem(10**7, 32, m=16)
    assert resolve_backend("auto", platform="tpu",
                           **dict(shape, n=10**7)) == "fused_stream"
    assert resolve_backend("auto", platform="tpu", budget=1024,
                           **shape) == "fused_stream"
    # every resolution target reaches a dispatchable fused inner backend
    for resolved in ("fused", "fused_stream"):
        assert resolved in _FUSED_INNER
    assert set(_FUSED_INNER.values()) <= set(beam_fused.BACKENDS)
    # the streaming hop backends map to resident per-stage kernels
    assert set(_STAGE_INNER) <= set(_FUSED_INNER.values())
    assert set(_STAGE_INNER.values()) == {"pallas", "interpret"}


def test_auto_backend_on_cpu_bitwise_equals_ref(built):
    """On a CPU host auto must be a no-op relative to backend="ref"."""
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("CPU-host behavior pin")
    ds, idx = built
    cfg = dict(l=32, max_hops=16)
    e0 = BatchedANNEngine.from_index(idx, EngineConfig(backend="ref", **cfg))
    e1 = BatchedANNEngine.from_index(idx, EngineConfig(backend="auto", **cfg))
    i0, d0 = e0.search_batch(ds.queries, K)
    i1, d1 = e1.search_batch(ds.queries, K)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(d0, d1)


def test_build_copies_params_no_cross_index_leak(tiny_points):
    """configure_io on one index must not leak knobs into other indexes
    built from the same (possibly default) params object."""
    from repro.core.engine import DiskANNIndex, DiskANNParams
    p = DiskANNParams(r=8, l_build=16)
    a = DiskANNIndex.build(tiny_points, p)
    b = DiskANNIndex.build(tiny_points, p)
    a.configure_io(qd=8, batch_io=True, cache_policy="2q")
    assert b.params.qd == 1 and not b.params.batch_io
    assert p.qd == 1 and not p.batch_io and p.cache_policy == "lru"


def test_warm_cache_reduces_nio_not_recall(built):
    ds, idx = built
    cold = idx.search_batch(ds.queries, k=K, l=48, gt=ds.gt)
    warm = idx.search_batch(ds.queries, k=K, l=48, gt=ds.gt, warm_cache=True)
    assert warm.mean_nio < cold.mean_nio
    assert warm.recall >= cold.recall - 1e-9
    assert warm.cache_hit_rate > cold.cache_hit_rate


def test_sharded_frontend_matches_global_brute_force(built):
    """2-shard scatter-gather at exhaustive budget == global brute force."""
    ds, _ = built
    n = len(ds.base)
    fe = ShardedFrontend.build(
        ds.base, n_shards=2,
        params=BAMGParams(alpha=3, beta=1.05, r=16, l_build=32, knn_k=16),
        config=EngineConfig(l=n, max_hops=n))
    ids, dists = fe.search_batch(ds.queries, K)
    _, gi = exact_knn(ds.base, ds.queries, K)
    np.testing.assert_array_equal(ids, gi)
    assert (np.diff(dists, axis=1) >= 0).all()
