"""Kernel micro-benchmarks (CPU wall time of the jnp reference backend;
the Pallas TPU path is validated in interpret mode by tests/test_kernels)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from repro.kernels.flash_decode import flash_decode
from repro.kernels.l2_topk import l2_topk
from repro.kernels.pq_adc import pq_adc


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> None:
    rng = np.random.default_rng(0)
    tables = jnp.asarray(rng.random((8, 16, 256)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 256, (65536, 16)), jnp.uint8)
    us = _time(lambda t, c: pq_adc(t, c, backend="ref"), tables, codes)
    common.emit("kernel.pq_adc.b8xn65536", round(us, 1),
                f"gflops={8*65536*16*2/us/1e3:.1f}")

    q = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    base = jnp.asarray(rng.normal(size=(100_000, 128)), jnp.float32)
    us = _time(lambda a, b: l2_topk(a, b, 100, backend="ref"), q, base)
    common.emit("kernel.l2_topk.b8xn100k", round(us, 1),
                f"gflops={2*8*100_000*128/us/1e3:.1f}")

    qq = jnp.asarray(rng.normal(size=(4, 32, 128)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(4, 8192, 8, 128)), jnp.bfloat16)
    vv = jnp.asarray(rng.normal(size=(4, 8192, 8, 128)), jnp.bfloat16)
    lens = jnp.full((4,), 8192, jnp.int32)
    us = _time(lambda a, b, c, d: flash_decode(a, b, c, d, backend="ref"),
               qq, kk, vv, lens)
    common.emit("kernel.flash_decode.b4s8192", round(us, 1),
                f"gbps={(kk.nbytes+vv.nbytes)/us/1e3:.1f}")


if __name__ == "__main__":
    run()
