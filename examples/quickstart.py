"""Quickstart: build a BAMG index, search it, inspect the I/O profile.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.engine import BAMGIndex, BAMGParams  # noqa: E402
from repro.data.synthetic import make_vector_dataset  # noqa: E402


def main() -> None:
    # 1. a corpus with exact ground truth ------------------------------------
    ds = make_vector_dataset("quickstart", n=2000, d=64, nq=20, k_gt=10,
                             seed=0)

    # 2. build: NSG -> BNF block shuffling -> BAMG refinement (Alg. 2)
    #    -> multi-layer nav graph (Alg. 3) -> decoupled disk layout (Fig. 3)
    idx = BAMGIndex.build(ds.base, BAMGParams(alpha=3, beta=1.05))
    print(f"blocks: {idx.graph.members.shape[0]} x capacity "
          f"{idx.graph.capacity}, nav layers: {idx.nav.n_layers}")
    print(f"on-disk: graph {idx.store.graph_bytes/2**20:.1f} MiB + "
          f"vectors {idx.store.vector_bytes/2**20:.1f} MiB; "
          f"in-memory: {idx.memory_bytes()/2**20:.2f} MiB (PQ codes + nav)")

    # 3. search one query (Alg. 4: block-first, PQ-guided, exact re-rank)
    r = idx.search(ds.queries[0], k=10, l=40)
    print(f"query 0: {r.nio} block reads "
          f"({r.graph_reads} graph + {r.vector_reads} vector), "
          f"{r.hops} hops, ids={r.ids[:5].tolist()}...")

    # 4. batch evaluation against ground truth
    st = idx.search_batch(ds.queries, k=10, l=40, gt=ds.gt)
    print(f"recall@10={st.recall:.3f}  NIO={st.mean_nio:.1f}  "
          f"simulated QPS~{st.qps:.0f}")

    # 5a. pipelined I/O: batched submissions at queue depth 8.  NIO is
    #     identical by construction -- only the modeled service time drops.
    idx.configure_io(qd=8, batch_io=True)
    stp = idx.search_batch(ds.queries, k=10, l=40, gt=ds.gt)
    print(f"pipelined qd=8: NIO={stp.mean_nio:.1f} (unchanged)  "
          f"service={stp.mean_service_us:.0f}us vs "
          f"serial={stp.mean_serial_us:.0f}us  QPS~{stp.qps_pipelined:.0f}")
    assert stp.mean_nio == st.mean_nio

    # 5b. cache engineering: 2Q block cache + the hot navigation-entry
    #     graph blocks pinned in memory (Starling-style) -- this one *does*
    #     cut NIO, by turning the per-query entry reads into hits.
    idx.configure_io(cache_policy="2q", pin_nav_blocks=16)
    stq = idx.search_batch(ds.queries, k=10, l=40, gt=ds.gt)
    print(f"2q + pinned nav: NIO={stq.mean_nio:.1f}  "
          f"hit_rate={stq.cache_hit_rate:.2f}  QPS~{stq.qps_pipelined:.0f}")
    idx.configure_io(cache_policy="lru", qd=1, batch_io=False,
                     pin_nav_blocks=0)

    # 6. persistence
    idx.save("/tmp/bamg_quickstart.npz")
    idx2 = BAMGIndex.load("/tmp/bamg_quickstart.npz")
    r2 = idx2.search(ds.queries[0], k=10, l=40)
    assert np.array_equal(r.ids, r2.ids)
    print("save/load roundtrip OK")


if __name__ == "__main__":
    main()
