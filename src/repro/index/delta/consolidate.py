"""Background consolidation: fold the delta overlay into a fresh BAMG build.

Deletes make this more than a rebuild-with-appends: dropping a node
severs every monotonic path that ran through it.  Following FreshDiskANN,
each live node that lost a neighbor repairs its row with
neighbor-of-neighbor RobustPrune -- candidates are its surviving
neighbors plus the surviving neighbors of its dead neighbors, pruned by
the standard occlusion rule -- so two-hop connectivity through a deleted
point collapses into a direct edge when no surviving edge dominates it.

Block assignment is then *re-run from scratch* on the repaired merged
graph (BNF + block-aware Alg-2 refine): per the page-alignment argument
in PAPERS.md, a block layout co-locates the topology it was computed on,
and the merged topology is new -- splicing edges into the old layout
would quietly degrade the very block-hit rates BAMG exists to exploit.

The output id space is compacted (live ids -> `0..m-1`, base-then-delta
ascending); `old2new` maps overlay ids to the new rows (-1 = deleted),
which `FreshService` uses to keep external ids stable across the swap.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.build import BuildConfig, GraphBuilder
from repro.build.prune import robust_prune_inc
from repro.core.block_assign import bnf_blocks
from repro.core.distances import medoid
from repro.core.engine import BAMGIndex, BAMGParams
from repro.core.graph_build import connect_to_entry
from repro.core.storage import max_capacity_for

from .layer import DeltaLayer


def _pad_rows(rows: list[np.ndarray], r: int) -> np.ndarray:
    out = np.full((len(rows), r), -1, np.int32)
    for i, row in enumerate(rows):
        m = min(len(row), r)
        out[i, :m] = row[:m]
    return out


def consolidate(base_index, delta: DeltaLayer,
                params: Optional[BAMGParams] = None,
                ) -> tuple[BAMGIndex, np.ndarray]:
    """Fold `delta` into a fresh BAMG index.

    Returns `(index, old2new)`: the consolidated `BAMGIndex` over the
    live corpus, and an `(n_total,)` int64 map from overlay ids to new
    rows (-1 for tombstoned ids).  The caller publishes the index
    through `DeploymentManager` and swaps via `BlueGreenEngine.refresh`.
    """
    p = dataclasses.replace(params if params is not None
                            else base_index.params)
    n_total = delta.n_total
    dead = delta.tombstones
    live = np.asarray([v for v in range(n_total) if v not in dead], np.int64)
    if len(live) < 3:
        raise ValueError(f"consolidate: {len(live)} live points; a graph "
                         f"index needs >= 3")
    x_all = delta.vectors(np.arange(n_total))
    prune_alpha = delta.params.prune_alpha

    # --- 1. materialize the overlay + repair edges around deleted nodes
    rows: dict[int, np.ndarray] = {}
    for u in live.tolist():
        nn = delta.neighbors(u)
        dead_nbrs = [v for v in nn.tolist() if v in dead]
        if not dead_nbrs:
            rows[u] = nn
            continue
        cand = {v for v in nn.tolist() if v not in dead}
        for v in dead_nbrs:           # neighbor-of-neighbor candidates
            cand.update(w for w in delta.neighbors(v).tolist()
                        if w not in dead and w != u)
        cand_ids = np.asarray(sorted(cand), np.int64)
        rows[u] = robust_prune_inc(x_all[u], cand_ids, x_all[cand_ids],
                                   r=p.r, alpha=prune_alpha)

    # --- 2. compact the id space (base-then-delta ascending)
    old2new = np.full(n_total, -1, np.int64)
    old2new[live] = np.arange(len(live))
    x_new = np.ascontiguousarray(x_all[live])
    new_rows = []
    for u in live.tolist():
        m = old2new[rows[u]]
        new_rows.append(m[m >= 0])
    width = max(p.r, max((len(r_) for r_ in new_rows), default=1), 1)
    adj = _pad_rows(new_rows, width)

    # --- 3. reconnect + re-run block assignment and Alg-2 refine
    entry = medoid(x_new)
    connect_to_entry(x_new, adj, entry)
    capacity = p.capacity or max_capacity_for(p.r)
    blocks = bnf_blocks(adj, capacity, seed=p.seed)
    builder = GraphBuilder(BuildConfig(backend=p.build_backend,
                                       batch_size=p.build_batch,
                                       knn_mode=p.build_knn))
    graph = builder.refine_bamg(x_new, adj, entry, blocks, capacity,
                                alpha=p.alpha, beta=p.beta,
                                sibling_edges=p.sibling_edges,
                                max_degree=p.r)
    return BAMGIndex.from_graph(x_new, graph, p), old2new
