"""repro: BAMG (Block-Aware Monotonic Graph) disk-ANN framework in JAX.

Reproduction + beyond-paper optimization of:
  Li & Xu, "BAMG: A Block-Aware Monotonic Graph Index for Disk-Based
  Approximate Nearest Neighbor Search" (2025).

Public entry points:
  repro.core.engine.BAMGIndex     -- build / save / load / search
  repro.configs.registry          -- assigned architecture configs
  repro.launch.dryrun             -- multi-pod dry-run driver
"""

__version__ = "1.0.0"
