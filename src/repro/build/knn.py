"""Clustered approximate kNN graph for the batched build backend.

The host NSG pipeline starts from an exact kNN graph -- an O(n^2 d)
all-pairs top-k that dwarfs every other stage as n grows.  The batched
backend replaces it with the standard IVF/EFANNA-style candidate
generation: k-means the corpus into ~sqrt(n) clusters (jit'd Lloyd
iterations), then compute each point's exact top-k among the members of
its cluster's `n_probe` nearest clusters only -- one padded matmul per
cluster, O(n * n_probe * n/c * d) total.

The result is a kNN graph with the same contract as
`repro.core.distances.knn_graph` (int32 (n, k), -1 padded, self excluded)
whose rows are exact within the probed candidate set.  NSG construction
consumes kNN rows only as supplemental candidates next to the frontier
pool, so the occasional missed true neighbor is recovered by the beam --
end recall stays within the parity budget (tests/test_build_parity.py).

Shapes are bucketed to powers of two so a handful of compilations serve
all clusters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distances import knn_graph, pairwise_sq_l2

_PAD = 1e17  # huge-norm sentinel row: never enters a top-k (cf. l2_topk)


@functools.partial(jax.jit, static_argnames=("k",))
def _topk_chunk(q, base, k: int):
    d = (jnp.sum(q * q, axis=1, keepdims=True)
         + jnp.sum(base * base, axis=1)[None, :]
         - 2.0 * (q @ base.T))
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


@jax.jit
def _assign(x, centers):
    d = (jnp.sum(x * x, axis=1, keepdims=True)
         + jnp.sum(centers * centers, axis=1)[None, :]
         - 2.0 * (x @ centers.T))
    return jnp.argmin(d, axis=1)


def _kmeans(x: np.ndarray, c: int, iters: int, seed: int) -> np.ndarray:
    """Lloyd's algorithm; returns (n,) int cluster assignment."""
    n = len(x)
    rng = np.random.default_rng(seed)
    centers = x[rng.choice(n, size=c, replace=False)].astype(np.float32)
    xj = jnp.asarray(x, jnp.float32)
    assign = None
    for _ in range(iters):
        assign = np.asarray(_assign(xj, jnp.asarray(centers)))
        sums = np.zeros((c, x.shape[1]), np.float64)
        np.add.at(sums, assign, x)
        counts = np.bincount(assign, minlength=c)
        live = counts > 0
        centers[live] = (sums[live] / counts[live, None]).astype(np.float32)
    return assign


def _bucket(m: int) -> int:
    """Next power of two >= m (min 32) -- bounds jit recompilations."""
    b = 32
    while b < m:
        b *= 2
    return b


def clustered_knn_graph(
    x: np.ndarray,
    k: int,
    n_clusters: int | None = None,
    n_probe: int = 8,
    iters: int = 4,
    seed: int = 0,
) -> np.ndarray:
    """Approximate kNN graph via per-cluster probed exact top-k."""
    n, d = x.shape
    c = n_clusters or max(8, int(np.sqrt(n)))
    c = min(c, n)
    if n <= 2048 or c < n_probe:     # small corpora: exact is already cheap
        return knn_graph(x, k)
    assign = _kmeans(x, c, iters, seed)
    centers = np.zeros((c, d), np.float64)
    np.add.at(centers, assign, x)
    counts = np.bincount(assign, minlength=c)
    centers[counts > 0] /= counts[counts > 0, None]
    # n_probe nearest clusters per cluster (by center distance, incl. self)
    cd = pairwise_sq_l2(centers, centers)
    probes = np.argsort(cd, axis=1, kind="stable")[:, :n_probe]

    members = [np.nonzero(assign == ci)[0] for ci in range(c)]
    adj = -np.ones((n, k), np.int32)
    for ci in range(c):
        q_ids = members[ci]
        if not len(q_ids):
            continue
        cand = np.concatenate([members[pj] for pj in probes[ci]])
        kk = min(k + 1, len(cand))
        qb = _bucket(len(q_ids))
        cb = _bucket(len(cand))
        q = np.zeros((qb, d), np.float32)
        q[: len(q_ids)] = x[q_ids]
        base = np.full((cb, d), _PAD, np.float32)
        base[: len(cand)] = x[cand]
        _, idx = _topk_chunk(jnp.asarray(q), jnp.asarray(base), kk)
        idx = np.asarray(idx)[: len(q_ids)]
        ids = np.where(idx < len(cand), cand[np.clip(idx, 0, len(cand) - 1)],
                       -1)
        for row_i, p in enumerate(q_ids.tolist()):
            row = ids[row_i]
            row = row[(row != p) & (row >= 0)][:k]
            adj[p, : len(row)] = row
    return adj
