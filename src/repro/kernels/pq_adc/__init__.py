from .ops import pq_adc  # noqa: F401
from .ref import pq_adc_ref  # noqa: F401
