"""Vectorized masked RobustPrune (Vamana) / MRNG edge selection (NSG).

The host loop (`repro.core.graph_build.robust_prune`) scans candidates in
ascending distance from p and keeps v unless an already kept u occludes it
(`alpha * d(u, v) <= d(p, v)`).  The kept set grows sequentially, so the
scan cannot be parallelized across candidates -- but it *can* run for a
whole batch of nodes at once, and the sequential axis can be the *kept*
set instead of the candidate list: the earliest candidate no kept entry
occludes is itself kept (first-survivor rounds), so each jitted round
promotes one candidate per row and occludes all later candidates against
it in a single (B, C, D) op.  Rounds = kept count (<= r), not C.

Exact-parity contract with the host reference (pinned by
tests/test_build_parity.py): candidates are deduplicated by id (ascending,
like `np.unique`), self is dropped, the scan order is a stable sort by
distance (ties break toward lower id), distances use the same f32
subtract-square-sum form as `graph_build._dists_to`, the occlusion test is
the same `alpha * duv <= dpv`, and the kept set caps at r.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.l2_topk.ops import sq_l2_rowwise


@functools.partial(jax.jit, static_argnames=("r",))
def _prune_batch(x, p_ids, cand_ids, cand_d, r: int, alpha: float):
    """x (N, D) f32; p_ids (B,) int32; cand_ids (B, C) int32 with -1 pad;
    cand_d (B, C) f32 (ignored where id < 0).  Returns kept (B, r) int32
    ids, -1 padded, in selection (ascending-distance) order.
    """
    b, c = cand_ids.shape
    sentinel = jnp.iinfo(jnp.int32).max
    ids = jnp.where((cand_ids >= 0) & (cand_ids != p_ids[:, None]),
                    cand_ids, -1)

    # dedupe by id, ascending (np.unique semantics): sort by id, mask runs
    key = jnp.where(ids < 0, sentinel, ids)
    o1 = jnp.argsort(key, axis=1, stable=True)
    key_s = jnp.take_along_axis(key, o1, axis=1)
    ids_s = jnp.take_along_axis(ids, o1, axis=1)
    d_s = jnp.take_along_axis(cand_d, o1, axis=1)
    dup = jnp.pad(key_s[:, 1:] == key_s[:, :-1], ((0, 0), (1, 0)))
    ids_s = jnp.where(dup, -1, ids_s)
    d_s = jnp.where((ids_s < 0) | dup, jnp.inf, d_s)

    # stable sort by distance: ties break toward lower id (ids ascending)
    o2 = jnp.argsort(d_s, axis=1, stable=True)
    ids_s = jnp.take_along_axis(ids_s, o2, axis=1)
    d_s = jnp.take_along_axis(d_s, o2, axis=1)
    vecs = x[jnp.clip(ids_s, 0)]                            # (B, C, D)

    # First-survivor rounds: the earliest candidate that no kept entry
    # occludes is itself kept (the host scan would reach it with exactly
    # this kept set), so each round promotes one candidate per row and
    # occludes every *later* candidate against it in a single (B, C, D)
    # distance op.  Rounds = kept count (<= r, typically ~R/2), not C --
    # identical decisions to the host loop in ~5x fewer steps.
    rows = jnp.arange(b)
    pos = jnp.arange(c)
    valid = jnp.isfinite(d_s)

    def cond(carry):
        occl, kept, cnt = carry
        avail = valid & ~occl & ~kept & (cnt < r)[:, None]
        return jnp.any(avail)

    def step(carry):
        occl, kept, cnt = carry
        avail = valid & ~occl & ~kept & (cnt < r)[:, None]
        act = jnp.any(avail, axis=1)                        # (B,)
        nxt = jnp.argmax(avail, axis=1)                     # first True
        kept = kept.at[rows, nxt].max(act)
        vj = vecs[rows, nxt]                                # (B, D)
        duv = sq_l2_rowwise(vj, vecs)                       # (B, C)
        later = pos[None, :] > nxt[:, None]
        occl = occl | (act[:, None] & later
                       & (alpha * duv <= d_s))
        return occl, kept, cnt + act

    occl0 = jnp.zeros((b, c), bool)
    _, kept, _ = jax.lax.while_loop(
        cond, step, (occl0, occl0, jnp.zeros(b, jnp.int32)))

    # compress kept entries (already in selection order) to the first r slots
    o3 = jnp.argsort(~kept, axis=1, stable=True)[:, :r]
    out = jnp.take_along_axis(jnp.where(kept, ids_s, -1), o3, axis=1)
    return out


def robust_prune_batch(
    x: np.ndarray,
    p_ids: np.ndarray,
    cand_ids: np.ndarray,
    cand_d: np.ndarray | None,
    r: int,
    alpha: float = 1.0,
) -> np.ndarray:
    """Batched RobustPrune; returns (B, r) int32 kept ids, -1 padded.

    `cand_d=None` recomputes candidate distances from x (the common build
    path, matching the host builders which re-derive distances after
    merging candidate sources).
    """
    p_ids = np.asarray(p_ids, np.int64)
    cand_ids = np.asarray(cand_ids, np.int32)
    xj = jnp.asarray(x, jnp.float32)
    if cand_d is None:
        d = sq_l2_rowwise(jnp.asarray(x[p_ids], jnp.float32),
                          xj[jnp.clip(jnp.asarray(cand_ids), 0)],
                          valid=jnp.asarray(cand_ids) >= 0)
    else:
        d = jnp.asarray(cand_d, jnp.float32)
    out = _prune_batch(xj, jnp.asarray(p_ids, jnp.int32),
                       jnp.asarray(cand_ids), d, r=r, alpha=float(alpha))
    return np.asarray(out)


def robust_prune_inc(
    p_vec: np.ndarray,
    cand_ids: np.ndarray,
    cand_vecs: np.ndarray,
    r: int,
    alpha: float = 1.0,
) -> np.ndarray:
    """Incremental RobustPrune over explicit candidate vectors.

    The streaming entry point (delta-layer inserts, consolidation edge
    repair): unlike `robust_prune_batch` there is no global corpus array --
    the caller hands over the candidate vectors directly, so it works on a
    growing buffer that mixes frozen-base and delta points.  Same contract
    as the host reference: dedupe by id ascending, stable scan by distance
    (ties toward lower id), keep v unless a kept u has
    ``alpha * d(u, v) <= d(p, v)``, cap at r.  Returns kept ids (<= r,)
    int64 in selection order.
    """
    cand_ids = np.asarray(cand_ids, np.int64)
    cand_vecs = np.asarray(cand_vecs, np.float32)
    p_vec = np.asarray(p_vec, np.float32)
    if len(cand_ids) == 0:
        return np.empty(0, np.int64)
    uniq, first = np.unique(cand_ids, return_index=True)
    cand_ids, cand_vecs = uniq, cand_vecs[first]
    diff = cand_vecs - p_vec[None, :]
    cand_d = np.einsum("nd,nd->n", diff, diff)
    o = np.argsort(cand_d, kind="stable")
    kept: list[int] = []
    kept_vecs: list[np.ndarray] = []
    for i in o.tolist():
        dv = float(cand_d[i])
        xv = cand_vecs[i]
        ok = True
        for xu in kept_vecs:
            duv = float(np.dot(xu - xv, xu - xv))
            if alpha * duv <= dv:
                ok = False
                break
        if ok:
            kept.append(int(cand_ids[i]))
            kept_vecs.append(xv)
            if len(kept) >= r:
                break
    return np.asarray(kept, np.int64)
