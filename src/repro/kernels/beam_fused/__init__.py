from .ops import beam_hops  # noqa: F401
from .ref import beam_hops_ref  # noqa: F401
