"""ServeRuntime: placement + compiled instruction stream + interpreter.

The distributed serving entry point.  Construction binds the fleet onto a
device mesh (`ShardPlacement.plan`) and compiles the static serving
program for its topology (`compile_program`); `serve_batch` then just
hands batches to the interpreter.  The legacy `ShardedFrontend` is a thin
compatibility shim over this class -- every query it serves flows through
the instruction stream.

Shard-level administration (`mark_down` / `mark_up` / `health`) keeps the
PR 7 semantics and report shape; the `health()` snapshot additionally
carries the replica map and worker count so a fleet operator can see
*where* a shard is running, not just whether it is up.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.engine import BAMGIndex, BAMGParams

from ..ann_engine import BatchedANNEngine, EngineConfig
from .instructions import InstructionInterpreter, compile_program
from .placement import ShardPlacement


def build_shard_fleet(x: np.ndarray, n_shards: int,
                      params: Optional[BAMGParams] = None,
                      config: Optional[EngineConfig] = None):
    """Round-robin partition + per-shard BAMG build.

    Returns (shard_vids, engines, host_indexes): the raw fleet pieces a
    `ServeRuntime` or `ShardedFrontend` is assembled from."""
    params = params or BAMGParams()
    config = config if config is not None else EngineConfig()
    owner = np.arange(len(x)) % n_shards
    vids, engines, indexes = [], [], []
    if len(x) < 3 * n_shards:
        raise ValueError(
            f"n_shards={n_shards} leaves <3 points per shard for a "
            f"{len(x)}-point corpus; a graph sub-index needs >=3 points")
    for s in range(n_shards):
        ids = np.nonzero(owner == s)[0]
        ns = len(ids)
        # small shards: graph-build degree/knn params cannot exceed n-1
        # (same clamp as navgraph's recursive layer builds)
        p = dataclasses.replace(
            params, seed=s, r=min(params.r, ns - 1),
            knn_k=min(params.knn_k, ns - 1),
            l_build=min(params.l_build, max(4, ns)))
        idx = BAMGIndex.build(x[ids], p)
        vids.append(ids)
        indexes.append(idx)
        engines.append(BatchedANNEngine.from_index(idx, config))
    return vids, engines, indexes


class ServeRuntime:
    """Distributed scatter-gather serving over a placed shard fleet.

    `shard_vids[s]` maps shard-local row ids back to global corpus ids.
    `mesh` (a `repro.launch.mesh` host mesh) and `n_replicas` control
    placement; with neither, every shard gets one replica on the default
    device -- exactly the legacy single-process fleet.
    """

    def __init__(self, shard_vids: Sequence[np.ndarray],
                 engines: Sequence[BatchedANNEngine],
                 host_indexes: Optional[Sequence[BAMGIndex]] = None,
                 mesh=None, n_replicas: int = 1):
        assert len(shard_vids) == len(engines)
        self.shard_vids = [np.asarray(v, np.int64) for v in shard_vids]
        # host BAMGIndex per shard (comparisons / persistence); None when
        # the runtime was assembled from bare engine arrays
        self.host_indexes = list(host_indexes) if host_indexes else None
        # -1 (absent) local ids pass through as global -1 via a sentinel row
        self._lut = [np.concatenate([v, [-1]]) for v in self.shard_vids]
        self.placement = ShardPlacement.plan(engines, mesh=mesh,
                                             n_replicas=n_replicas)
        self.program = compile_program(len(engines))
        self.interpreter = InstructionInterpreter(self.placement, self._lut)

    @classmethod
    def build(cls, x: np.ndarray, n_shards: int,
              params: Optional[BAMGParams] = None,
              config: Optional[EngineConfig] = None,
              mesh=None, n_replicas: int = 1) -> "ServeRuntime":
        """Partition + build + place a fleet in one call."""
        vids, engines, indexes = build_shard_fleet(x, n_shards,
                                                   params=params,
                                                   config=config)
        return cls(vids, engines, host_indexes=indexes, mesh=mesh,
                   n_replicas=n_replicas)

    @property
    def n_shards(self) -> int:
        return self.placement.n_shards

    @property
    def engines(self) -> list[BatchedANNEngine]:
        """Replica-0 engines in shard order (the caller's own objects)."""
        return self.placement.engines

    # --- shard health -------------------------------------------------------
    def mark_down(self, shard: int, reason: str = "marked down") -> None:
        self.placement.mark_down(shard, reason)

    def mark_up(self, shard: int) -> None:
        self.placement.mark_up(shard)

    def health(self) -> dict:
        """Snapshot: up/down counts, per-shard state, replica/worker map."""
        health = self.placement.shard_health
        down = [s for s, h in enumerate(health) if not h.up]
        return {"n_shards": self.n_shards,
                "shards_up": self.n_shards - len(down),
                "shards_down": down,
                "per_shard": [dataclasses.asdict(h) for h in health],
                "replicas": [[r.up for r in group]
                             for group in self.placement.shard_replicas],
                "n_workers": len(self.placement.workers)}

    # --- serving ------------------------------------------------------------
    def _scatter_exclude(self, exclude) -> Optional[list]:
        """Global tombstone ids -> per-shard local bool masks (None when a
        shard holds no tombstoned point, so its engine skips the merge)."""
        if exclude is None:
            return None
        ex = np.asarray(list(exclude), np.int64)
        if len(ex) == 0:
            return None
        out = []
        for vids in self.shard_vids:
            m = np.isin(vids, ex)
            out.append(m if m.any() else None)
        return out

    def serve_batch(self, queries: np.ndarray, k: int,
                    with_status: bool = False, *,
                    l: Optional[int] = None,
                    max_hops: Optional[int] = None,
                    exclude=None):
        """(B, D) queries -> global (ids (B, k) int64, dists (B, k)).

        One walk of the compiled program: SCATTER stages the batch and
        snapshots the shard mask, each live RUN makes one batched engine
        call on a round-robin replica (GATHER remaps local->global ids),
        and MERGE takes the global top-k in a single pass.  Masked shards
        are skipped without an engine call; a replica that raises is
        marked down and its RUN retried on the next replica.  With every
        shard down the answer is all -1/+inf.  `with_status=True`
        additionally returns a `ServeStatus` whose `degraded` flags mark
        answers that missed at least one shard.  `l`/`max_hops` shrink the
        beam for this batch only (deadline-pressed micro-batches).
        `exclude` is an iterable of *global* tombstoned ids (streaming
        freshness); they are scattered to shard-local masks and never
        appear in the merged top-k.
        """
        ids, dists, status = self.interpreter.execute(
            self.program, queries, k, l=l, max_hops=max_hops,
            exclude=self._scatter_exclude(exclude))
        if not with_status:
            return ids, dists
        return ids, dists, status
