from . import sharding, tree  # noqa: F401
