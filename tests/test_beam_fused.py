"""Fused beam-hop kernel: merge equivalence, kernel parity, engine parity.

Three layers, each anchoring the next:

1. `pool_merge_ranked` (the sort-free merge the fused kernel inlines) is
   bit-identical to `pool_merge` -- swept over duplicate ids across the
   incoming chunks, all-(-1) padded rows, distance ties, and chained
   merges (the output invariant feeds the next call).
2. `beam_hops` interpret (the Pallas program on CPU) matches the jnp
   oracle `beam_hops_ref` in both scoring modes, and the ref matches the
   serve engine's unfused scan by construction (same step ops + merge).
3. The serve engine under a `fused*` backend returns bit-identical
   (ids, dists) to the unfused backend, and the fused construction
   frontier matches the width-1 batched beam.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.build.pool import pool_merge, pool_merge_ranked
from repro.core.distances import exact_knn
from repro.core.engine import BAMGIndex, BAMGParams
from repro.data.synthetic import make_vector_dataset
from repro.kernels.beam_fused import beam_hops, beam_hops_ref
from repro.serve import BatchedANNEngine, EngineConfig

RNG = np.random.default_rng(7)


# --- layer 1: pool_merge_ranked == pool_merge --------------------------------

def _sorted_pool(b, l, n_ids, n_dists=5):
    """Random pool satisfying the merge invariant: ascending (dist, id),
    unique valid ids, invalid entries exactly (-1, +inf, False).  Integer-
    quantized distances engineer ties."""
    pool_ids = np.full((b, l), -1, np.int32)
    pool_d = np.full((b, l), np.inf, np.float32)
    pool_exp = np.zeros((b, l), bool)
    nvalid = int(RNG.integers(0, l + 1))
    for bi in range(b):
        vids = RNG.choice(n_ids, size=min(nvalid, n_ids), replace=False)
        vd = RNG.integers(0, n_dists, size=len(vids)).astype(np.float32)
        o = np.lexsort((vids, vd))
        pool_ids[bi, : len(vids)] = vids[o]
        pool_d[bi, : len(vids)] = vd[o]
        pool_exp[bi, : len(vids)] = RNG.random(len(vids)) < 0.5
    return pool_ids, pool_d, pool_exp


def _assert_merges_equal(pool, cands, l):
    args = [jnp.asarray(a) for a in (*pool, *cands)]
    a = pool_merge(*args, l)
    r = pool_merge_ranked(*args, l)
    for got, want, name in zip(r, a, ("ids", "dists", "expanded")):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=name)
    return a


# fixed shapes keep the jit cache to a handful of entries across the sweep
@pytest.mark.parametrize("lo", (1, 5, 9, 16))
def test_pool_merge_ranked_equivalence_sweep(lo):
    b, l, r, n_ids = 3, 9, 7, 14
    for trial in range(25):
        pool = _sorted_pool(b, l, n_ids)
        cand_ids = RNG.integers(-1, n_ids, size=(b, r)).astype(np.int32)
        cand_d = np.where(cand_ids < 0, np.inf,
                          RNG.integers(0, 5, size=(b, r))).astype(np.float32)
        merged = _assert_merges_equal(pool, (cand_ids, cand_d), lo)
        # chained: the (invariant-satisfying) output is the next pool
        cand2 = RNG.integers(-1, n_ids, size=(b, r)).astype(np.int32)
        cd2 = np.where(cand2 < 0, np.inf,
                       RNG.integers(0, 5, size=(b, r))).astype(np.float32)
        _assert_merges_equal([np.asarray(m) for m in merged],
                             (cand2, cd2), lo)


def test_pool_merge_ranked_all_padded_candidates():
    """An all-(-1) candidate chunk must leave the pool bit-identical."""
    pool = _sorted_pool(4, 8, 20)
    cand_ids = np.full((4, 6), -1, np.int32)
    cand_d = np.full((4, 6), np.inf, np.float32)
    out = _assert_merges_equal(pool, (cand_ids, cand_d), 8)
    np.testing.assert_array_equal(np.asarray(out[0]), pool[0])
    np.testing.assert_array_equal(np.asarray(out[2]), pool[2])


def test_pool_merge_ranked_duplicates_across_chunks():
    """A candidate duplicating a pool id is dropped (the incumbent keeps
    its expanded flag); duplicates within the chunk collapse to one."""
    pool_ids = np.array([[3, 7, -1, -1]], np.int32)
    pool_d = np.array([[1.0, 2.0, np.inf, np.inf]], np.float32)
    pool_exp = np.array([[True, False, False, False]])
    cand_ids = np.array([[7, 5, 5, 3]], np.int32)     # 7,3 dup pool; 5 dup 5
    cand_d = np.array([[2.0, 1.5, 1.5, 1.0]], np.float32)
    out = _assert_merges_equal((pool_ids, pool_d, pool_exp),
                               (cand_ids, cand_d), 4)
    np.testing.assert_array_equal(np.asarray(out[0]), [[3, 5, 7, -1]])
    np.testing.assert_array_equal(np.asarray(out[2]),
                                  [[True, False, False, False]])


# --- layer 2: beam_hops interpret vs ref -------------------------------------

def _graph(n=300, r=8, m=4, k=16, d=6, b=5, l=12, seed=3):
    rng = np.random.default_rng(seed)
    adj = rng.integers(0, n, (n, r)).astype(np.int32)
    adj[rng.random((n, r)) < 0.2] = -1                # padded slots
    x = rng.normal(size=(n, d)).astype(np.float32)
    codes = rng.integers(0, k, (n, m)).astype(np.int32)
    tables = rng.random((b, m, k)).astype(np.float32)
    queries = rng.normal(size=(b, d)).astype(np.float32)
    seeds = np.sort(rng.choice(n, (b, 3), replace=False).astype(np.int32), 1)
    pool_ids = np.full((b, l), -1, np.int32)
    pool_d = np.full((b, l), np.inf, np.float32)
    pool_ids[:, :3] = seeds
    pool_d[:, :3] = np.sort(rng.random((b, 3)), axis=1)
    pool_exp = np.zeros((b, l), bool)
    return (jnp.asarray(adj), jnp.asarray(x), jnp.asarray(codes),
            jnp.asarray(tables), jnp.asarray(queries),
            jnp.asarray(pool_ids), jnp.asarray(pool_d),
            jnp.asarray(pool_exp))


def _assert_hops_match(ref, out):
    names = ("pool_ids", "pool_d", "pool_exp", "hops",
             "trace_ids", "trace_d", "next_id", "done")
    for got, want, name in zip(out, ref, names):
        got, want = np.asarray(got), np.asarray(want)
        if want.dtype.kind == "f":   # one-hot matmul vs gather: ulp noise
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                       err_msg=name)
        else:
            np.testing.assert_array_equal(got, want, err_msg=name)


def test_beam_hops_interpret_matches_ref_adc():
    adj, x, codes, tables, _, pi, pd, pe = _graph()
    ref = beam_hops_ref(adj, pi, pd, pe, 6, mode="adc",
                        tables=tables, codes=codes)
    out = beam_hops(adj, pi, pd, pe, 6, tables=tables, codes=codes,
                    backend="interpret", tile_b=4, n_chunk=128)
    _assert_hops_match(ref, out)


def test_beam_hops_interpret_matches_ref_l2():
    adj, x, codes, tables, queries, pi, pd, pe = _graph()
    n2 = jnp.sum(x * x, axis=1)
    ref = beam_hops_ref(adj, pi, pd, pe, 6, mode="l2",
                        x=x, n2=n2, queries=queries)
    out = beam_hops(adj, pi, pd, pe, 6, x=x, n2=n2, queries=queries,
                    backend="interpret", tile_b=4, n_chunk=128)
    _assert_hops_match(ref, out)


def test_beam_hops_exhausts_and_reports_done():
    """With a hop budget past exhaustion every row reports done, the next
    pick is -1, and the trace tail is (-1, +inf)."""
    adj, x, codes, tables, _, pi, pd, pe = _graph(n=40, l=40)
    out = beam_hops_ref(adj, pi, pd, pe, 60, mode="adc",
                        tables=tables, codes=codes)
    _, _, _, hops, tid, td, next_id, done = out
    assert bool(np.asarray(done).all())
    assert (np.asarray(next_id) == -1).all()
    assert (np.asarray(hops) <= 40).all()
    tail = np.asarray(tid)[np.arange(5), np.asarray(hops)]
    assert (tail == -1).all()


# --- streaming mode: HBM-resident corpus, double-buffered DMA gathers --------

def test_beam_hops_stream_interpret_matches_ref_adc():
    adj, x, codes, tables, _, pi, pd, pe = _graph()
    ref = beam_hops_ref(adj, pi, pd, pe, 6, mode="adc",
                        tables=tables, codes=codes)
    out = beam_hops(adj, pi, pd, pe, 6, tables=tables, codes=codes,
                    backend="stream_interpret", tile_b=4, n_chunk=128)
    _assert_hops_match(ref, out)


def test_beam_hops_stream_interpret_matches_ref_l2():
    adj, x, codes, tables, queries, pi, pd, pe = _graph()
    n2 = jnp.sum(x * x, axis=1)
    ref = beam_hops_ref(adj, pi, pd, pe, 6, mode="l2",
                        x=x, n2=n2, queries=queries)
    out = beam_hops(adj, pi, pd, pe, 6, x=x, n2=n2, queries=queries,
                    backend="stream_interpret", tile_b=4, n_chunk=128)
    _assert_hops_match(ref, out)


@pytest.mark.parametrize("n_chunk", (64, 256))
def test_beam_hops_stream_bitwise_matches_resident(n_chunk):
    """Streaming must be *bit-identical* to the resident program at every
    slab size: both walk identical chunk contents in identical order and
    the one-hot contraction's 0.0 contributions are exact, so the DMA
    chunking can never move a single bit of ids or dists."""
    adj, x, codes, tables, queries, pi, pd, pe = _graph(n=256)
    n2 = jnp.sum(x * x, axis=1)
    kw = dict(tile_b=4)
    res = beam_hops(adj, pi, pd, pe, 6, tables=tables, codes=codes,
                    backend="interpret", n_chunk=128, **kw)
    stream = beam_hops(adj, pi, pd, pe, 6, tables=tables, codes=codes,
                       backend="stream_interpret", n_chunk=n_chunk, **kw)
    for got, want in zip(stream, res):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    res = beam_hops(adj, pi, pd, pe, 6, x=x, n2=n2, queries=queries,
                    backend="interpret", n_chunk=128, **kw)
    stream = beam_hops(adj, pi, pd, pe, 6, x=x, n2=n2, queries=queries,
                       backend="stream_interpret", n_chunk=n_chunk, **kw)
    for got, want in zip(stream, res):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_beam_hops_rejects_unknown_backend():
    adj, x, codes, tables, _, pi, pd, pe = _graph()
    with pytest.raises(ValueError, match="backend"):
        beam_hops(adj, pi, pd, pe, 2, tables=tables, codes=codes,
                  backend="bogus")


def test_kernel_tiling_errors_name_offending_dims():
    """The raw kernels (callable without the ops-layer padding) must raise
    ValueErrors naming the offending dims, not bare asserts."""
    from repro.kernels.beam_fused import (beam_hops_adc_pallas,
                                          beam_hops_adc_stream)
    adj, x, codes, tables, _, pi, pd, pe = _graph()   # b=5, n=300
    f32 = lambda a: jnp.asarray(a, jnp.float32)       # noqa: E731
    args = (f32(adj), f32(codes), f32(tables), f32(pi), f32(pd), f32(pe))
    for fn in (beam_hops_adc_pallas, beam_hops_adc_stream):
        with pytest.raises(ValueError, match=r"b=5 .* tile_b=4"):
            fn(*args, 2, tile_b=4, n_chunk=300, interpret=True)
        with pytest.raises(ValueError, match=r"n=300 .* n_chunk=128"):
            fn(*args, 2, tile_b=5, n_chunk=128, interpret=True)


def test_vmem_estimator_sanity():
    from repro.kernels import beam_fused as bf
    small = bf.vmem_bytes(4096, 32, m=16)
    big = bf.vmem_bytes(1_000_000, 32, m=16)
    assert small < big
    # resident is corpus-dominated: N * (R + M) f32 is a hard lower bound
    assert big > 1_000_000 * (32 + 16) * 4
    # streaming footprint is independent of N (that is the whole point)
    s_small = bf.stream_vmem_bytes(4096, 32, m=16, n_chunk=1024)
    s_big = bf.stream_vmem_bytes(1_000_000, 32, m=16, n_chunk=1024)
    assert s_small == s_big
    assert s_big < big
    # fits_vmem is the exact <= budget comparison
    assert bf.fits_vmem(1000, 8, m=4, budget=bf.vmem_bytes(1000, 8, m=4))
    assert not bf.fits_vmem(1000, 8, m=4,
                            budget=bf.vmem_bytes(1000, 8, m=4) - 1)
    # l2 mode sizes with d=; exactly one of m=/d= is required
    assert bf.vmem_bytes(1000, 8, d=16) > bf.stream_vmem_bytes(
        1000, 8, d=16, n_chunk=128)
    with pytest.raises(ValueError, match="exactly one"):
        bf.vmem_bytes(1000, 8)
    with pytest.raises(ValueError, match="exactly one"):
        bf.vmem_bytes(1000, 8, m=4, d=16)


def test_vmem_budget_env_override(monkeypatch):
    from repro.kernels import beam_fused as bf
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "12345")
    assert bf.vmem_budget_bytes() == 12345
    assert not bf.fits_vmem(4096, 32, m=16)          # ~12 kB budget
    monkeypatch.delenv("REPRO_VMEM_BUDGET")
    assert bf.vmem_budget_bytes() == 16 * 2 ** 20
    assert bf.fits_vmem(4096, 32, m=16)


# --- layer 3: engine + frontier parity ---------------------------------------

@pytest.fixture(scope="module")
def built():
    ds = make_vector_dataset("fused", n=150, d=12, nq=6, k_gt=5,
                             n_clusters=3, seed=0)
    idx = BAMGIndex.build(ds.base, BAMGParams(alpha=2, beta=1.05, r=12,
                                              l_build=24, knn_k=12, seed=0))
    return ds, idx


@pytest.mark.parametrize("cfg", (dict(l=150, max_hops=150),
                                 dict(l=32, max_hops=16),
                                 dict(l=32, max_hops=16, rerank=8)))
def test_engine_fused_ref_bitwise_vs_unfused(built, cfg):
    ds, idx = built
    e0 = BatchedANNEngine.from_index(idx, EngineConfig(backend="ref", **cfg))
    e1 = BatchedANNEngine.from_index(idx,
                                     EngineConfig(backend="fused_ref", **cfg))
    i0, d0 = e0.search_batch(ds.queries, 5)
    i1, d1 = e1.search_batch(ds.queries, 5)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(d0, d1)


def test_engine_fused_interpret_bitwise_vs_unfused(built):
    """The Pallas program (interpret mode on CPU) drives the whole hop
    loop: identical pool -> identical exact re-rank -> identical ids."""
    ds, idx = built
    cfg = dict(l=32, max_hops=16)
    e0 = BatchedANNEngine.from_index(idx, EngineConfig(backend="ref", **cfg))
    e1 = BatchedANNEngine.from_index(
        idx, EngineConfig(backend="fused_interpret", **cfg))
    i0, d0 = e0.search_batch(ds.queries, 5)
    i1, d1 = e1.search_batch(ds.queries, 5)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(d0, d1)


def test_engine_fused_stream_interpret_bitwise_vs_unfused(built):
    """The HBM-streaming Pallas program (interpret mode on CPU) drives the
    whole hop loop and must land on the same pools as the unfused scan."""
    ds, idx = built
    cfg = dict(l=32, max_hops=16)
    e0 = BatchedANNEngine.from_index(idx, EngineConfig(backend="ref", **cfg))
    e1 = BatchedANNEngine.from_index(
        idx, EngineConfig(backend="fused_stream_interpret", **cfg))
    i0, d0 = e0.search_batch(ds.queries, 5)
    i1, d1 = e1.search_batch(ds.queries, 5)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(d0, d1)


def test_engine_fused_exhaustive_matches_host_and_brute_force(built):
    """The fused engine inherits the serve-contract of
    tests/test_serve_engine.py: exhaustive config == brute force == host."""
    from repro.core.search import search_bamg
    ds, idx = built
    n = len(ds.base)
    cands = idx.batch_arrays(n_entry_cands=256)["entry_cands"]
    eng = BatchedANNEngine.from_index(
        idx, EngineConfig(l=n, max_hops=n, n_entry=len(cands),
                          backend="fused_ref"))
    ids, _ = eng.search_batch(ds.queries, 5)
    _, gi = exact_knn(ds.base, ds.queries, 5)
    np.testing.assert_array_equal(ids, gi)
    for qi, q in enumerate(ds.queries):
        r = search_bamg(idx.store, idx.codes, idx.codec.adc_table(q), q,
                        cands.tolist(), k=5, l=n, alpha=n)
        np.testing.assert_array_equal(ids[qi], r.ids)


def test_engine_rerank_none_equals_rerank_l(built):
    """rerank=None defaults to the full pool prefix: bit-identical to an
    explicit rerank=l, on both the fused and unfused paths."""
    ds, idx = built
    for backend in ("ref", "fused_ref"):
        e0 = BatchedANNEngine.from_index(
            idx, EngineConfig(l=32, max_hops=16, rerank=None,
                              backend=backend))
        e1 = BatchedANNEngine.from_index(
            idx, EngineConfig(l=32, max_hops=16, rerank=32, backend=backend))
        i0, d0 = e0.search_batch(ds.queries, 5)
        i1, d1 = e1.search_batch(ds.queries, 5)
        np.testing.assert_array_equal(i0, i1)
        np.testing.assert_array_equal(d0, d1)
        assert e0.rerank_capacity == e1.rerank_capacity == 32


def test_frontier_fused_matches_batched_width1(built):
    """With an exhaustive pool (no evictions) the fused frontier visits
    the identical node sequence as the width-1 seen-mask beam."""
    from repro.build.frontier import frontier_pools
    from repro.core.distances import knn_graph, medoid
    ds, _ = built
    x = ds.base
    knn = knn_graph(x, 12)
    med = medoid(x)
    nodes = np.arange(len(x))
    ids_b, d_b = frontier_pools(x, knn, [med], nodes, ef=len(x), max_hops=12,
                                batch=64, width=1, backend="batched")
    ids_f, d_f = frontier_pools(x, knn, [med], nodes, ef=len(x), max_hops=12,
                                batch=64, backend="fused_ref")
    np.testing.assert_array_equal(ids_b, ids_f)
    np.testing.assert_allclose(d_b, d_f, rtol=1e-5, atol=1e-4)


def test_frontier_fused_stream_bitwise_matches_fused_interpret(built):
    """The streaming frontier runs the same Pallas hop program through the
    DMA gathers: bit-identical pools to the resident interpret frontier."""
    from repro.build.frontier import frontier_pools
    from repro.core.distances import knn_graph, medoid
    ds, _ = built
    x = ds.base
    knn = knn_graph(x, 12)
    med = medoid(x)
    nodes = np.arange(len(x))
    kw = dict(ef=24, max_hops=8, batch=64)
    ids_i, d_i = frontier_pools(x, knn, [med], nodes,
                                backend="fused_interpret", **kw)
    ids_s, d_s = frontier_pools(x, knn, [med], nodes,
                                backend="fused_stream_interpret", **kw)
    np.testing.assert_array_equal(ids_i, ids_s)
    np.testing.assert_array_equal(d_i, d_s)


def test_build_with_fused_frontier(built):
    """BuildConfig.frontier_backend plumbs through to a working build."""
    from repro.build.builder import BuildConfig, GraphBuilder
    ds, _ = built
    gb = GraphBuilder(BuildConfig(backend="batched",
                                  frontier_backend="fused_ref",
                                  batch_size=64))
    adj, entry = gb.build_nsg(ds.base, r=12, l_build=24, knn_k=12, seed=0)
    n = len(ds.base)
    assert adj.shape == (n, 12)
    assert (adj >= -1).all() and (adj < n).all()
    assert (adj[adj >= 0] != np.repeat(np.arange(n), 12)
            [adj.ravel() >= 0]).all()                  # no self loops
    with pytest.raises(ValueError, match="frontier_backend"):
        BuildConfig(frontier_backend="bogus")
