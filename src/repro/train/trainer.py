"""Trainer: jit'd train step with gradient accumulation, remat-aware loss,
checkpoint/restart, and the manual-DP compressed-gradient mode.

`make_train_step(loss_fn, opt_cfg, ...)` builds a single jit-compiled
function  (state, batch) -> (state, metrics)  where state is
{"step", "params", "opt", ["ef"]}.

Gradient accumulation: the global batch is reshaped to
(accum, micro, ...) and scanned; gradients accumulate in f32.  This is the
memory lever for the big dry-run cells (microbatch the 4k-seq training
shapes) and doubles as the overlap lever: XLA pipelines the per-microbatch
DP collectives against the next microbatch's backward.

Fault tolerance contract (train/ft.py): state is a pure pytree -> any step
boundary is a consistent snapshot; data order is a function of step
(data/pipeline.py) -> restart replays identically.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .compression import compressed_psum, ef_init
from .optimizer import AdamWConfig, adamw_init, adamw_update


def init_train_state(params, opt_cfg: AdamWConfig, ef: bool = False) -> dict:
    state = {"step": jnp.zeros((), jnp.int32), "params": params,
             "opt": adamw_init(params)}
    if ef:
        state["ef"] = ef_init(params)
    return state


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig,
                    accum: int = 1, donate: bool = True):
    """loss_fn(params, batch) -> (loss, aux dict).  Returns jit'd step fn."""

    def grads_of(params, batch):
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, aux, g

    def step(state, batch):
        params = state["params"]
        if accum == 1:
            loss, aux, g = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                loss, _aux, g = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g, loss_sum), _ = jax.lax.scan(body, (g0, jnp.float32(0)), micro)
            g = jax.tree.map(lambda x: x / accum, g)
            loss = loss_sum / accum
            aux = {}
        new_params, new_opt, om = adamw_update(opt_cfg, g, state["opt"], params)
        new_state = dict(state, step=state["step"] + 1, params=new_params,
                         opt=new_opt)
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return jax.jit(step, donate_argnums=(0,) if donate else ())


def make_manual_dp_step(loss_fn: Callable, opt_cfg: AdamWConfig, mesh,
                        dp_axes: tuple = ("data",),
                        compression: str = "int8_ef"):
    """Explicit data parallelism under shard_map with a compressed gradient
    all-reduce (cross-pod DP at 1000-node scale -- DESIGN.md §4).

    The model itself must be replicable per-device (no model sharding);
    this is the cross-pod outer loop, used standalone for small models and
    in tests for convergence parity of the compressed exchange.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local_step(state, batch):
        params = state["params"]
        (loss, _aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        g, new_ef = compressed_psum(g, dp_axes, method=compression,
                                    err=state.get("ef"))
        loss = jax.lax.pmean(loss, dp_axes)
        new_params, new_opt, om = adamw_update(opt_cfg, g, state["opt"], params)
        new_state = dict(state, step=state["step"] + 1, params=new_params,
                         opt=new_opt)
        if new_ef is not None:
            new_state["ef"] = new_ef
        return new_state, {"loss": loss, **om}

    rep = P()

    def specs_like(tree, batch_like=False):
        if batch_like:
            return jax.tree.map(lambda _: P(dp_axes), tree)
        return jax.tree.map(lambda _: rep, tree)

    def step(state, batch):
        fn = shard_map(local_step, mesh=mesh,
                       in_specs=(specs_like(state), specs_like(batch, True)),
                       out_specs=(specs_like(state),
                                  {"loss": rep, "grad_norm": rep, "lr": rep}),
                       check_rep=False)
        return fn(state, batch)

    return jax.jit(step)
