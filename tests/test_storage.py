"""Storage layouts (paper Fig. 3) + I/O simulator byte accounting."""
import numpy as np
import pytest

from repro.core.io_sim import BLOCK_SIZE, BlockDevice, CostModel, IOStats
from repro.core.storage import (CoupledStorage, DecoupledStorage,
                                coupled_nodes_per_block, max_capacity_for)


def _graph(n, r, seed=0):
    rng = np.random.default_rng(seed)
    adj = rng.integers(0, n, (n, r)).astype(np.int32)
    adj[rng.random((n, r)) < 0.2] = -1
    return adj


def test_block_device_lru_and_counting():
    dev = BlockDevice(list(range(10)), cache_blocks=2, kind="graph")
    dev.read(0); dev.read(1)
    assert dev.stats.graph_reads == 2
    dev.read(0)                      # hit
    assert dev.stats.cache_hits == 1
    dev.read(2)                      # evicts 1
    dev.read(1)                      # miss again
    assert dev.stats.graph_reads == 4
    with pytest.raises(IndexError):
        dev.read(99)


def test_coupled_storage_roundtrip():
    n, d, r = 50, 16, 8
    x = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
    adj = _graph(n, r)
    st = CoupledStorage(x, adj)
    assert st.npb == BLOCK_SIZE // (4 * d + 4 + 4 * r)
    for vid in (0, 17, 49):
        rec = st.read_node_block(vid)
        s = st.slot_in_block(vid)
        assert rec.vids[s] == vid
        np.testing.assert_array_equal(rec.vecs[s], x[vid])
        np.testing.assert_array_equal(rec.nbrs[s], adj[vid])


def test_coupled_large_record_spans_blocks():
    n, d, r = 10, 1500, 8          # 6 KB record > 4 KB block
    x = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
    st = CoupledStorage(x, _graph(n, r))
    assert st.blocks_per_record == 2
    st.device.reset()
    st.read_node_block(3)
    assert st.device.stats.graph_reads == 2   # both span blocks counted


def _decoupled(n=60, d=32, r=6, cap=None, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    adj = _graph(n, r, seed)
    cap = cap or max_capacity_for(r)
    blocks = (np.arange(n) // cap).astype(np.int32)
    m = int(blocks.max()) + 1
    members = -np.ones((m, cap), np.int32)
    for b in range(m):
        mem = np.nonzero(blocks == b)[0]
        members[b, :len(mem)] = mem
    return x, adj, blocks, members, cap


def test_decoupled_graph_block_capacity_respects_block_size():
    x, adj, blocks, members, cap = _decoupled()
    st = DecoupledStorage(x, adj, blocks, members)
    assert cap * st.record_bytes <= BLOCK_SIZE
    with pytest.raises(ValueError):
        DecoupledStorage(x, adj, blocks, members, block_size=cap * 4)


def test_decoupled_oid_addressing_and_vectors():
    x, adj, blocks, members, cap = _decoupled()
    st = DecoupledStorage(x, adj, blocks, members)
    for vid in (0, 31, 59):
        oid = int(st.vid2oid[vid])
        assert int(st.oid2vid[oid]) == vid
        vec = st.read_vector(oid)
        np.testing.assert_allclose(vec, x[vid], rtol=1e-6)
        gb = st.gblock_of_oid(oid)
        blk = st.read_graph_block(gb)
        s = oid - gb * cap
        assert blk.vids[s] == vid
        nn = adj[vid][adj[vid] >= 0]
        got = blk.nbrs[s][blk.nbrs[s] >= 0]
        np.testing.assert_array_equal(np.sort(st.oid2vid[got]), np.sort(nn))


def test_vector_alignment_no_straddle():
    """d=960 (GIST regime): one 3840 B vector per 4 KB block, 1 read each."""
    x, adj, blocks, members, cap = _decoupled(n=30, d=960, r=6, cap=10)
    st = DecoupledStorage(x, adj, blocks, members)
    assert st.vecs_per_vblock == 1
    st.reset()
    st.read_vector(int(st.vid2oid[7]))
    assert st.vector_dev.stats.vector_reads == 1
    np.testing.assert_allclose(st.read_vector(int(st.vid2oid[7])), x[7],
                               rtol=1e-6)


def test_vector_larger_than_block():
    x, adj, blocks, members, cap = _decoupled(n=20, d=1100, r=4, cap=8)
    st = DecoupledStorage(x, adj, blocks, members)
    assert st.vblocks_per_vec == 2
    st.reset()
    v = st.read_vector(int(st.vid2oid[5]))
    assert st.vector_dev.stats.vector_reads == 2
    np.testing.assert_allclose(v, x[5], rtol=1e-6)


def test_cost_model_monotone():
    cm = CostModel()
    assert cm.qps(10, 100, 1000) > cm.qps(20, 100, 1000)
    assert cm.query_time_us(10, 0, 0) == pytest.approx(10 * cm.read_us)
