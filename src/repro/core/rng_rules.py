"""Edge-occlusion rules: RNG, MRNG, and the paper's BMRNG rules (§2.2, §3.1).

These are the *reference* (exact, O(n^2..n^3)) implementations used as
oracles by tests and by the exact BMRNG builder on small point sets. The
scalable path is core/bamg.py.

All geometry uses squared L2 (monotone with L2; lune membership unchanged).
"""
from __future__ import annotations

import numpy as np

from .distances import pairwise_sq_l2


def in_lune(d: np.ndarray, u: int, q: int, v: int) -> bool:
    """v in lune_{u,q}  <=>  d(u,v) < d(u,q) and d(q,v) < d(u,q)."""
    duq = d[u, q]
    return bool(d[u, v] < duq and d[q, v] < duq)


def rng_edges(x: np.ndarray) -> np.ndarray:
    """Classic RNG (undirected, as symmetric bool adjacency). O(n^3)."""
    d = pairwise_sq_l2(x, x)
    n = len(x)
    adj = np.zeros((n, n), bool)
    for u in range(n):
        for q in range(u + 1, n):
            duq = d[u, q]
            occ = np.any((d[u] < duq) & (d[q] < duq))
            if not occ:
                adj[u, q] = adj[q, u] = True
    return adj


def mrng_edges(x: np.ndarray, d: np.ndarray | None = None) -> np.ndarray:
    """MRNG [Fu et al. 2019] as directed bool adjacency. O(n^2 log n) style.

    For each node u, consider other nodes in ascending distance; keep edge
    (u,q) unless some *already kept* neighbor v of u lies in lune_{u,q}
    (i.e. d(u,v) < d(u,q) -- guaranteed by the ordering -- and
    d(v,q) < d(u,q)). This is the standard constructive MRNG definition and
    yields a monotonic graph (Theorem 3 of [15]).
    """
    if d is None:
        d = pairwise_sq_l2(x, x)
    n = len(x)
    adj = np.zeros((n, n), bool)
    order = np.argsort(d, axis=1)
    for u in range(n):
        kept: list[int] = []
        for q in order[u]:
            q = int(q)
            if q == u:
                continue
            duq = d[u, q]
            occluded = False
            for v in kept:
                if d[u, v] < duq and d[v, q] < duq:
                    occluded = True
                    break
            if not occluded:
                adj[u, q] = True
                kept.append(q)
    return adj


def is_monotonic_path(d: np.ndarray, path: list[int], q: int) -> bool:
    """Distances to q strictly decrease along `path` (which ends at q)."""
    for a, b in zip(path, path[1:]):
        if not d[b, q] < d[a, q]:
            return False
    return True


def has_monotonic_path(adj: np.ndarray, d: np.ndarray, u: int, q: int) -> bool:
    """Greedy existence check: from u, repeatedly move to any out-neighbor
    strictly closer to q. In a monotonic graph this always reaches q.

    We use best-first over strictly-closer neighbors (not just greedy best)
    so the check is exact for the *existence* of a monotone path.
    """
    n = adj.shape[0]
    if u == q:
        return True
    # BFS over the DAG of strictly-decreasing-distance moves.
    seen = np.zeros(n, bool)
    stack = [u]
    seen[u] = True
    while stack:
        v = stack.pop()
        if adj[v, q] and d[q, q] < d[v, q]:
            return True
        for w in np.nonzero(adj[v])[0]:
            w = int(w)
            if not seen[w] and d[w, q] < d[v, q]:
                seen[w] = True
                stack.append(w)
    return False
