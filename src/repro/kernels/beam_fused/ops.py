"""Public jit'd wrapper for the fused beam-hop kernel: padding + backend.

`beam_hops` runs `max_hops` fused beam hops (frontier select + gather +
score + pool merge per hop) over a seeded sorted pool and returns the
final pool plus the per-hop frontier trace, next pick, and done mask.
Two scoring modes select the operand set:

- ADC (serving): pass ``tables`` (B, M, K) and ``codes`` (N, M);
- exact L2 (construction frontier): pass ``x`` (N, D), ``n2`` (N,)
  squared norms, and ``queries`` (B, D).

backend:

- "pallas" (TPU) / "interpret" (CPU-validated kernel): the VMEM-resident
  program -- the corpus must fit the `vmem_bytes` budget;
- "stream" (TPU) / "stream_interpret" (CPU-validated): the HBM-streaming
  program -- corpus arrays stay in HBM and every gather DMA-walks them
  in double-buffered `n_chunk` slabs (`stream_vmem_bytes` footprint,
  independent of N).  Bit-identical to the resident program at every
  config; the oracle for both is `beam_hops_ref`;
- "ref": pure jnp scan, bit-identical to the unfused serve hop loop;
- "auto": on TPU, "pallas" when the resident footprint fits
  `vmem_budget_bytes()` else "stream"; "ref" elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import (beam_hops_adc_pallas, beam_hops_adc_stream,
                     beam_hops_l2_pallas, beam_hops_l2_stream, fits_vmem)
from .ref import beam_hops_ref

BACKENDS = ("auto", "pallas", "interpret", "ref", "stream",
            "stream_interpret")


def _pad_rows(a, mult: int, fill=0):
    pad = (-a.shape[0]) % mult
    if pad == 0:
        return a
    widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("max_hops", "backend", "tile_b",
                                             "n_chunk"))
def beam_hops(adj, pool_ids, pool_d, pool_exp, max_hops: int,
              tables=None, codes=None, x=None, n2=None, queries=None,
              backend: str = "auto", tile_b: int = 8, n_chunk: int = 2048):
    """Fused beam-hop loop.  adj (N, R) int32 with -1 pad; the seeded pool
    (B, L) triplet must satisfy the `pool_merge` invariant (sorted by
    (dist, id), invalid = (-1, +inf, False)).

    Returns (pool_ids (B, L) int32, pool_d (B, L) f32, pool_exp (B, L)
    bool, hops (B,) int32, trace_ids (B, max_hops) int32, trace_d
    (B, max_hops) f32, next_id (B,) int32, done (B,) bool).
    """
    if backend not in BACKENDS:
        raise ValueError(f"beam_hops backend must be one of {BACKENDS}, "
                         f"got {backend!r}")
    mode = "adc" if codes is not None else "l2"
    nc = min(n_chunk, max(adj.shape[0], 128))
    if backend == "auto":
        if jax.default_backend() == "tpu":
            dims = (dict(m=codes.shape[1], k=tables.shape[2])
                    if mode == "adc" else dict(d=x.shape[1]))
            fits = fits_vmem(adj.shape[0], adj.shape[1],
                             l=pool_ids.shape[1], max_hops=max_hops,
                             tile_b=tile_b, n_chunk=nc, **dims)
            backend = "pallas" if fits else "stream"
        else:
            backend = "ref"
    if backend == "ref":
        return beam_hops_ref(adj, pool_ids, pool_d, pool_exp, max_hops,
                             mode=mode, tables=tables, codes=codes,
                             x=x, n2=n2, queries=queries)

    b0 = pool_ids.shape[0]
    adj_p = _pad_rows(adj.astype(jnp.float32), nc, fill=-1)
    pids = _pad_rows(pool_ids.astype(jnp.float32), tile_b, fill=-1)
    pd = _pad_rows(pool_d.astype(jnp.float32), tile_b, fill=jnp.inf)
    pexp = _pad_rows(pool_exp.astype(jnp.float32), tile_b)
    interpret = backend in ("interpret", "stream_interpret")
    stream = backend in ("stream", "stream_interpret")
    if mode == "adc":
        fn = beam_hops_adc_stream if stream else beam_hops_adc_pallas
        out = fn(adj_p, _pad_rows(codes.astype(jnp.float32), nc),
                 _pad_rows(tables.astype(jnp.float32), tile_b),
                 pids, pd, pexp, max_hops, tile_b=tile_b, n_chunk=nc,
                 interpret=interpret)
    else:
        xn = jnp.concatenate(
            [x.astype(jnp.float32), n2.astype(jnp.float32)[:, None]], axis=1)
        fn = beam_hops_l2_stream if stream else beam_hops_l2_pallas
        out = fn(adj_p, _pad_rows(xn, nc),
                 _pad_rows(queries.astype(jnp.float32), tile_b),
                 pids, pd, pexp, max_hops, tile_b=tile_b, n_chunk=nc,
                 interpret=interpret)
    ids, d, exp, hops, tid, td, nxt, done = out
    return (ids[:b0], d[:b0], exp[:b0].astype(bool), hops[:b0, 0],
            tid[:b0], td[:b0], nxt[:b0, 0], done[:b0, 0].astype(bool))
