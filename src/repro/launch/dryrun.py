import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run driver (DESIGN.md, deliverable e).

For every (architecture x input shape x mesh) cell:
  jax.jit(step, in_shardings=...).lower(**abstract inputs).compile()
then record memory_analysis / cost_analysis / loop-corrected HLO terms
(roofline) into a resumable JSON.

The XLA_FLAGS line above MUST precede any jax import: jax locks the device
count at first init.  512 fake CPU devices back both the single-pod
(16 x 16 = 256 chips) and the multi-pod (2 x 16 x 16 = 512) meshes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --out out.json --force
"""
import argparse
import json
import time
import traceback

HBM_PER_CHIP = 16 * 2 ** 30  # v5e


def run_cell(arch_id: str, shape_name: str, multi_pod: bool) -> dict:
    import jax
    from .mesh import make_production_mesh
    from .cells import build_cell
    from ..roofline.analysis import roofline_from_text

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(mesh.devices.size)
    rec: dict = {"arch": arch_id, "shape": shape_name,
                 "mesh": "multi" if multi_pod else "single",
                 "n_devices": n_dev}
    t0 = time.time()
    cell = build_cell(arch_id, shape_name, mesh)
    rec["kind"] = cell.kind
    rec["comment"] = cell.comment
    rec["model_flops"] = cell.model_flops
    lowered = cell.lower()
    rec["t_lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["t_compile_s"] = round(time.time() - t0, 1)
    ma = compiled.memory_analysis()
    rec["mem"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "generated_code_bytes": int(ma.generated_code_size_in_bytes),
    }
    tot = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
           + ma.output_size_in_bytes)
    rec["mem"]["total_bytes"] = int(tot)
    rec["mem"]["fits_hbm"] = bool(tot <= HBM_PER_CHIP)
    ca = compiled.cost_analysis()
    rec["cost_analysis"] = {k: float(ca[k]) for k in
                            ("flops", "bytes accessed") if k in ca}
    t0 = time.time()
    rl = roofline_from_text(compiled.as_text(), cell.model_flops, n_dev)
    rec["roofline"] = rl.summary()
    rec["t_analyze_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from ..configs.registry import ARCHS, all_cells

    cells = [(a, s) for (a, s) in all_cells()
             if (args.arch == "all" or a == args.arch)
             and (args.shape == "all" or s == args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results: dict = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    n_fail = 0
    for arch_id, shape_name in cells:
        for multi in meshes:
            key = f"{arch_id}|{shape_name}|{'multi' if multi else 'single'}"
            if key in results and results[key].get("status") == "ok" \
                    and not args.force:
                continue
            t0 = time.time()
            try:
                rec = run_cell(arch_id, shape_name, multi)
                rec["status"] = "ok"
                mem_g = rec["mem"]["total_bytes"] / 2 ** 30
                fits = "fits" if rec["mem"]["fits_hbm"] else "OVER"
                rl = rec["roofline"]
                print(f"OK   {key:58s} {time.time()-t0:6.1f}s "
                      f"mem={mem_g:6.2f}GiB({fits}) "
                      f"bneck={rl['bottleneck']:10s} "
                      f"t={rl['t_bound_s']*1e3:8.2f}ms "
                      f"useful={rl['useful_ratio']:.2f}", flush=True)
            except Exception as e:  # noqa: BLE001 -- report, continue sweep
                rec = {"status": "fail", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                n_fail += 1
                print(f"FAIL {key:58s} {time.time()-t0:6.1f}s {rec['error'][:140]}",
                      flush=True)
            results[key] = rec
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    ok = sum(1 for r in results.values() if r.get("status") == "ok")
    print(f"\n{ok} ok / {len(results)} recorded -> {args.out}")


if __name__ == "__main__":
    main()
