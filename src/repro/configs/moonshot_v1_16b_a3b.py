"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]: 48L d=2048 16H
(kv=16), MoE: 64 routed top-6 (d_ff 1408) + 2 shared experts, v=163840."""
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

from .base import LM_SHAPES

ARCH_ID = "moonshot-v1-16b-a3b"
FAMILY = "lm"
SHAPES = LM_SHAPES
TRAIN_ACCUM = 8  # microbatches for train_4k (memory lever)


def model_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(name=ARCH_ID + "-smoke", n_layers=2, d_model=128,
                        n_heads=4, n_kv_heads=4, d_head=32, d_ff=0,
                        vocab=512, remat="none", loss_chunks=2,
                        dtype="float32",
                        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                                      n_shared=1, d_ff_shared=64,
                                      pad_multiple=8, groups=2))
    return LMConfig(
        name=ARCH_ID, n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_head=128, d_ff=0, vocab=163840, norm="rmsnorm", activation="silu",
        remat="full", loss_chunks=64,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                      d_ff_shared=2816, pad_multiple=16, groups=16))
