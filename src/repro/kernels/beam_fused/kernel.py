"""Pallas TPU kernel: the fused beam-hop serve loop, resident or streamed.

One grid step owns a TB-row query tile and runs the *entire* hop loop --
frontier select, adjacency gather, neighbor scoring, pool merge -- as a
`fori_loop` whose (TB, L) pool state never leaves VMEM.  The unfused
engine round-trips pool/frontier arrays through HBM between four XLA
programs per hop; here one program launch serves all `max_hops` hops.

TPU adaptation of each stage (no fast gather on TPU, so every gather is
a one-hot contraction -- the `pq_adc` trick applied throughout):

- **frontier select**: the pool is kept sorted, so the pop is the first
  unexpanded valid entry -- a masked iota min + one-hot readout, no
  argsort.
- **adjacency / code / vector gather**: rows are pulled from the corpus
  arrays by one-hot @ matrix MXU contractions, chunked over N
  (`n_chunk`) so the one-hot tile, not the corpus, bounds the live
  footprint.
- **scoring**: mode="adc" inlines the `pq_adc_rowwise` one-hot LUT
  lookup against the tile's private (TB, M, K) tables; mode="l2" is the
  build frontier's dot-form exact distance vs (N, D+1) vectors carrying
  their squared norms in the last column.
- **merge**: `pool_merge_ranked` verbatim -- lexicographic (dist, id)
  merge ranks from elementwise comparisons, then a slot-match scatter
  (rank == slot-iota one-hots); no sort anywhere in the hop.

Every hop also records its frontier pick into a (TB, max_hops) trace
(the build frontier's visited set), and the program ends by emitting the
*next* frontier pick and a done mask so callers can chain hop programs.

Two execution modes share the hop loop and differ only in where the
corpus lives:

- **resident** (`beam_hops_{adc,l2}_pallas`): adjacency + codes/vectors
  are VMEM blocks, gather chunks come from `dynamic_slice`.  Footprint
  per grid step is N*(R + M)*4 bytes (adc) or N*(R + D + 1)*4 (l2) plus
  the (TB*R, n_chunk) gather one-hot and (TB, R|L, L) merge tensors --
  see `vmem_bytes`.  A 100k-node shard at R=32, M=16 is ~20 MB, past
  most cores' VMEM.
- **streaming** (`beam_hops_{adc,l2}_stream`): the corpus stays in HBM
  (`memory_space=ANY`); every gather walks it in `n_chunk`-row slabs
  DMA'd into a double-buffered VMEM scratch (`pltpu.make_async_copy`:
  the copy for slab i+1 is issued before the one-hot tile contracts
  slab i, so the MXU and the DMA engine overlap).  Footprint is
  `stream_vmem_bytes` -- O(n_chunk), independent of N -- which is what
  lets one grid step serve a shard far larger than VMEM instead of
  requiring `serve.frontend.ShardedFrontend` to slice the corpus down
  to fast-memory size.  The slab walk order and slab contents are
  identical to the resident gather's chunk loop, so both modes are
  bit-identical on every output (streaming changes timing and memory
  traffic, never results).

Ids and flags travel as exact f32 (N < 2^24) so every stage stays on
the VPU/MXU datapath.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SENT = float(2 ** 31)   # f32 id sentinel: -1 ids rank last, like pool_merge

# resident-fused VMEM budget the auto backend compares `vmem_bytes`
# against; ~16 MiB is a safe per-core figure across TPU generations
_DEFAULT_VMEM_BUDGET = 16 * 2 ** 20


def vmem_budget_bytes() -> int:
    """The resident-fused VMEM budget (bytes); REPRO_VMEM_BUDGET overrides."""
    return int(os.environ.get("REPRO_VMEM_BUDGET", _DEFAULT_VMEM_BUDGET))


def _mode_dims(m, d):
    if (m is None) == (d is None):
        raise ValueError("pass exactly one of m= (adc mode) / d= (l2 mode)")
    # corpus row width beyond adjacency: codes (M) or vectors+norm (D+1)
    return (m, 0) if m is not None else (d + 1, d)


def vmem_bytes(n: int, r: int, *, m: int | None = None, d: int | None = None,
               l: int = 64, max_hops: int = 32, tile_b: int = 8,
               n_chunk: int = 2048, k: int = 256) -> int:
    """Estimated VMEM footprint (bytes) of one *resident* fused grid step.

    n/r: padded corpus rows and adjacency width; exactly one of m (PQ
    subquantizers, adc mode) / d (vector dim, l2 mode); l the pool
    width, k the PQ centroid count.  Terms: the VMEM-resident corpus
    blocks (the part streaming eliminates), the per-tile private
    operands (ADC tables / query tile), the (TB*R, n_chunk) gather
    one-hot, the (TB, R, K) score one-hot (adc), the merge rank/scatter
    tensors, and the pool + trace state.
    """
    row_w, dd = _mode_dims(m, d)
    f = 4
    corpus = n * (r + row_w) * f
    if m is not None:
        private = tile_b * m * k * f               # (TB, M, K) ADC tables
        score = tile_b * r * k * f                 # (TB, R, K) LUT one-hot
    else:
        private = tile_b * dd * f                  # (TB, D) query tile
        score = tile_b * r * (dd + 1) * f          # gathered rows + dots
    gather = tile_b * r * n_chunk * f              # (TB*R, n_chunk) one-hot
    merge = 4 * tile_b * (l * l + 2 * r * l + r * r) * f
    state = (6 * tile_b * l + 4 * tile_b * max_hops) * f
    return corpus + private + score + gather + merge + state


def stream_vmem_bytes(n: int, r: int, *, m: int | None = None,
                      d: int | None = None, l: int = 64, max_hops: int = 32,
                      tile_b: int = 8, n_chunk: int = 2048,
                      k: int = 256) -> int:
    """Estimated VMEM footprint of one *streaming* fused grid step: the
    resident estimate minus the corpus blocks, plus the two double-
    buffered (2, n_chunk, R|row_w) DMA slabs -- O(n_chunk), not O(n)."""
    row_w, _ = _mode_dims(m, d)
    resident = vmem_bytes(n, r, m=m, d=d, l=l, max_hops=max_hops,
                          tile_b=tile_b, n_chunk=n_chunk, k=k)
    f = 4
    return resident - n * (r + row_w) * f + 2 * n_chunk * (r + row_w) * f


def fits_vmem(n: int, r: int, *, m: int | None = None, d: int | None = None,
              l: int = 64, max_hops: int = 32, tile_b: int = 8,
              n_chunk: int = 2048, k: int = 256,
              budget: int | None = None) -> bool:
    """Whether the resident fused kernel's footprint fits the VMEM budget
    (the `backend="auto"` rule: resident when it fits, streaming when
    not)."""
    budget = vmem_budget_bytes() if budget is None else int(budget)
    return vmem_bytes(n, r, m=m, d=d, l=l, max_hops=max_hops, tile_b=tile_b,
                      n_chunk=n_chunk, k=k) <= budget


def _check_tiling(b: int, tile_b: int, n: int, n_chunk: int) -> None:
    """Public-kernel shape contract, raised (not asserted: asserts vanish
    under `python -O`, and these kernels are callable without the
    ops-layer padding)."""
    if tile_b <= 0 or b % tile_b != 0:
        raise ValueError(
            f"pool batch b={b} is not divisible by tile_b={tile_b}; pad the "
            f"pool rows to a tile_b multiple (ops.beam_hops does this)")
    if n_chunk <= 0 or n % n_chunk != 0:
        raise ValueError(
            f"corpus rows n={n} are not divisible by n_chunk={n_chunk}; pad "
            f"the corpus arrays to an n_chunk multiple (ops.beam_hops does "
            f"this)")


def _gather_rows(ids_col, mat, n: int, n_chunk: int):
    """One-hot gather of `mat` rows: ids_col (S, 1) exact-int f32 with all
    values in [0, n); mat (N, C) f32.  Returns (S, C).  Chunked over N so
    only an (S, n_chunk) one-hot tile is live per iteration; each id
    matches exactly one column of exactly one chunk."""
    s = ids_col.shape[0]
    c = mat.shape[1]
    col = jax.lax.broadcasted_iota(jnp.float32, (s, n_chunk), 1)

    def body(ci, acc):
        off = (ci * n_chunk).astype(jnp.float32)
        onehot = (col + off == ids_col).astype(jnp.float32)
        chunk = jax.lax.dynamic_slice_in_dim(mat, ci * n_chunk, n_chunk, 0)
        return acc + jax.lax.dot_general(
            onehot, chunk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    return jax.lax.fori_loop(0, n // n_chunk, body,
                             jnp.zeros((s, c), jnp.float32))


def _gather_rows_stream(ids_col, hbm_ref, buf, sem, n: int, n_chunk: int):
    """`_gather_rows` with the corpus in HBM: the slab for chunk i is
    DMA'd into one slot of the (2, n_chunk, C) VMEM scratch `buf` while
    the one-hot tile contracts the other slot (double buffering --
    `make_async_copy` for slab i+1 is started before the wait on slab i).
    Same chunk order and contents as the resident gather, so the f32
    accumulation -- and therefore every downstream output -- is
    bit-identical."""
    s = ids_col.shape[0]
    c = hbm_ref.shape[1]
    col = jax.lax.broadcasted_iota(jnp.float32, (s, n_chunk), 1)
    num = n // n_chunk

    def dma(slot, ci):
        return pltpu.make_async_copy(
            hbm_ref.at[pl.ds(ci * n_chunk, n_chunk), :],
            buf.at[slot], sem.at[slot])

    dma(0, 0).start()

    def body(ci, acc):
        slot = jax.lax.rem(ci, 2)

        @pl.when(ci + 1 < num)
        def _():
            dma(jax.lax.rem(ci + 1, 2), ci + 1).start()

        dma(slot, ci).wait()
        off = (ci * n_chunk).astype(jnp.float32)
        onehot = (col + off == ids_col).astype(jnp.float32)
        return acc + jax.lax.dot_general(
            onehot, buf[slot], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    return jax.lax.fori_loop(0, num, body, jnp.zeros((s, c), jnp.float32))


def _merge_ranked(pids, pd, pexp, cids, cd, tb: int, l: int, r: int):
    """In-kernel `pool_merge_ranked` (see repro.build.pool), f32 ids."""
    cd = jnp.where(cids < 0.0, jnp.inf, cd)
    dup_pool = jnp.any((pids[:, None, :] == cids[:, :, None])
                       & (cids[:, :, None] >= 0.0), axis=2)
    earlier = (jax.lax.broadcasted_iota(jnp.int32, (tb, r, r), 1)
               > jax.lax.broadcasted_iota(jnp.int32, (tb, r, r), 2))
    dup_cand = jnp.any((cids[:, :, None] == cids[:, None, :])
                       & (cids[:, :, None] >= 0.0) & earlier, axis=2)
    valid = (cids >= 0.0) & ~dup_pool & ~dup_cand
    cd = jnp.where(valid, cd, jnp.inf)
    cids = jnp.where(valid, cids, -1.0)

    pkid = jnp.where(pids < 0.0, _SENT, pids)
    ckid = jnp.where(cids < 0.0, _SENT, cids)
    c_lt_p = ((cd[:, :, None] < pd[:, None, :])
              | ((cd[:, :, None] == pd[:, None, :])
                 & (ckid[:, :, None] < pkid[:, None, :])))
    pos_p = (jax.lax.broadcasted_iota(jnp.int32, (tb, l), 1)
             + c_lt_p.astype(jnp.int32).sum(axis=1))
    p_le_c = ((pd[:, :, None] < cd[:, None, :])
              | ((pd[:, :, None] == cd[:, None, :])
                 & (pkid[:, :, None] <= ckid[:, None, :])))
    ctie = cd[:, :, None] == cd[:, None, :]
    c_lt_c = ((cd[:, :, None] > cd[:, None, :])
              | (ctie & (ckid[:, :, None] > ckid[:, None, :]))
              | (ctie & (ckid[:, :, None] == ckid[:, None, :]) & earlier))
    pos_c = (p_le_c.astype(jnp.int32).sum(axis=1)
             + c_lt_c.astype(jnp.int32).sum(axis=2))

    # slot-match scatter: rank >= l simply matches no slot; every slot
    # < l has exactly one owning source (merge ranks are a bijection)
    mp = pos_p[:, :, None] == jax.lax.broadcasted_iota(
        jnp.int32, (tb, l, l), 2)
    mc = pos_c[:, :, None] == jax.lax.broadcasted_iota(
        jnp.int32, (tb, r, l), 2)
    out_ids = (jnp.where(mp, pids[:, :, None], 0.0).sum(axis=1)
               + jnp.where(mc, cids[:, :, None], 0.0).sum(axis=1))
    out_d = (jnp.where(mp, pd[:, :, None], 0.0).sum(axis=1)
             + jnp.where(mc, cd[:, :, None], 0.0).sum(axis=1))
    out_exp = jnp.where(mp, pexp[:, :, None], 0.0).sum(axis=1)
    return out_ids, out_d, out_exp


def _hop_loop(gather_adj, ids_ref, d_ref, exp_ref, score, outs,
              *, max_hops: int, r: int):
    """Shared hop loop; `gather_adj(v_col (TB, 1)) -> (TB, R)` pulls the
    frontier adjacency rows (resident dynamic_slice chunks or streamed
    HBM slabs) and `score(nbrs, valid) -> (TB, R)` closes over the
    mode-specific operands.  Writes the eight output refs in `outs`."""
    (oi_ref, od_ref, oe_ref, oh_ref, oti_ref, otd_ref,
     onx_ref, odn_ref) = outs
    tb, l = ids_ref.shape
    iota_l = jax.lax.broadcasted_iota(jnp.int32, (tb, l), 1)
    iota_h = jax.lax.broadcasted_iota(jnp.int32, (tb, max_hops), 1)

    def pick(ids, d, exp):
        fm = (exp == 0.0) & (ids >= 0.0) & (d < jnp.inf)
        jmin = jnp.min(jnp.where(fm, iota_l, l), axis=1)        # (TB,)
        has = jmin < l
        onej = iota_l == jmin[:, None]                          # all-0 if !has
        v = jnp.where(onej, ids, 0.0).sum(axis=1)
        vd = jnp.where(has, jnp.where(onej, d, 0.0).sum(axis=1), jnp.inf)
        return onej, has, v, vd

    def hop(h, carry):
        ids, d, exp, hops, tid, td = carry
        onej, has, v, vd = pick(ids, d, exp)
        exp = jnp.maximum(exp, onej.astype(jnp.float32))
        nbrs = gather_adj(v[:, None])                           # (TB, R)
        nbrs = jnp.where(has[:, None], nbrs, -1.0)
        nd = score(nbrs, nbrs >= 0.0)
        ids, d, exp = _merge_ranked(ids, d, exp, nbrs, nd, tb, l, r)
        hops = hops + has.astype(jnp.float32)
        at_h = iota_h == h
        tid = jnp.where(at_h, jnp.where(has, v, -1.0)[:, None], tid)
        td = jnp.where(at_h, vd[:, None], td)
        return ids, d, exp, hops, tid, td

    ids, d, exp, hops, tid, td = jax.lax.fori_loop(
        0, max_hops, hop,
        (ids_ref[...], d_ref[...], exp_ref[...], jnp.zeros(tb, jnp.float32),
         jnp.full((tb, max_hops), -1.0, jnp.float32),
         jnp.full((tb, max_hops), jnp.inf, jnp.float32)))

    _, has, v, _ = pick(ids, d, exp)
    oi_ref[...] = ids.astype(jnp.int32)
    od_ref[...] = d
    oe_ref[...] = exp.astype(jnp.int32)
    oh_ref[...] = hops.astype(jnp.int32)[:, None]
    oti_ref[...] = tid.astype(jnp.int32)
    otd_ref[...] = td
    onx_ref[...] = jnp.where(has, v, -1.0).astype(jnp.int32)[:, None]
    odn_ref[...] = (~has).astype(jnp.int32)[:, None]


def _adc_score_from(gather_codes, tables, tb: int, r: int):
    """ADC scoring closure shared by the resident and streaming kernels:
    gather the frontier neighbors' PQ codes, then the `pq_adc_rowwise`
    one-hot LUT lookup against the tile's private (TB, M, K) tables."""
    m_sub, k_cent = tables.shape[1], tables.shape[2]
    kio = jax.lax.broadcasted_iota(jnp.int32, (tb, r, k_cent), 2)

    def score(nbrs, valid):
        nbc = jnp.maximum(nbrs, 0.0).reshape(tb * r, 1)
        ncodes = gather_codes(nbc)                               # (TB*R, M)
        ncodes = ncodes.astype(jnp.int32).reshape(tb, r, m_sub)

        def body(mi, acc):
            c_m = jax.lax.dynamic_slice_in_dim(ncodes, mi, 1, axis=2)
            onehot = (kio == c_m).astype(jnp.float32)            # (TB, R, K)
            t_m = jax.lax.dynamic_slice_in_dim(tables, mi, 1, axis=1)
            t_m = t_m.reshape(tb, 1, k_cent)
            return acc + jnp.sum(onehot * t_m, axis=2)           # (TB, R)

        nd = jax.lax.fori_loop(0, m_sub, body,
                               jnp.zeros((tb, r), jnp.float32))
        return jnp.where(valid, nd, jnp.inf)

    return score


def _l2_score_from(gather_xn, q, dd: int, tb: int, r: int):
    """Exact-L2 scoring closure shared by the resident and streaming
    kernels: gather (vector, squared-norm) rows, dot-form distance."""
    qn = jnp.sum(q * q, axis=1)

    def score(nbrs, valid):
        nbc = jnp.maximum(nbrs, 0.0).reshape(tb * r, 1)
        rows = gather_xn(nbc)                                    # (TB*R, D+1)
        vecs = rows[:, :dd].reshape(tb, r, dd)
        n2g = rows[:, dd].reshape(tb, r)
        dot = jax.lax.dot_general(vecs, q, (((2,), (1,)), ((0,), (0,))),
                                  preferred_element_type=jnp.float32)
        dist = jnp.maximum(n2g - 2.0 * dot + qn[:, None], 0.0)
        return jnp.where(valid, dist, jnp.inf)

    return score


def _beam_adc_kernel(adj_ref, codes_ref, tables_ref, ids_ref, d_ref, exp_ref,
                     *outs, max_hops: int, n: int, n_chunk: int):
    tb = ids_ref.shape[0]
    r = adj_ref.shape[1]
    adj_f = adj_ref[...]
    codes_f = codes_ref[...]
    score = _adc_score_from(
        lambda ids: _gather_rows(ids, codes_f, n, n_chunk),
        tables_ref[...], tb, r)
    _hop_loop(lambda v: _gather_rows(v, adj_f, n, n_chunk),
              ids_ref, d_ref, exp_ref, score, outs,
              max_hops=max_hops, r=r)


def _beam_l2_kernel(adj_ref, xn_ref, q_ref, ids_ref, d_ref, exp_ref,
                    *outs, max_hops: int, n: int, n_chunk: int):
    tb = ids_ref.shape[0]
    r = adj_ref.shape[1]
    dd = xn_ref.shape[1] - 1                     # last column = squared norm
    adj_f = adj_ref[...]
    xn = xn_ref[...]
    score = _l2_score_from(lambda ids: _gather_rows(ids, xn, n, n_chunk),
                           q_ref[...], dd, tb, r)
    _hop_loop(lambda v: _gather_rows(v, adj_f, n, n_chunk),
              ids_ref, d_ref, exp_ref, score, outs,
              max_hops=max_hops, r=r)


def _beam_adc_stream_kernel(adj_ref, codes_ref, tables_ref, ids_ref, d_ref,
                            exp_ref, *outs_scratch,
                            max_hops: int, n: int, n_chunk: int):
    """ADC hop loop with adj/codes left in HBM (`memory_space=ANY`) and
    every gather streamed through the double-buffered DMA scratch."""
    *outs, adj_buf, adj_sem, code_buf, code_sem = outs_scratch
    tb = ids_ref.shape[0]
    r = adj_ref.shape[1]
    score = _adc_score_from(
        lambda ids: _gather_rows_stream(ids, codes_ref, code_buf, code_sem,
                                        n, n_chunk),
        tables_ref[...], tb, r)
    _hop_loop(lambda v: _gather_rows_stream(v, adj_ref, adj_buf, adj_sem,
                                            n, n_chunk),
              ids_ref, d_ref, exp_ref, score, tuple(outs),
              max_hops=max_hops, r=r)


def _beam_l2_stream_kernel(adj_ref, xn_ref, q_ref, ids_ref, d_ref, exp_ref,
                           *outs_scratch,
                           max_hops: int, n: int, n_chunk: int):
    """Exact-L2 hop loop with adj/vectors left in HBM and every gather
    streamed through the double-buffered DMA scratch."""
    *outs, adj_buf, adj_sem, xn_buf, xn_sem = outs_scratch
    tb = ids_ref.shape[0]
    r = adj_ref.shape[1]
    dd = xn_ref.shape[1] - 1
    score = _l2_score_from(
        lambda ids: _gather_rows_stream(ids, xn_ref, xn_buf, xn_sem,
                                        n, n_chunk),
        q_ref[...], dd, tb, r)
    _hop_loop(lambda v: _gather_rows_stream(v, adj_ref, adj_buf, adj_sem,
                                            n, n_chunk),
              ids_ref, d_ref, exp_ref, score, tuple(outs),
              max_hops=max_hops, r=r)


def _out_shapes(b, l, max_hops):
    i32, f32 = jnp.int32, jnp.float32
    return (jax.ShapeDtypeStruct((b, l), i32),        # pool ids
            jax.ShapeDtypeStruct((b, l), f32),        # pool dists
            jax.ShapeDtypeStruct((b, l), i32),        # pool expanded
            jax.ShapeDtypeStruct((b, 1), i32),        # hops used
            jax.ShapeDtypeStruct((b, max_hops), i32), # frontier trace ids
            jax.ShapeDtypeStruct((b, max_hops), f32), # frontier trace dists
            jax.ShapeDtypeStruct((b, 1), i32),        # next frontier pick
            jax.ShapeDtypeStruct((b, 1), i32))        # done mask


def _out_specs(tile_b, l, max_hops):
    return (pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, max_hops), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, max_hops), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)))


@functools.partial(jax.jit, static_argnames=("max_hops", "tile_b", "n_chunk",
                                             "interpret"))
def beam_hops_adc_pallas(adj, codes, tables, pool_ids, pool_d, pool_exp,
                         max_hops: int, tile_b: int = 8, n_chunk: int = 2048,
                         interpret: bool = False):
    """adj (N, R) f32, codes (N, M) f32, tables (B, M, K) f32, seeded pool
    (B, L) f32 triplet.  B % tile_b == 0 and N % n_chunk == 0 (ops pads).
    Returns the 8-tuple of `_out_shapes` (hops/next/done as (B, 1))."""
    b, l = pool_ids.shape
    n = adj.shape[0]
    _check_tiling(b, tile_b, n, n_chunk)
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    return pl.pallas_call(
        functools.partial(_beam_adc_kernel, max_hops=max_hops, n=n,
                          n_chunk=n_chunk),
        grid=(b // tile_b,),
        in_specs=[
            full(adj.shape),
            full(codes.shape),
            pl.BlockSpec((tile_b,) + tables.shape[1:], lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
        ],
        out_specs=_out_specs(tile_b, l, max_hops),
        out_shape=_out_shapes(b, l, max_hops),
        interpret=interpret,
    )(adj, codes, tables, pool_ids, pool_d, pool_exp)


@functools.partial(jax.jit, static_argnames=("max_hops", "tile_b", "n_chunk",
                                             "interpret"))
def beam_hops_l2_pallas(adj, xn, queries, pool_ids, pool_d, pool_exp,
                        max_hops: int, tile_b: int = 8, n_chunk: int = 2048,
                        interpret: bool = False):
    """adj (N, R) f32, xn (N, D+1) f32 with squared norms in the last
    column, queries (B, D) f32, seeded pool (B, L) f32 triplet.  Same
    contract as `beam_hops_adc_pallas` with exact-L2 scoring."""
    b, l = pool_ids.shape
    n = adj.shape[0]
    _check_tiling(b, tile_b, n, n_chunk)
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    return pl.pallas_call(
        functools.partial(_beam_l2_kernel, max_hops=max_hops, n=n,
                          n_chunk=n_chunk),
        grid=(b // tile_b,),
        in_specs=[
            full(adj.shape),
            full(xn.shape),
            pl.BlockSpec((tile_b, queries.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
        ],
        out_specs=_out_specs(tile_b, l, max_hops),
        out_shape=_out_shapes(b, l, max_hops),
        interpret=interpret,
    )(adj, xn, queries, pool_ids, pool_d, pool_exp)


def _stream_scratch(n_chunk: int, r: int, row_w: int):
    """Double-buffered DMA scratch: (2, n_chunk, C) slab pairs + their
    completion semaphores, for the adjacency and the codes/vector gathers."""
    return [pltpu.VMEM((2, n_chunk, r), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.VMEM((2, n_chunk, row_w), jnp.float32),
            pltpu.SemaphoreType.DMA((2,))]


@functools.partial(jax.jit, static_argnames=("max_hops", "tile_b", "n_chunk",
                                             "interpret"))
def beam_hops_adc_stream(adj, codes, tables, pool_ids, pool_d, pool_exp,
                         max_hops: int, tile_b: int = 8, n_chunk: int = 2048,
                         interpret: bool = False):
    """`beam_hops_adc_pallas` with adj/codes streamed from HBM: the corpus
    operands get `memory_space=ANY` block specs (never staged into VMEM by
    the pipeline) and each gather DMA-copies `n_chunk`-row slabs into a
    double-buffered VMEM scratch.  Bit-identical outputs to the resident
    kernel at every config; VMEM footprint is `stream_vmem_bytes` --
    independent of N, so shards far larger than VMEM serve from one grid
    step."""
    b, l = pool_ids.shape
    n = adj.shape[0]
    _check_tiling(b, tile_b, n, n_chunk)
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    return pl.pallas_call(
        functools.partial(_beam_adc_stream_kernel, max_hops=max_hops, n=n,
                          n_chunk=n_chunk),
        grid=(b // tile_b,),
        in_specs=[
            any_spec,
            any_spec,
            pl.BlockSpec((tile_b,) + tables.shape[1:], lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
        ],
        out_specs=_out_specs(tile_b, l, max_hops),
        out_shape=_out_shapes(b, l, max_hops),
        scratch_shapes=_stream_scratch(n_chunk, adj.shape[1], codes.shape[1]),
        interpret=interpret,
    )(adj, codes, tables, pool_ids, pool_d, pool_exp)


@functools.partial(jax.jit, static_argnames=("max_hops", "tile_b", "n_chunk",
                                             "interpret"))
def beam_hops_l2_stream(adj, xn, queries, pool_ids, pool_d, pool_exp,
                        max_hops: int, tile_b: int = 8, n_chunk: int = 2048,
                        interpret: bool = False):
    """`beam_hops_l2_pallas` with adj/vectors streamed from HBM through
    the double-buffered DMA scratch; same contract and bit-identical
    outputs, `stream_vmem_bytes` footprint."""
    b, l = pool_ids.shape
    n = adj.shape[0]
    _check_tiling(b, tile_b, n, n_chunk)
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    return pl.pallas_call(
        functools.partial(_beam_l2_stream_kernel, max_hops=max_hops, n=n,
                          n_chunk=n_chunk),
        grid=(b // tile_b,),
        in_specs=[
            any_spec,
            any_spec,
            pl.BlockSpec((tile_b, queries.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
        ],
        out_specs=_out_specs(tile_b, l, max_hops),
        out_shape=_out_shapes(b, l, max_hops),
        scratch_shapes=_stream_scratch(n_chunk, adj.shape[1], xn.shape[1]),
        interpret=interpret,
    )(adj, xn, queries, pool_ids, pool_d, pool_exp)
