"""Pallas TPU kernel: the fused beam-hop serve loop, VMEM-resident.

One grid step owns a TB-row query tile and runs the *entire* hop loop --
frontier select, adjacency gather, neighbor scoring, pool merge -- as a
`fori_loop` whose (TB, L) pool state never leaves VMEM.  The unfused
engine round-trips pool/frontier arrays through HBM between four XLA
programs per hop; here one program launch serves all `max_hops` hops.

TPU adaptation of each stage (no fast gather on TPU, so every gather is
a one-hot contraction -- the `pq_adc` trick applied throughout):

- **frontier select**: the pool is kept sorted, so the pop is the first
  unexpanded valid entry -- a masked iota min + one-hot readout, no
  argsort.
- **adjacency / code / vector gather**: rows are pulled from the
  VMEM-resident corpus arrays by one-hot @ matrix MXU contractions,
  chunked over N (`n_chunk`) so the one-hot tile, not the corpus, bounds
  the live footprint.
- **scoring**: mode="adc" inlines the `pq_adc_rowwise` one-hot LUT
  lookup against the tile's private (TB, M, K) tables; mode="l2" is the
  build frontier's dot-form exact distance vs (N, D+1) vectors carrying
  their squared norms in the last column.
- **merge**: `pool_merge_ranked` verbatim -- lexicographic (dist, id)
  merge ranks from elementwise comparisons, then a slot-match scatter
  (rank == slot-iota one-hots); no sort anywhere in the hop.

Every hop also records its frontier pick into a (TB, max_hops) trace
(the build frontier's visited set), and the program ends by emitting the
*next* frontier pick and a done mask so callers can chain hop programs.

VMEM budget per grid step: the corpus blocks N*(R + M + 1)*4 bytes (adc)
or N*(R + D + 1 + 1)*4 (l2) plus the (TB*R, n_chunk) gather one-hot and
(TB, R|L, L) merge tensors -- a 100k-node shard at R=32, M=16 is ~20 MB,
so shard via `serve.frontend.ShardedFrontend` before N outgrows VMEM
(streaming the corpus through HBM DMA is the documented next step).
Ids and flags travel as exact f32 (N < 2^24) so every stage stays on
the VPU/MXU datapath.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SENT = float(2 ** 31)   # f32 id sentinel: -1 ids rank last, like pool_merge


def _gather_rows(ids_col, mat, n: int, n_chunk: int):
    """One-hot gather of `mat` rows: ids_col (S, 1) exact-int f32 with all
    values in [0, n); mat (N, C) f32.  Returns (S, C).  Chunked over N so
    only an (S, n_chunk) one-hot tile is live per iteration; each id
    matches exactly one column of exactly one chunk."""
    s = ids_col.shape[0]
    c = mat.shape[1]
    col = jax.lax.broadcasted_iota(jnp.float32, (s, n_chunk), 1)

    def body(ci, acc):
        off = (ci * n_chunk).astype(jnp.float32)
        onehot = (col + off == ids_col).astype(jnp.float32)
        chunk = jax.lax.dynamic_slice_in_dim(mat, ci * n_chunk, n_chunk, 0)
        return acc + jax.lax.dot_general(
            onehot, chunk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    return jax.lax.fori_loop(0, n // n_chunk, body,
                             jnp.zeros((s, c), jnp.float32))


def _merge_ranked(pids, pd, pexp, cids, cd, tb: int, l: int, r: int):
    """In-kernel `pool_merge_ranked` (see repro.build.pool), f32 ids."""
    cd = jnp.where(cids < 0.0, jnp.inf, cd)
    dup_pool = jnp.any((pids[:, None, :] == cids[:, :, None])
                       & (cids[:, :, None] >= 0.0), axis=2)
    earlier = (jax.lax.broadcasted_iota(jnp.int32, (tb, r, r), 1)
               > jax.lax.broadcasted_iota(jnp.int32, (tb, r, r), 2))
    dup_cand = jnp.any((cids[:, :, None] == cids[:, None, :])
                       & (cids[:, :, None] >= 0.0) & earlier, axis=2)
    valid = (cids >= 0.0) & ~dup_pool & ~dup_cand
    cd = jnp.where(valid, cd, jnp.inf)
    cids = jnp.where(valid, cids, -1.0)

    pkid = jnp.where(pids < 0.0, _SENT, pids)
    ckid = jnp.where(cids < 0.0, _SENT, cids)
    c_lt_p = ((cd[:, :, None] < pd[:, None, :])
              | ((cd[:, :, None] == pd[:, None, :])
                 & (ckid[:, :, None] < pkid[:, None, :])))
    pos_p = (jax.lax.broadcasted_iota(jnp.int32, (tb, l), 1)
             + c_lt_p.astype(jnp.int32).sum(axis=1))
    p_le_c = ((pd[:, :, None] < cd[:, None, :])
              | ((pd[:, :, None] == cd[:, None, :])
                 & (pkid[:, :, None] <= ckid[:, None, :])))
    ctie = cd[:, :, None] == cd[:, None, :]
    c_lt_c = ((cd[:, :, None] > cd[:, None, :])
              | (ctie & (ckid[:, :, None] > ckid[:, None, :]))
              | (ctie & (ckid[:, :, None] == ckid[:, None, :]) & earlier))
    pos_c = (p_le_c.astype(jnp.int32).sum(axis=1)
             + c_lt_c.astype(jnp.int32).sum(axis=2))

    # slot-match scatter: rank >= l simply matches no slot; every slot
    # < l has exactly one owning source (merge ranks are a bijection)
    mp = pos_p[:, :, None] == jax.lax.broadcasted_iota(
        jnp.int32, (tb, l, l), 2)
    mc = pos_c[:, :, None] == jax.lax.broadcasted_iota(
        jnp.int32, (tb, r, l), 2)
    out_ids = (jnp.where(mp, pids[:, :, None], 0.0).sum(axis=1)
               + jnp.where(mc, cids[:, :, None], 0.0).sum(axis=1))
    out_d = (jnp.where(mp, pd[:, :, None], 0.0).sum(axis=1)
             + jnp.where(mc, cd[:, :, None], 0.0).sum(axis=1))
    out_exp = jnp.where(mp, pexp[:, :, None], 0.0).sum(axis=1)
    return out_ids, out_d, out_exp


def _hop_loop(adj_ref, ids_ref, d_ref, exp_ref, score, outs,
              *, max_hops: int, n: int, n_chunk: int):
    """Shared hop loop; `score(nbrs, valid) -> (TB, R)` closes over the
    mode-specific operands.  Writes the eight output refs in `outs`."""
    (oi_ref, od_ref, oe_ref, oh_ref, oti_ref, otd_ref,
     onx_ref, odn_ref) = outs
    tb, l = ids_ref.shape
    r = adj_ref.shape[1]
    adj_f = adj_ref[...]
    iota_l = jax.lax.broadcasted_iota(jnp.int32, (tb, l), 1)
    iota_h = jax.lax.broadcasted_iota(jnp.int32, (tb, max_hops), 1)

    def pick(ids, d, exp):
        fm = (exp == 0.0) & (ids >= 0.0) & (d < jnp.inf)
        jmin = jnp.min(jnp.where(fm, iota_l, l), axis=1)        # (TB,)
        has = jmin < l
        onej = iota_l == jmin[:, None]                          # all-0 if !has
        v = jnp.where(onej, ids, 0.0).sum(axis=1)
        vd = jnp.where(has, jnp.where(onej, d, 0.0).sum(axis=1), jnp.inf)
        return onej, has, v, vd

    def hop(h, carry):
        ids, d, exp, hops, tid, td = carry
        onej, has, v, vd = pick(ids, d, exp)
        exp = jnp.maximum(exp, onej.astype(jnp.float32))
        nbrs = _gather_rows(v[:, None], adj_f, n, n_chunk)      # (TB, R)
        nbrs = jnp.where(has[:, None], nbrs, -1.0)
        nd = score(nbrs, nbrs >= 0.0)
        ids, d, exp = _merge_ranked(ids, d, exp, nbrs, nd, tb, l, r)
        hops = hops + has.astype(jnp.float32)
        at_h = iota_h == h
        tid = jnp.where(at_h, jnp.where(has, v, -1.0)[:, None], tid)
        td = jnp.where(at_h, vd[:, None], td)
        return ids, d, exp, hops, tid, td

    ids, d, exp, hops, tid, td = jax.lax.fori_loop(
        0, max_hops, hop,
        (ids_ref[...], d_ref[...], exp_ref[...], jnp.zeros(tb, jnp.float32),
         jnp.full((tb, max_hops), -1.0, jnp.float32),
         jnp.full((tb, max_hops), jnp.inf, jnp.float32)))

    _, has, v, _ = pick(ids, d, exp)
    oi_ref[...] = ids.astype(jnp.int32)
    od_ref[...] = d
    oe_ref[...] = exp.astype(jnp.int32)
    oh_ref[...] = hops.astype(jnp.int32)[:, None]
    oti_ref[...] = tid.astype(jnp.int32)
    otd_ref[...] = td
    onx_ref[...] = jnp.where(has, v, -1.0).astype(jnp.int32)[:, None]
    odn_ref[...] = (~has).astype(jnp.int32)[:, None]


def _beam_adc_kernel(adj_ref, codes_ref, tables_ref, ids_ref, d_ref, exp_ref,
                     *outs, max_hops: int, n: int, n_chunk: int):
    tb = ids_ref.shape[0]
    r = adj_ref.shape[1]
    m_sub, k_cent = tables_ref.shape[1], tables_ref.shape[2]
    codes_f = codes_ref[...]
    tables = tables_ref[...]
    kio = jax.lax.broadcasted_iota(jnp.int32, (tb, r, k_cent), 2)

    def score(nbrs, valid):
        nbc = jnp.maximum(nbrs, 0.0).reshape(tb * r, 1)
        ncodes = _gather_rows(nbc, codes_f, n, n_chunk)          # (TB*R, M)
        ncodes = ncodes.astype(jnp.int32).reshape(tb, r, m_sub)

        def body(mi, acc):
            c_m = jax.lax.dynamic_slice_in_dim(ncodes, mi, 1, axis=2)
            onehot = (kio == c_m).astype(jnp.float32)            # (TB, R, K)
            t_m = jax.lax.dynamic_slice_in_dim(tables, mi, 1, axis=1)
            t_m = t_m.reshape(tb, 1, k_cent)
            return acc + jnp.sum(onehot * t_m, axis=2)           # (TB, R)

        nd = jax.lax.fori_loop(0, m_sub, body,
                               jnp.zeros((tb, r), jnp.float32))
        return jnp.where(valid, nd, jnp.inf)

    _hop_loop(adj_ref, ids_ref, d_ref, exp_ref, score, outs,
              max_hops=max_hops, n=n, n_chunk=n_chunk)


def _beam_l2_kernel(adj_ref, xn_ref, q_ref, ids_ref, d_ref, exp_ref,
                    *outs, max_hops: int, n: int, n_chunk: int):
    tb = ids_ref.shape[0]
    r = adj_ref.shape[1]
    dd = xn_ref.shape[1] - 1                     # last column = squared norm
    xn = xn_ref[...]
    q = q_ref[...]
    qn = jnp.sum(q * q, axis=1)

    def score(nbrs, valid):
        nbc = jnp.maximum(nbrs, 0.0).reshape(tb * r, 1)
        rows = _gather_rows(nbc, xn, n, n_chunk)                 # (TB*R, D+1)
        vecs = rows[:, :dd].reshape(tb, r, dd)
        n2g = rows[:, dd].reshape(tb, r)
        dot = jax.lax.dot_general(vecs, q, (((2,), (1,)), ((0,), (0,))),
                                  preferred_element_type=jnp.float32)
        dist = jnp.maximum(n2g - 2.0 * dot + qn[:, None], 0.0)
        return jnp.where(valid, dist, jnp.inf)

    _hop_loop(adj_ref, ids_ref, d_ref, exp_ref, score, outs,
              max_hops=max_hops, n=n, n_chunk=n_chunk)


def _out_shapes(b, l, max_hops):
    i32, f32 = jnp.int32, jnp.float32
    return (jax.ShapeDtypeStruct((b, l), i32),        # pool ids
            jax.ShapeDtypeStruct((b, l), f32),        # pool dists
            jax.ShapeDtypeStruct((b, l), i32),        # pool expanded
            jax.ShapeDtypeStruct((b, 1), i32),        # hops used
            jax.ShapeDtypeStruct((b, max_hops), i32), # frontier trace ids
            jax.ShapeDtypeStruct((b, max_hops), f32), # frontier trace dists
            jax.ShapeDtypeStruct((b, 1), i32),        # next frontier pick
            jax.ShapeDtypeStruct((b, 1), i32))        # done mask


def _out_specs(tile_b, l, max_hops):
    return (pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, max_hops), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, max_hops), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0)))


@functools.partial(jax.jit, static_argnames=("max_hops", "tile_b", "n_chunk",
                                             "interpret"))
def beam_hops_adc_pallas(adj, codes, tables, pool_ids, pool_d, pool_exp,
                         max_hops: int, tile_b: int = 8, n_chunk: int = 2048,
                         interpret: bool = False):
    """adj (N, R) f32, codes (N, M) f32, tables (B, M, K) f32, seeded pool
    (B, L) f32 triplet.  B % tile_b == 0 and N % n_chunk == 0 (ops pads).
    Returns the 8-tuple of `_out_shapes` (hops/next/done as (B, 1))."""
    b, l = pool_ids.shape
    n = adj.shape[0]
    assert b % tile_b == 0 and n % n_chunk == 0, (b, tile_b, n, n_chunk)
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    return pl.pallas_call(
        functools.partial(_beam_adc_kernel, max_hops=max_hops, n=n,
                          n_chunk=n_chunk),
        grid=(b // tile_b,),
        in_specs=[
            full(adj.shape),
            full(codes.shape),
            pl.BlockSpec((tile_b,) + tables.shape[1:], lambda i: (i, 0, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
        ],
        out_specs=_out_specs(tile_b, l, max_hops),
        out_shape=_out_shapes(b, l, max_hops),
        interpret=interpret,
    )(adj, codes, tables, pool_ids, pool_d, pool_exp)


@functools.partial(jax.jit, static_argnames=("max_hops", "tile_b", "n_chunk",
                                             "interpret"))
def beam_hops_l2_pallas(adj, xn, queries, pool_ids, pool_d, pool_exp,
                        max_hops: int, tile_b: int = 8, n_chunk: int = 2048,
                        interpret: bool = False):
    """adj (N, R) f32, xn (N, D+1) f32 with squared norms in the last
    column, queries (B, D) f32, seeded pool (B, L) f32 triplet.  Same
    contract as `beam_hops_adc_pallas` with exact-L2 scoring."""
    b, l = pool_ids.shape
    n = adj.shape[0]
    assert b % tile_b == 0 and n % n_chunk == 0, (b, tile_b, n, n_chunk)
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    return pl.pallas_call(
        functools.partial(_beam_l2_kernel, max_hops=max_hops, n=n,
                          n_chunk=n_chunk),
        grid=(b // tile_b,),
        in_specs=[
            full(adj.shape),
            full(xn.shape),
            pl.BlockSpec((tile_b, queries.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, l), lambda i: (i, 0)),
        ],
        out_specs=_out_specs(tile_b, l, max_hops),
        out_shape=_out_shapes(b, l, max_hops),
        interpret=interpret,
    )(adj, xn, queries, pool_ids, pool_d, pool_exp)
