"""Host-side fanout neighbor sampler (GraphSAGE-style) for minibatch_lg.

Given a CSR adjacency, sample `fanouts` (e.g. [15, 10]) neighbors per layer
for a seed batch, returning a *fixed-shape padded* subgraph ready for the
fixed-shape JAX step:

  nodes:  (max_nodes,) global ids, -1 pad
  edges:  (max_edges,) src/dst in *local* subgraph indices, -1 pad
  seeds:  local indices of the batch nodes (first `batch` entries)

Deterministic per (seed, step).  Memory per sample is
O(batch * prod(fanouts)) -- the full graph never enters device memory.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray    # (N+1,)
    indices: np.ndarray   # (E,)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1


def csr_from_edges(n_nodes: int, src: np.ndarray, dst: np.ndarray) -> CSRGraph:
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    counts = np.bincount(src_s, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=dst_s.astype(np.int64))


def sample_subgraph(g: CSRGraph, seeds: np.ndarray, fanouts: list[int],
                    rng: np.random.Generator):
    """Layered fanout sampling.  Returns (nodes, edge_src, edge_dst) with
    edges in local indices, exact (unpadded) sizes."""
    node_ids: list[int] = list(dict.fromkeys(seeds.tolist()))
    local = {v: i for i, v in enumerate(node_ids)}
    e_src: list[int] = []
    e_dst: list[int] = []
    frontier = list(node_ids)
    for f in fanouts:
        nxt: list[int] = []
        for v in frontier:
            lo, hi = g.indptr[v], g.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, deg)
            sel = rng.choice(deg, size=take, replace=False) if deg > f \
                else np.arange(deg)
            for u in g.indices[lo:hi][sel].tolist():
                if u not in local:
                    local[u] = len(node_ids)
                    node_ids.append(u)
                    nxt.append(u)
                # message flows neighbor -> seed direction
                e_src.append(local[u])
                e_dst.append(local[v])
        frontier = nxt
    return (np.asarray(node_ids, np.int64),
            np.asarray(e_src, np.int32), np.asarray(e_dst, np.int32))


def padded_sample(g: CSRGraph, feats: np.ndarray, labels: np.ndarray,
                  batch_nodes: int, fanouts: list[int], step: int,
                  max_nodes: int, max_edges: int, seed: int = 0):
    """Deterministic fixed-shape minibatch for global step `step`."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    seeds = rng.choice(g.n_nodes, size=batch_nodes, replace=False)
    nodes, es, ed = sample_subgraph(g, seeds, fanouts, rng)
    nodes, es, ed = nodes[:max_nodes], es[:max_edges], ed[:max_edges]
    keep = (es < len(nodes)) & (ed < len(nodes))
    es, ed = es[keep], ed[keep]
    nf = np.zeros((max_nodes, feats.shape[1]), np.float32)
    nf[: len(nodes)] = feats[nodes]
    lab = np.zeros((max_nodes,), np.int32)
    lab[: len(nodes)] = labels[nodes]
    pe = -np.ones((max_edges,), np.int32)
    pad_src = pe.copy(); pad_src[: len(es)] = es
    pad_dst = pe.copy(); pad_dst[: len(ed)] = ed
    seed_mask = np.zeros((max_nodes,), bool)
    seed_mask[: batch_nodes] = True
    return {"node_feat": nf, "edge_src": pad_src, "edge_dst": pad_dst,
            "labels": lab, "seed_mask": seed_mask}


def expected_sizes(batch_nodes: int, fanouts: list[int]) -> tuple[int, int]:
    """(max_nodes, max_edges) bounds for padding."""
    nodes = batch_nodes
    edges = 0
    frontier = batch_nodes
    for f in fanouts:
        edges += frontier * f
        frontier *= f
        nodes += frontier
    return nodes, edges
