"""Shared failure taxonomy: seeded deterministic fault injection for the
storage stack (block read errors, latency spikes, torn payloads, dead
blocks) and the training loop (step failures).

One `FaultPlan` drives every injected failure in the system, so a run is
reproducible end to end from a single seed.  Determinism is *access-order
independent*: every decision is a pure function of
``(seed, stream, kind, block, attempt)`` hashed through blake2b, so the
same plan produces the same fault schedule whether reads are issued
serially, batched, or interleaved across devices -- the property the
`tests/test_faults.py` suite pins.

Failure classes (mirroring what a real disk path sees):

* **transient read error** -- an attempt fails outright; an independent
  draw per attempt, so a bounded retry usually recovers (rate
  ``read_error_rate``).
* **persistent dead block** -- a per-block draw (rate ``dead_rate``);
  every attempt fails, retries cannot help, the reader must degrade.
* **torn/corrupted payload** -- the transfer "succeeds" but the payload is
  perturbed (rate ``corrupt_rate``); the per-block checksum catches it and
  the read is retried.  `corrupt_payload` really flips bytes so the
  checksum mechanism is load-bearing, not a flag.
* **latency spike** -- the attempt takes ``read_us + spike`` (rate
  ``spike_rate``, exponential magnitude scaled by ``spike_us``); hedged
  reads and timeouts in `repro.core.io_sim` bound the tail.
* **training step failure** -- `fail_step` (rate ``step_fail_rate``) is the
  same taxonomy applied to `repro.train.ft.run_loop`: a transient failure
  per (step, attempt), recovered by checkpoint restart.

Exception hierarchy: `InjectedFault` is the base for every simulated
failure; `SimulatedFailure` (training) subclasses it and is re-exported by
`repro.train.ft` for backward compatibility.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import zlib
from typing import Optional

import numpy as np


class InjectedFault(Exception):
    """Base class of every simulated failure in the system."""


class SimulatedFailure(InjectedFault):
    """Injected training-step failure (see repro.train.ft)."""


class IntegrityError(InjectedFault):
    """A checksum/manifest verification failed (corrupted artifact)."""


# ---------------------------------------------------------------------------
# Deterministic uniform draws
# ---------------------------------------------------------------------------
def _u01(seed: int, *key) -> float:
    """Uniform [0, 1) as a pure function of (seed, key) -- blake2b-based,
    independent of PYTHONHASHSEED and of access order."""
    h = hashlib.blake2b(repr((int(seed),) + key).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "little") / 2.0 ** 64


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Rates and magnitudes of every injected failure mode (all default 0:
    a zero spec is a valid no-op plan, used to prove the resilient read
    path is bit-identical to the plain one when nothing fires)."""

    read_error_rate: float = 0.0   # transient per-attempt read failure
    dead_rate: float = 0.0         # persistent per-block failure
    corrupt_rate: float = 0.0      # per-attempt torn payload (checksummed)
    spike_rate: float = 0.0        # per-attempt latency spike probability
    spike_us: float = 2000.0       # spike magnitude scale (exponential)
    step_fail_rate: float = 0.0    # training-loop per-step failure

    @property
    def any_io(self) -> bool:
        return (self.read_error_rate > 0 or self.dead_rate > 0
                or self.corrupt_rate > 0 or self.spike_rate > 0)


@dataclasses.dataclass(frozen=True)
class FaultOutcome:
    """Resolution of one read attempt."""

    error: bool = False        # attempt failed outright
    persistent: bool = False   # the block is dead: retries cannot help
    corrupt: bool = False      # payload delivered torn (checksum will fail)
    spike_us: float = 0.0      # extra latency on top of the base read time


class FaultPlan:
    """Seeded, deterministic fault schedule over block reads and training
    steps.  Stateless: every query is a pure hash of its coordinates."""

    def __init__(self, spec: FaultSpec = FaultSpec(), seed: int = 0):
        self.spec = spec
        self.seed = int(seed)

    def __repr__(self) -> str:
        return f"FaultPlan(seed={self.seed}, spec={self.spec})"

    # --- storage faults -----------------------------------------------------
    def dead(self, kind: str, block: int) -> bool:
        """Persistent per-block failure (same answer for every attempt)."""
        if self.spec.dead_rate <= 0:
            return False
        return _u01(self.seed, "dead", kind, int(block)) < self.spec.dead_rate

    def outcome(self, kind: str, block: int, attempt: int) -> FaultOutcome:
        """Resolve one read attempt of `block` on the `kind` device."""
        s = self.spec
        b, a = int(block), int(attempt)
        if self.dead(kind, b):
            return FaultOutcome(error=True, persistent=True)
        if s.read_error_rate > 0 and \
                _u01(self.seed, "err", kind, b, a) < s.read_error_rate:
            return FaultOutcome(error=True)
        corrupt = (s.corrupt_rate > 0
                   and _u01(self.seed, "tear", kind, b, a) < s.corrupt_rate)
        spike = 0.0
        if s.spike_rate > 0 and \
                _u01(self.seed, "spike", kind, b, a) < s.spike_rate:
            # exponential magnitude, deterministic from the same hash family
            u = _u01(self.seed, "spikemag", kind, b, a)
            spike = s.spike_us * -math.log(max(1e-12, 1.0 - u))
        return FaultOutcome(corrupt=corrupt, spike_us=spike)

    def jitter(self, kind: str, block: int, attempt: int) -> float:
        """Uniform [0, 1) backoff jitter draw for a retry."""
        return _u01(self.seed, "jit", kind, int(block), int(attempt))

    def corruption_salt(self, kind: str, block: int, attempt: int) -> int:
        """Which byte perturbation a torn transfer applies (deterministic)."""
        return int(_u01(self.seed, "salt", kind, int(block), int(attempt))
                   * 2 ** 31)

    # --- training faults ----------------------------------------------------
    def fail_step(self, step: int, attempt: int = 0) -> bool:
        """Should training step `step` fail on restart-attempt `attempt`?
        Independent draws per attempt, so checkpoint-restart recovery
        converges (the block-read transient-retry semantics, applied to
        steps)."""
        if self.spec.step_fail_rate <= 0:
            return False
        return _u01(self.seed, "step", int(step),
                    int(attempt)) < self.spec.step_fail_rate


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff + jitter for failed reads.

    An initial attempt plus up to `budget` retries; retry r waits
    ``backoff_us * backoff_mult**r * (1 + jitter * u)`` with u drawn
    deterministically from the fault plan.  budget=0 disables retries
    (first failure is final)."""

    budget: int = 3
    backoff_us: float = 50.0
    backoff_mult: float = 2.0
    jitter: float = 0.5

    def backoff(self, retry_index: int, u: float) -> float:
        return (self.backoff_us * self.backoff_mult ** retry_index
                * (1.0 + self.jitter * u))


# ---------------------------------------------------------------------------
# Payload checksums + deterministic corruption
# ---------------------------------------------------------------------------
def payload_checksum(payload) -> int:
    """CRC32 of a block payload: ndarray, dataclass-of-ndarrays (the storage
    layer's CoupledRecord / GraphBlock), bytes, or None (span placeholder)."""
    if payload is None:
        return 0
    if isinstance(payload, np.ndarray):
        return zlib.crc32(np.ascontiguousarray(payload).tobytes())
    if dataclasses.is_dataclass(payload):
        c = 0
        for f in dataclasses.fields(payload):
            v = np.ascontiguousarray(getattr(payload, f.name))
            c = zlib.crc32(v.tobytes(), c)
        return c
    if isinstance(payload, (bytes, bytearray)):
        return zlib.crc32(bytes(payload))
    return zlib.crc32(repr(payload).encode())


def corrupt_payload(payload, salt: int = 0):
    """A torn copy of `payload`: one element of (the first array of) the
    payload gets its bits flipped, position chosen by `salt`.  The original
    is never mutated.  None (span placeholders) has no bytes to tear and is
    returned as-is."""
    if payload is None:
        return None
    if isinstance(payload, np.ndarray):
        return _corrupt_array(payload, salt)
    if dataclasses.is_dataclass(payload):
        kw = {f.name: getattr(payload, f.name)
              for f in dataclasses.fields(payload)}
        first = dataclasses.fields(payload)[0].name
        kw[first] = _corrupt_array(np.asarray(kw[first]), salt)
        return type(payload)(**kw)
    if isinstance(payload, (bytes, bytearray)):
        b = bytearray(payload)
        if b:
            b[salt % len(b)] ^= 0xFF
        return bytes(b)
    return payload


def _corrupt_array(a: np.ndarray, salt: int) -> np.ndarray:
    out = np.array(a, copy=True)
    flat = out.reshape(-1).view(np.uint8)
    if flat.size:
        flat[salt % flat.size] ^= 0xFF
    return out
