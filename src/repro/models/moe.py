"""Mixture-of-Experts FFN with sort-based expert-parallel dispatch.

Why not GShard one-hot dispatch: the (tokens, E, capacity) dispatch einsum
costs 2*T*E*C*d FLOPs -- at 60 experts / top-4 that *exceeds* the expert
FFN FLOPs themselves and its mask tensor dwarfs VMEM/HBM budgets.  Instead
we use the production pattern (DeepSpeed-MoE / dropless-style):

  1. top-k routing (GSPMD side, tiny).
  2. inside shard_map over (batch axes x model axis):
     a. sort the T_l*k (token, expert) slots by expert id -- destination
        ranks become contiguous;
     b. gather into fixed-capacity per-rank send buffers (mp, C, d);
     c. lax.all_to_all over the model axis (expert parallelism);
     d. locally sort received rows by local expert, gather to (E_l, Ce, d),
        run the gated-FFN einsums (the only "real" FLOPs);
     e. inverse gathers + all_to_all back + weighted scatter-add combine.
  3. load-balance aux loss (GSPMD side).

Everything is fixed-shape (rank capacity C and expert capacity Ce follow
the usual capacity-factor convention; overflow tokens drop, underflow pads
with zero rows).  A `groups` knob scans the tokens in chunks to bound live
buffer memory (and lets XLA overlap the per-group all_to_alls with the
previous group's expert compute).

Expert counts that do not divide the model-axis size are padded with dead
experts (router logits forced to -inf), e.g. qwen2-moe's 60 -> 64.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .layers import act_fn
from repro.utils.sharding import bound_axis_size


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int               # routed experts (logical)
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared experts (fused into one gated FFN)
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    expert_capacity_factor: float = 1.5
    aux_loss_weight: float = 0.01
    groups: int = 1              # token chunks scanned inside shard_map
    pad_multiple: int = 16       # pad n_experts up to a multiple of this

    @property
    def n_experts_padded(self) -> int:
        m = self.pad_multiple
        return -(-self.n_experts // m) * m

    @property
    def d_ff_shared_total(self) -> int:
        return self.d_ff_shared if self.d_ff_shared else 0


def _round8(x: int) -> int:
    return max(8, -(-x // 8) * 8)


# ---------------------------------------------------------------------------
# Routing (GSPMD side)
# ---------------------------------------------------------------------------
def route(x_flat: jnp.ndarray, router_w: jnp.ndarray, cfg: MoEConfig):
    """x (T, d) -> (gates (T, k) f32, eids (T, k) i32, aux_loss scalar)."""
    logits = (x_flat.astype(jnp.float32) @ router_w.astype(jnp.float32))
    e_pad = cfg.n_experts_padded
    if e_pad > cfg.n_experts:  # dead experts: never routable
        neg = jnp.full((logits.shape[0], e_pad - cfg.n_experts), -1e30,
                       jnp.float32)
        logits = jnp.concatenate([logits, neg], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance loss (Switch-style): E * sum_e f_e * p_e
    t = logits.shape[0]
    onehot = jax.nn.one_hot(eids[:, 0], e_pad, dtype=jnp.float32)
    f = onehot.mean(0)
    p = probs.mean(0)
    aux = cfg.n_experts * jnp.sum(f * p) * cfg.aux_loss_weight
    return gates, eids.astype(jnp.int32), aux


# ---------------------------------------------------------------------------
# shard_map body
# ---------------------------------------------------------------------------
def _expert_ffn(xg: jnp.ndarray, wg, wi, wo, activation: str) -> jnp.ndarray:
    """(E_l, Ce, d) x (E_l, d, f) -> (E_l, Ce, d) gated FFN."""
    g = act_fn(activation)(jnp.einsum("ecd,edf->ecf", xg, wg))
    h = g * jnp.einsum("ecd,edf->ecf", xg, wi)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _moe_group_local(xt, gates, eids, wg, wi, wo, *, cfg: MoEConfig,
                     model_axis: str, activation: str):
    """One token group on one device.  xt (Tg, d); gates/eids (Tg, k).

    Runs steps 2a-2e of the module docstring.  All shapes static.
    """
    tg, d = xt.shape
    k = cfg.top_k
    mp = bound_axis_size(model_axis)
    e_pad = cfg.n_experts_padded
    e_l = e_pad // mp
    n_slot = tg * k
    cap = _round8(int(cfg.capacity_factor * n_slot / mp))
    # expected rows per local expert = (mp ranks x n_slot) / e_pad; sizing
    # by the worst-case mp*cap instead multiplies expert FLOPs and buffers
    # by ~mp (measured 13-20x useless compute on qwen/moonshot)
    cap_e = _round8(int(cfg.expert_capacity_factor * mp * n_slot / e_pad))

    flat_e = eids.reshape(-1)                      # (n_slot,)
    flat_g = gates.reshape(-1)
    flat_t = jnp.arange(n_slot, dtype=jnp.int32) // k

    # --- 2a: sort slots by expert id (ranks contiguous) --------------------
    perm = jnp.argsort(flat_e)
    s_e = flat_e[perm]
    s_t = flat_t[perm]
    rank_of = s_e // e_l                           # (n_slot,) sorted too
    seg_start = jnp.searchsorted(rank_of, jnp.arange(mp, dtype=jnp.int32),
                                 side="left").astype(jnp.int32)
    seg_end = jnp.searchsorted(rank_of, jnp.arange(mp, dtype=jnp.int32),
                               side="right").astype(jnp.int32)

    # --- 2b: fixed-capacity send buffers ------------------------------------
    idx = seg_start[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    valid = idx < seg_end[:, None]                 # (mp, cap)
    idx_c = jnp.clip(idx, 0, n_slot - 1)
    send_tok = jnp.where(valid, s_t[idx_c], 0)
    send_eid = jnp.where(valid, s_e[idx_c] % e_l, -1)       # local expert id
    send_x = jnp.where(valid[..., None], xt[send_tok], 0.0)  # (mp, cap, d)

    # --- 2c: expert-parallel exchange ---------------------------------------
    recv_x = jax.lax.all_to_all(send_x, model_axis, 0, 0, tiled=True)
    recv_eid = jax.lax.all_to_all(send_eid, model_axis, 0, 0, tiled=True)
    rx = recv_x.reshape(mp * cap, d)
    re = recv_eid.reshape(mp * cap)                # -1 = padding

    # --- 2d: local per-expert gather + FFN ----------------------------------
    sort_key = jnp.where(re < 0, e_l, re)          # invalid rows sort last
    perm2 = jnp.argsort(sort_key)
    r_e = sort_key[perm2]
    estart = jnp.searchsorted(r_e, jnp.arange(e_l, dtype=jnp.int32),
                              side="left").astype(jnp.int32)
    eend = jnp.searchsorted(r_e, jnp.arange(e_l, dtype=jnp.int32),
                            side="right").astype(jnp.int32)
    eidx = estart[:, None] + jnp.arange(cap_e, dtype=jnp.int32)[None, :]
    evalid = eidx < eend[:, None]                  # (e_l, cap_e)
    eidx_c = jnp.clip(eidx, 0, mp * cap - 1)
    rows = jnp.where(evalid, perm2[eidx_c], 0)
    xg = jnp.where(evalid[..., None], rx[rows], 0.0)        # (e_l, cap_e, d)
    yg = _expert_ffn(xg.astype(wg.dtype), wg, wi, wo, activation)

    # --- 2e: inverse path ----------------------------------------------------
    # scatter expert outputs back to recv-row order
    y_rx = jnp.zeros((mp * cap, d), yg.dtype)
    y_rx = y_rx.at[rows.reshape(-1)].add(
        jnp.where(evalid[..., None], yg, 0.0).reshape(-1, d))
    y_send = jax.lax.all_to_all(y_rx.reshape(mp, cap, d), model_axis, 0, 0,
                                tiled=True)        # back to sender layout
    # combine: slot j's result sits at (rank_of[j], j - seg_start[rank_of[j]])
    pos = jnp.arange(n_slot, dtype=jnp.int32) - seg_start[rank_of]
    ok = pos < cap                                  # dropped slots contribute 0
    row_flat = jnp.clip(rank_of * cap + pos, 0, mp * cap - 1)
    slot_y = jnp.where(ok[:, None], y_send.reshape(mp * cap, d)[row_flat], 0.0)
    w = flat_g[perm][:, None].astype(slot_y.dtype)
    out = jnp.zeros((tg, d), slot_y.dtype)
    out = out.at[s_t].add(slot_y * w)
    return out


def _moe_local(xt, gates, eids, wg, wi, wo, *, cfg: MoEConfig,
               model_axis: str, activation: str):
    """All local tokens, scanned in `groups` chunks.

    Tokens arrive replicated along the model axis (they are sharded over
    the batch axes only).  Each model rank therefore takes its own 1/mp
    slice and the slices' outputs merge with one psum -- without this every
    expert would process mp duplicate copies of its tokens (measured 16x
    FLOPs waste).  Tiny token counts (decode) fall back to the replicated
    path (duplicated but correct).

    The group count adapts downward to the largest divisor of the local
    token count."""
    mp = bound_axis_size(model_axis)
    t_full, d = xt.shape
    sliced = t_full % mp == 0 and t_full >= mp and (t_full // mp) >= 1
    if sliced:
        sl = t_full // mp
        idx = jax.lax.axis_index(model_axis)
        xt = jax.lax.dynamic_slice_in_dim(xt, idx * sl, sl, 0)
        gates = jax.lax.dynamic_slice_in_dim(gates, idx * sl, sl, 0)
        eids = jax.lax.dynamic_slice_in_dim(eids, idx * sl, sl, 0)
    t_l = xt.shape[0]
    g = max(gg for gg in range(1, min(cfg.groups, t_l) + 1) if t_l % gg == 0)
    fn = functools.partial(_moe_group_local, cfg=cfg, model_axis=model_axis,
                           activation=activation)
    if g == 1:
        out = fn(xt, gates, eids, wg, wi, wo)
    else:
        # remat each group: the inner scan otherwise saves every group's
        # dispatch/expert buffers for the backward pass (measured: 60 GiB
        # on qwen2-moe train_4k vs ~9 GiB with per-group recompute)
        fn = jax.checkpoint(fn)

        def body(_, inp):
            xg, gg, eg = inp
            return None, fn(xg, gg, eg, wg, wi, wo)

        _, outs = jax.lax.scan(
            body, None,
            (xt.reshape(g, t_l // g, d),
             gates.reshape(g, t_l // g, -1),
             eids.reshape(g, t_l // g, -1)))
        out = outs.reshape(t_l, d)
    if sliced:
        full = jnp.zeros((t_full, d), out.dtype)
        full = jax.lax.dynamic_update_slice_in_dim(full, out, idx * sl, 0)
        return jax.lax.psum(full, model_axis)
    return out


# ---------------------------------------------------------------------------
# Public layer
# ---------------------------------------------------------------------------
def moe_ffn(x: jnp.ndarray, params: dict, cfg: MoEConfig, *,
            mesh: Optional[Mesh], batch_axes: tuple, model_axis: Optional[str],
            activation: str = "silu"):
    """MoE FFN block.  x (B, S, d) sharded over batch_axes.

    params: router (d, E), we_gate/we_in (E_pad, d, fe), we_out (E_pad, fe, d)
            [+ ws_gate/ws_in/ws_out for the fused shared expert].
    Returns (out (B, S, d), aux_loss).
    """
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gates, eids, aux = route(xt, params["router"], cfg)

    if mesh is None or model_axis is None or model_axis not in mesh.axis_names:
        # single-axis fallback: pure local compute (tests / CPU smoke)
        out = _moe_local_nosharding(xt, gates, eids, params["we_gate"],
                                    params["we_in"], params["we_out"],
                                    cfg=cfg, activation=activation)
    else:
        from jax.experimental.shard_map import shard_map
        # batch axes only when the flat token count divides them (decode
        # cells can have 1 token per sequence, batch 1)
        t = b * s
        ndp = 1
        ba = batch_axes if batch_axes else None
        if ba is not None:
            for a in (ba if isinstance(ba, tuple) else (ba,)):
                ndp *= mesh.devices.shape[mesh.axis_names.index(a)]
            if t < ndp or t % ndp != 0:
                ba = None
        tok_spec = P(ba, None)
        w_spec = P(model_axis, None, None)
        out = shard_map(
            functools.partial(_moe_local, cfg=cfg, model_axis=model_axis,
                              activation=activation),
            mesh=mesh,
            in_specs=(tok_spec, tok_spec, tok_spec, w_spec, w_spec, w_spec),
            out_specs=tok_spec,
            check_rep=False,
        )(xt, gates.astype(x.dtype), eids, params["we_gate"],
          params["we_in"], params["we_out"])

    if cfg.n_shared:
        from .layers import gated_mlp
        shared = gated_mlp(xt, params["ws_gate"], params["ws_in"],
                           params["ws_out"], activation)
        out = out + shared
    return out.reshape(b, s, d).astype(x.dtype), aux


def _moe_local_nosharding(xt, gates, eids, wg, wi, wo, *, cfg: MoEConfig,
                          activation: str):
    """Single-device reference path (mp=1): same sort/gather code with a
    trivial 'exchange' -- also the oracle for the shard_map path."""
    t, d = xt.shape
    k = cfg.top_k
    e_pad = cfg.n_experts_padded
    n_slot = t * k
    cap_e = _round8(int(cfg.expert_capacity_factor * n_slot / e_pad))
    flat_e = eids.reshape(-1)
    flat_g = gates.reshape(-1)
    flat_t = jnp.arange(n_slot, dtype=jnp.int32) // k
    perm = jnp.argsort(flat_e)
    s_e = flat_e[perm]
    s_t = flat_t[perm]
    estart = jnp.searchsorted(s_e, jnp.arange(e_pad, dtype=jnp.int32),
                              side="left").astype(jnp.int32)
    eend = jnp.searchsorted(s_e, jnp.arange(e_pad, dtype=jnp.int32),
                            side="right").astype(jnp.int32)
    eidx = estart[:, None] + jnp.arange(cap_e, dtype=jnp.int32)[None, :]
    evalid = eidx < eend[:, None]
    eidx_c = jnp.clip(eidx, 0, n_slot - 1)
    rows = jnp.where(evalid, s_t[eidx_c], 0)
    xg = jnp.where(evalid[..., None], xt[rows], 0.0)
    yg = _expert_ffn(xg.astype(wg.dtype), wg, wi, wo, activation)
    # combine: slot j -> (expert e = s_e[j], c = j - estart[e])
    pos = jnp.arange(n_slot, dtype=jnp.int32) - estart[s_e]
    ok = pos < cap_e
    flat_idx = jnp.clip(s_e * cap_e + pos, 0, e_pad * cap_e - 1)
    slot_y = jnp.where(ok[:, None], yg.reshape(-1, d)[flat_idx], 0.0)
    w = flat_g[perm][:, None].astype(slot_y.dtype)
    out = jnp.zeros((t, d), slot_y.dtype)
    return out.at[s_t].add(slot_y * w)
