"""Unified base+delta search: one query, two structures, one top-k.

Every query fans out to (a) the frozen BAMG index -- the host Alg-4
block-first path through the I/O simulator, or the fixed-shape batched
serve engine -- and (b) the in-memory delta overlay.  Both sides return
*exact* distances (the base path reranks through raw vectors, the overlay
is exact by construction), so the merge is a straight pool merge through
`repro.build.pool.pool_merge`: base results seed the sorted pool, delta
candidates insert, duplicate ids collapse to the incumbent.  Tombstones
are masked on every path before the merge ever sees them:

- host base path: `exclude=` on `BAMGIndex.search` (masked at rerank);
- batched base path: the engine's traced tombstone mask (masked at
  rerank, which also covers the fused `backend="fused*"` hop loop --
  the fused kernel only builds pools, rerank happens outside it);
- delta path: filtered from the overlay beam's result set.

A tombstoned id therefore never reaches the pool merge, the rerank, or
the final top-k on any path.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.build.pool import pool_merge

from .layer import DeltaLayer


def _merge_topk(base_ids, base_d, cand_ids, cand_d, k: int):
    """(B, Cb) sorted-unique base results + (B, Cc) candidates -> (B, k).

    Base rows satisfy the pool contract (ascending unique, -1/+inf pads);
    candidates may duplicate them (the host delta beam walks base nodes
    too) -- `pool_merge` collapses duplicates to the incumbent."""
    width = max(base_ids.shape[1], k)
    pad = width - base_ids.shape[1]
    if pad:
        base_ids = np.pad(base_ids, ((0, 0), (0, pad)), constant_values=-1)
        base_d = np.pad(base_d, ((0, 0), (0, pad)), constant_values=np.inf)
    ids, d, _ = pool_merge(
        jnp.asarray(base_ids, jnp.int32),
        jnp.asarray(base_d, jnp.float32),
        jnp.zeros(base_ids.shape, bool),
        jnp.asarray(cand_ids, jnp.int32),
        jnp.asarray(cand_d, jnp.float32), width)
    ids = np.asarray(ids[:, :k], np.int64)
    d = np.asarray(d[:, :k], np.float64)
    return np.where(np.isfinite(d), ids, -1), d


class FreshBAMGEngine:
    """Serves base+delta unified top-k over a frozen index and its overlay.

    `base_index` is the frozen `BAMGIndex` (host path); `engine` is an
    optional `BatchedANNEngine` over the same index for the fixed-shape
    batched/fused path (`search_batch`).  The delta overlay is shared.
    """

    def __init__(self, base_index, delta: DeltaLayer,
                 engine=None):
        self.base = base_index
        self.delta = delta
        self.engine = engine

    # --- host path ----------------------------------------------------------
    def search(self, q: np.ndarray, k: int, l: int = 48,
               ef: Optional[int] = None):
        """One query through Alg-4 + the overlay beam; merged exact top-k.

        Returns (ids (k,) int64 with -1 pad, dists (k,) ascending)."""
        q = np.asarray(q, np.float32)
        tomb = self.delta.tombstones
        res = self.base.search(q, k=min(k, l), l=l,
                               exclude=tomb if tomb else None)
        d_ids, d_d = self.delta.search(q, k=k, ef=ef)
        ids, dists = _merge_topk(
            res.ids[None, :].astype(np.int64), res.dists[None, :],
            d_ids[None, :] if len(d_ids) else np.full((1, 1), -1, np.int64),
            d_d[None, :] if len(d_ids) else np.full((1, 1), np.inf), k)
        return ids[0], dists[0]

    # --- batched path -------------------------------------------------------
    def _delta_candidates(self, queries: np.ndarray, k: int):
        """Exact brute-force top-k over the live delta points (vectorized;
        the overlay holds one epoch of writes, so this is a small dense
        scan, the fixed-shape analog of the host overlay beam)."""
        live = self.delta.live_delta_ids()
        if len(live) == 0:
            b = len(queries)
            return (np.full((b, 1), -1, np.int64),
                    np.full((b, 1), np.inf, np.float64))
        xd = self.delta.vectors(live)                      # (Nd, D)
        diff = queries[:, None, :] - xd[None, :, :]
        d = np.einsum("bnd,bnd->bn", diff, diff)           # (B, Nd)
        kk = min(k, len(live))
        part = np.argpartition(d, kk - 1, axis=1)[:, :kk]
        pd = np.take_along_axis(d, part, axis=1)
        o = np.argsort(pd, axis=1, kind="stable")
        return (live[np.take_along_axis(part, o, axis=1)],
                np.take_along_axis(pd, o, axis=1))

    def search_batch(self, queries: np.ndarray, k: int, *,
                     l: Optional[int] = None,
                     max_hops: Optional[int] = None):
        """(B, D) -> merged (ids (B, k) int64, dists (B, k)) over
        base (batched/fused engine, tombstones masked at rerank) + delta
        (exact scan, tombstones filtered)."""
        if self.engine is None:
            raise RuntimeError("no BatchedANNEngine attached; construct "
                               "FreshBAMGEngine(..., engine=...) for the "
                               "batched path")
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        tomb = self.delta.tombstones
        base_tomb = [t for t in tomb if t < self.delta.n_base]
        b_ids, b_d = self.engine.search_batch(
            queries, k, l=l, max_hops=max_hops,
            exclude=base_tomb if base_tomb else None)
        c_ids, c_d = self._delta_candidates(queries, k)
        return _merge_topk(b_ids, b_d, c_ids, c_d, k)
