"""GNN zoo: graphcast (EPD mesh GNN), nequip / mace (E(3)-equivariant),
dimenet (directional triplet MP) -- all on segment-op message passing.

Submodules are imported lazily (import repro.models.gnn.<name>) to keep
partial builds importable.
"""
