"""Pure-jnp oracle for PQ asymmetric distance computation (ADC).

est[b, n] = sum_m tables[b, m, codes[n, m]]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_adc_ref(tables: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """tables (B, M, K) f32; codes (N, M) uint8/int32 -> (B, N) f32."""
    codes = codes.astype(jnp.int32)
    # gather form: for each (b, n, m) pick tables[b, m, codes[n, m]]
    g = jnp.take_along_axis(
        tables[:, None, :, :],                       # (B, 1, M, K)
        codes[None, :, :, None].astype(jnp.int32),   # (1, N, M, 1)
        axis=3,
    )  # (B, N, M, 1)
    return g[..., 0].sum(-1)


def pq_adc_rowwise_ref(tables: jnp.ndarray,
                       cand_codes: jnp.ndarray) -> jnp.ndarray:
    """Per-row ADC: each query scores its *own* gathered candidate codes.

    tables (B, M, K) f32; cand_codes (B, R, M) uint8/int32 -> (B, R) f32.
    The hop-loop form of ADC: the serve beam gathers each row's popped
    adjacency codes, so unlike `pq_adc_ref` there is no shared corpus
    axis.  est[b, r] = sum_m tables[b, m, cand_codes[b, r, m]].
    """
    g = jnp.take_along_axis(
        tables[:, None],                             # (B, 1, M, K)
        cand_codes[..., None].astype(jnp.int32),     # (B, R, M, 1)
        axis=3,
    )  # (B, R, M, 1)
    return g[..., 0].sum(-1)
