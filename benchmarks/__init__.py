"""Benchmark harness: one module per paper table/figure (DESIGN.md §6)."""
