"""The fixed-shape (B, L) insert-sort candidate pool.

Consumed by the batched serving engine (`repro.serve.ann_engine`): a
sorted (ids, dists, expanded) pool per row, merging new candidates with
two stable argsorts -- no Python heaps, one compilation for the lifetime
of the process.  The construction frontier (`repro.build.frontier`) keeps
the same pool *shape* but inlines a leaner merge (single top_k; its
(B, N) seen mask already guarantees candidates are distinct and unseen,
which the serve path cannot assume).
"""
from __future__ import annotations

import jax.numpy as jnp


def pool_merge(pool_ids, pool_d, pool_exp, cand_ids, cand_d, l: int):
    """Vectorized insert-sort of candidates into the sorted (B, L) pool.

    Duplicate ids collapse to the incumbent pool entry (stable sort by id
    keeps the lower concat index first, and the pool occupies indices
    0..L-1), so expanded flags survive re-insertion and a node is not
    re-expanded *while it stays in the pool*.  A node evicted past L loses
    its flag; if the beam later re-encounters it as a best unexpanded
    candidate it is re-expanded -- the price of a fixed-shape pool vs the
    host engine's unbounded `explored` set.  In practice eviction means L
    closer candidates exist, so re-expansion is rare and costs only a hop,
    never correctness.  Returns the new (ids, dists, expanded), sorted
    ascending by dist with invalid entries (+inf, id=-1) at the tail.
    """
    sentinel = jnp.iinfo(jnp.int32).max
    ids = jnp.concatenate([pool_ids, cand_ids.astype(jnp.int32)], axis=1)
    d = jnp.concatenate([pool_d, cand_d], axis=1)
    exp = jnp.concatenate(
        [pool_exp, jnp.zeros(cand_ids.shape, bool)], axis=1)
    d = jnp.where(ids < 0, jnp.inf, d)
    key = jnp.where(ids < 0, sentinel, ids)
    order = jnp.argsort(key, axis=1, stable=True)
    sid = jnp.take_along_axis(key, order, axis=1)
    ids_s = jnp.take_along_axis(ids, order, axis=1)
    d_s = jnp.take_along_axis(d, order, axis=1)
    exp_s = jnp.take_along_axis(exp, order, axis=1)
    dup = jnp.pad(sid[:, 1:] == sid[:, :-1], ((0, 0), (1, 0)))
    ids_s = jnp.where(dup, -1, ids_s)
    d_s = jnp.where(dup, jnp.inf, d_s)
    exp_s = jnp.where(dup, False, exp_s)
    o2 = jnp.argsort(d_s, axis=1, stable=True)[:, :l]
    return (jnp.take_along_axis(ids_s, o2, axis=1),
            jnp.take_along_axis(d_s, o2, axis=1),
            jnp.take_along_axis(exp_s, o2, axis=1))
