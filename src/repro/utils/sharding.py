"""NamedSharding helpers shared by train/serve/dry-run paths.

Sharding conventions (see DESIGN.md §4):
  mesh axes: ("data", "model") single-pod / ("pod", "data", "model") multi-pod
  - batch-like dims        -> ("pod", "data") when multi_pod else ("data",)
  - tensor-parallel dims   -> "model"
  - replicated             -> None
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5: explicit axis types on mesh construction
    from jax.sharding import AxisType as _AxisType
except ImportError:  # jax <= 0.4.x: meshes are implicitly Auto
    _AxisType = None


def make_mesh_compat(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """`jax.make_mesh` with `axis_types=(AxisType.Auto, ...)` where supported.

    jax 0.4.x has neither `jax.sharding.AxisType` nor the `axis_types`
    kwarg; its meshes behave as Auto, so omitting the argument is the
    semantically identical spelling there.
    """
    if _AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(_AxisType.Auto,) * len(axes))


def bound_axis_size(axis_name) -> int:
    """Size of a bound mesh axis inside shard_map/pmap, as a Python int.

    `jax.lax.axis_size` only exists on jax >= 0.5; on 0.4.x, `psum` of a
    Python-literal constant folds to the axis size eagerly.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def batch_axes(mesh: Mesh) -> tuple:
    """The mesh axes that jointly shard the batch dimension."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def spec_batch(mesh: Mesh, *rest: Any) -> P:
    """PartitionSpec with the leading dim sharded over the data(+pod) axes."""
    return P(batch_axes(mesh), *rest)


def ns(mesh: Mesh, spec: Optional[P]) -> NamedSharding:
    return NamedSharding(mesh, spec if spec is not None else P())


def shard_leaf(mesh: Mesh, spec: P, x):
    return jax.device_put(x, ns(mesh, spec))


def mesh_size(mesh: Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


def axis_size(mesh: Mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.devices.shape[mesh.axis_names.index(name)]


def tp_size(mesh: Mesh) -> int:
    return axis_size(mesh, "model")


def dp_size(mesh: Mesh) -> int:
    return axis_size(mesh, "data") * axis_size(mesh, "pod")


def check_divisible(dim: int, parts: int, what: str) -> None:
    if dim % parts != 0:
        raise ValueError(f"{what}={dim} not divisible by mesh factor {parts}")


def specs_like(tree, spec_fn) -> Any:
    """Map a function leaf->PartitionSpec over a pytree of arrays."""
    return jax.tree.map(spec_fn, tree)
