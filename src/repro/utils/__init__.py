from . import faults, sharding, tree  # noqa: F401
