"""Plain-pytest regressions for the PR-5 satellite fixes (kept out of
test_core_graphs.py, whose module-level hypothesis importorskip would
silently skip them in environments without dev dependencies)."""
import numpy as np

from repro.core.block_assign import bnf_blocks, undirected_neighbor_lists
from repro.core.distances import knn_graph, medoid


def _points(n, d, seed):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def test_undirected_view_dedupes_symmetric_edges():
    """Regression: a symmetric edge (u->v and v->u both stored) used to
    insert each endpoint twice, doubling its block-neighbor frequency."""
    adj = np.array([[1, -1],     # 0->1
                    [0, -1],     # 1->0  (symmetric with the above)
                    [0, 1],      # 2->0, 2->1 (one-way)
                    [-1, -1]], np.int32)
    und = undirected_neighbor_lists(adj)
    assert sorted(und[0]) == [1, 2]
    assert sorted(und[1]) == [0, 2]
    assert sorted(und[2]) == [0, 1]
    assert und[3] == []
    for row in und:
        assert len(set(row)) == len(row), "no duplicate neighbors"


def test_bnf_blocks_symmetrization_is_noop():
    """The undirected view of a graph equals that of its explicit
    symmetrization, so BNF must produce the same assignment for both --
    the old double-counting inflated frequencies on the symmetrized copy."""
    x = _points(80, 4, 2)
    adj = knn_graph(x, 4)
    sym = [set(adj[u][adj[u] >= 0].tolist()) for u in range(80)]
    for u in range(80):
        for v in list(sym[u]):
            sym[v].add(u)
    width = max(len(s) for s in sym)
    full = -np.ones((80, width), np.int32)
    for u, s in enumerate(sym):
        full[u, : len(s)] = sorted(s)
    assert np.array_equal(bnf_blocks(adj, 8, seed=3),
                          bnf_blocks(full, 8, seed=3))
    counts = np.bincount(bnf_blocks(full, 8, seed=3))
    assert counts.max() <= 8


def test_knn_graph_pads_with_negative_one():
    """Regression: short rows used to be padded by repeating earlier
    entries, creating duplicate edges downstream; they must be -1 now.
    (k >= n is the only reachable short-row case -- and it used to crash
    in top_k before the clamp.)"""
    x = np.zeros((4, 3), np.float32)
    x[3] = 1.0
    adj = knn_graph(x, 5)            # k exceeds n-1: rows have 3 entries
    assert adj.shape == (4, 5)
    for i in range(4):
        row = adj[i]
        valid = row[row >= 0]
        assert len(set(valid.tolist())) == len(valid), "no duplicate edges"
        assert i not in valid.tolist()
        assert len(valid) == 3
    assert (adj < 0).any(), "short rows must be -1 padded"
    # degenerate duplicates at n > k: rows stay full, distinct, self-free
    y = np.zeros((8, 3), np.float32)
    adj2 = knn_graph(y, 5)
    for i in range(8):
        row = adj2[i]
        assert (row >= 0).all()
        assert len(set(row.tolist())) == 5 and i not in row.tolist()


def test_medoid_sampled_approximation():
    x = _points(500, 6, 21)
    exact = medoid(x, sample=None)
    assert exact == int(np.argmin(((x - x.mean(0)) ** 2).sum(1)))
    # sampled mode: argmin restricted to the seeded candidate set
    approx = medoid(x, sample=64, seed=9)
    cand = np.random.default_rng(9).choice(500, size=64, replace=False)
    d = ((x[cand] - x.mean(0)) ** 2).sum(1)
    assert approx == int(cand[np.argmin(d)])
    # small n: sampling is a no-op
    assert medoid(x, sample=1000, seed=9) == exact


