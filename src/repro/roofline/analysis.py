"""Three-term roofline from compiled dry-run artifacts (TPU v5e target).

  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_traffic_per_device / HBM_bw
  collective term = weighted collective bytes / ICI link bw

HLO numbers come from the loop-corrected parser (roofline/hlo_parse.py --
XLA's cost_analysis does not multiply while bodies).  Per-device shapes:
compiled.as_text() is post-SPMD.

Collective weighting (ring algorithms, P = participating devices):
  all-reduce      2 (P-1)/P   ~ 2x payload over the slowest link
  all-gather      (P-1)/P     (payload = gathered output, counted as the
                               shard each device must receive)
  reduce-scatter  (P-1)/P
  all-to-all      (P-1)/P     (each device keeps 1/P of its payload)
  collective-permute 1
We report the simple x2 / x1 weights (P large) -- the error is O(1/P).
"""
from __future__ import annotations

import dataclasses

from .hlo_parse import analyze_compiled_text

# TPU v5e per chip (brief-specified constants)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

COLL_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


@dataclasses.dataclass
class Roofline:
    flops: float                  # per device, loop-corrected
    traffic_bytes: float          # per device
    collective_bytes: float       # weighted, per device
    collectives: dict             # raw per-kind bytes
    model_flops: float            # analytic useful FLOPs (whole step, global)
    n_devices: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.traffic_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time (no overlap assumption: max of terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops x devices): compiled-compute usefulness."""
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        if self.t_bound <= 0:
            return 0.0
        return (self.model_flops / self.n_devices / self.t_bound) / PEAK_FLOPS

    def summary(self) -> dict:
        return {
            "hlo_flops_per_dev": self.flops,
            "traffic_bytes_per_dev": self.traffic_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "collectives": self.collectives,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "t_bound_s": self.t_bound,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "mfu_bound": self.mfu_bound,
        }


def roofline_from_text(hlo_text: str, model_flops: float,
                       n_devices: int) -> Roofline:
    agg = analyze_compiled_text(hlo_text)
    coll = agg["collectives"]
    weighted = sum(COLL_WEIGHT.get(k, 1.0) * v for k, v in coll.items())
    return Roofline(flops=agg["flops"], traffic_bytes=agg["traffic_bytes"],
                    collective_bytes=weighted, collectives=coll,
                    model_flops=model_flops, n_devices=n_devices)
