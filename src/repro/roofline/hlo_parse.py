"""Optimized-HLO text parser: loop-corrected FLOPs, memory traffic, and
per-collective byte counts.

Why not compiled.cost_analysis(): XLA reports while-loop bodies ONCE, not
times their trip count -- a scanned 28-layer transformer under-reports
FLOPs ~28x (measured).  We parse `compiled.as_text()` (post-SPMD, i.e.
*per-device* shapes), build the computation call graph, extract loop trip
counts from the loop-condition compare-against-constant pattern, and
propagate multipliers.

Accounting per computation:
  flops            2 * prod(dot output shape) * prod(contracting dims)
  traffic_bytes    output bytes of every materializing op (post-fusion, so
                   this approximates HBM write traffic; reads ~ equal)
  coll_bytes[kind] payload bytes for all-reduce / all-gather /
                   reduce-scatter / all-to-all / collective-permute
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_ATTR_RES = {
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
}
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

SKIP_OPS = ("parameter(", "constant(", "tuple(", "get-tuple-element(",
            "bitcast(", "after-all(", "partition-id(", "replica-id(",
            "iota(")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    traffic: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    whiles: list = dataclasses.field(default_factory=list)   # (cond, body, trips)
    calls: list = dataclasses.field(default_factory=list)    # plain callees
    consts_s32: list = dataclasses.field(default_factory=list)


def _dot_flops(rhs: str, types: dict) -> float:
    """FLOPs of one dot line.  Operand types are looked up in the
    per-computation symbol table (optimized HLO omits inline types)."""
    out_dims = _shape_dims(rhs)
    if out_dims is None:
        return 0.0
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    try:
        paren = rhs.index("dot(")
    except ValueError:
        return 0.0
    args = rhs[paren + 4:]
    lhs_dims = None
    inline = _SHAPE_RE.search(args.split(",")[0])
    if inline:
        g2 = inline.group(2)
        lhs_dims = [int(d) for d in g2.split(",")] if g2 else []
    else:
        om = _OPERAND_RE.search(args)
        if om and om.group(1) in types:
            lhs_dims = _shape_dims(types[om.group(1)])
    contract = 1
    if mdims and lhs_dims is not None:
        for ci in mdims.group(1).split(","):
            if ci != "" and int(ci) < len(lhs_dims):
                contract *= lhs_dims[int(ci)]
    return 2.0 * out_elems * contract


def parse_hlo(text: str) -> dict[str, CompStats]:
    """Parse module text into per-computation stats."""
    comps: dict[str, CompStats] = {}
    entry_name = [None]
    cur: CompStats | None = None
    types: dict[str, str] = {}
    for line in text.splitlines():
        ls = line.strip()
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{$", ls)
        if m:
            cur = comps.setdefault(m.group(2), CompStats())
            types = {}
            if m.group(1):
                entry_name[0] = m.group(2)
            continue
        if cur is None:
            continue
        if ls.startswith("}"):
            cur = None
            continue
        om = _OP_RE.match(ls)
        if not om:
            continue
        name, rhs = om.group(1), om.group(2)
        types[name] = rhs.split("(")[0]
        cm = _CONST_RE.search(ls)
        if cm:
            cur.consts_s32.append(int(cm.group(1)))
        opname_part = rhs[:96]
        if any(s in opname_part for s in SKIP_OPS):
            continue
        for c in COLLECTIVES:
            if re.search(rf"\b{c}(?:-start)?\(", rhs):
                cur.coll[c] += shape_bytes(rhs.split("(")[0])
                break
        if re.search(r"\bdot(?:_general)?\w*\s*=|\bdot\(", rhs) \
                and " dot(" in " " + rhs:
            cur.flops += _dot_flops(rhs, types)
        cur.traffic += shape_bytes(rhs.split("(")[0])
        if " while(" in rhs or rhs.startswith("while("):
            cm2 = _ATTR_RES["condition"].search(rhs)
            bm = _ATTR_RES["body"].search(rhs)
            tm = _TRIP_RE.search(rhs)
            trips = float(tm.group(1)) if tm else None
            if cm2 and bm:
                cur.whiles.append((cm2.group(1), bm.group(1), trips))
        else:
            is_fusion = " fusion(" in " " + rhs
            for key in ("calls", "to_apply"):
                am = _ATTR_RES[key].search(rhs)
                if am:
                    cur.calls.append((am.group(1), is_fusion))
            bm = _ATTR_RES["branches"].search(rhs)
            if bm:
                for c in bm.group(1).split(","):
                    cur.calls.append((c.strip().lstrip("%"), False))
    comps["__entry__"] = comps.get(entry_name[0], CompStats()) \
        if entry_name[0] else CompStats()
    if entry_name[0]:
        comps["__entry_name__"] = entry_name[0]  # type: ignore
    return comps


def _trip_count(comps: dict, cond_name: str) -> float:
    """Trip count heuristic: largest s32 constant in the loop condition."""
    cond = comps.get(cond_name)
    if cond is not None and getattr(cond, "consts_s32", None):
        return float(max(cond.consts_s32))
    return 1.0


def aggregate(comps: dict) -> dict:
    entry = comps.get("__entry_name__")
    if not isinstance(entry, str):
        called = set()
        for n, st in comps.items():
            if not isinstance(st, CompStats):
                continue
            called.update(c for c, _f in st.calls)
            for cond, body, _t in st.whiles:
                called.add(cond)
                called.add(body)
        cands = [n for n, st in comps.items()
                 if isinstance(st, CompStats) and n not in called
                 and not n.startswith("__")]
        entry = cands[0] if cands else None

    memo: dict[str, tuple] = {}

    def roll(name: str, depth=0) -> tuple:
        if name in memo:
            return memo[name]
        st = comps.get(name)
        if not isinstance(st, CompStats) or depth > 64:
            return (0.0, 0.0, {})
        memo[name] = (0.0, 0.0, {})  # cycle guard
        flops, traffic = st.flops, st.traffic
        coll = defaultdict(float, st.coll)
        for callee, is_fusion in st.calls:
            f2, t2, c2 = roll(callee, depth + 1)
            flops += f2
            # fusion internals never touch HBM: count flops, skip traffic
            if not is_fusion:
                traffic += t2
            for k, v in c2.items():
                coll[k] += v
        for cond, body, trips in st.whiles:
            if trips is None:
                trips = _trip_count(comps, cond)
            f2, t2, c2 = roll(body, depth + 1)
            flops += trips * f2
            traffic += trips * t2
            for k, v in c2.items():
                coll[k] += trips * v
        memo[name] = (flops, traffic, dict(coll))
        return memo[name]

    if entry is None:
        return {"flops": 0.0, "traffic_bytes": 0.0, "collectives": {},
                "entry": None}
    flops, traffic, coll = roll(entry)
    return {"flops": flops, "traffic_bytes": traffic,
            "collectives": coll, "entry": entry}


def analyze_compiled_text(text: str) -> dict:
    return aggregate(parse_hlo(text))
