"""Serving throughput: batched fixed-shape engine vs the host query loop.

Rows: host-engine wall-clock qps, then the batched engine's qps at batch
sizes {1, 8, 64, 256} (same index, same search budget l) with p50/p99
per-call latency, plus recall of both so the speedup is apples-to-apples.
The acceptance bar for the serving layer is batched-qps(B=64) > host-qps.

The tail isolates the hop loop: per-hop latency of the unfused scan vs
the fused beam kernel (`EngineConfig(backend="fused")`; auto-resolves to
the jnp fused oracle on CPU, the Pallas program on TPU) by differencing
engine wall time across two hop budgets -- entry selection, re-rank and
dispatch overheads subtract out.
"""
import time

import numpy as np

from . import common
from repro.core.distances import recall_at_k
from repro.serve import BatchedANNEngine, EngineConfig

K = 10
L = 48
BATCHES = (1, 8, 64, 256)
HOP_SPLIT = (8, 32)        # hop budgets differenced for per-hop timing


def run() -> None:
    regime = "sift-like"
    ds = common.dataset(regime)
    idx = common.default_bamg(regime)

    t0 = time.perf_counter()
    st = idx.search_batch(ds.queries, k=K, l=L, gt=ds.gt)
    host_s = time.perf_counter() - t0
    host_qps = len(ds.queries) / host_s
    common.emit("serve.host_loop.qps", round(host_qps, 1),
                f"recall={st.recall:.3f}")

    eng = BatchedANNEngine.from_index(idx, EngineConfig(l=L, max_hops=32))
    ids, _ = eng.search_batch(ds.queries, K)
    common.emit("serve.batched.recall", round(recall_at_k(ids, ds.gt, K), 3),
                f"l={L}")

    nq = len(ds.queries)
    for b in BATCHES:
        q = np.tile(ds.queries, (-(-b // nq), 1))[:b]
        eng.search_batch(q, K)                       # compile + warm
        reps = max(4, 256 // b)
        lat = np.empty(reps)
        for i in range(reps):
            t0 = time.perf_counter()
            eng.search_batch(q, K)
            lat[i] = time.perf_counter() - t0
        qps = b * reps / lat.sum()
        p50, p99 = np.percentile(lat, [50, 99]) * 1e3
        common.emit(f"serve.batched.b{b}.qps", round(qps, 1),
                    f"p50_ms={p50:.2f} p99_ms={p99:.2f} "
                    f"speedup_vs_host={qps / host_qps:.2f}x")

    # --- per-hop latency, unfused scan vs fused beam kernel (B=64)
    q = np.tile(ds.queries, (-(-64 // nq), 1))[:64]
    per_hop = {}
    for backend in ("ref", "fused"):
        times = []
        for hops in HOP_SPLIT:
            e = BatchedANNEngine.from_index(
                idx, EngineConfig(l=L, max_hops=hops, backend=backend))
            e.search_batch(q, K)                     # compile + warm
            reps = 8
            t0 = time.perf_counter()
            for _ in range(reps):
                e.search_batch(q, K)
            times.append((time.perf_counter() - t0) / reps)
        per_hop[backend] = ((times[1] - times[0])
                            / (HOP_SPLIT[1] - HOP_SPLIT[0]) * 1e6)
        common.emit(f"serve.{backend}.b64.hop_us",
                    round(per_hop[backend], 1), f"l={L}")
    common.emit("serve.fused.b64.hop_speedup",
                round(per_hop["ref"] / per_hop["fused"], 2),
                "unfused_scan_vs_fused_kernel")


if __name__ == "__main__":
    run()
