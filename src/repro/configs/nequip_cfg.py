"""nequip [arXiv:2101.03164]: O(3)-equivariant interatomic potential,
5 layers, 32 channels, l_max=2, n_rbf=8, cutoff=5."""
from repro.models.gnn.nequip import NequIPConfig

from .base import GNN_SHAPES

ARCH_ID = "nequip"
FAMILY = "gnn"
SHAPES = GNN_SHAPES


def model_config(reduced: bool = False) -> NequIPConfig:
    if reduced:
        return NequIPConfig(name=ARCH_ID + "-smoke", n_layers=2, channels=8,
                            l_max=2, n_rbf=4)
    return NequIPConfig(name=ARCH_ID, n_layers=5, channels=32, l_max=2,
                        n_rbf=8, cutoff=5.0)
