"""Sharded embedding substrate for recsys (kernel_taxonomy §RecSys).

JAX has no native EmbeddingBag and no CSR sparse -- we build both pieces:

  * `embedding_bag`: take + segment_sum pooled lookup (sum/mean), the hot
    path of every recsys model.
  * `sharded_lookup`: Megatron-style row-sharded table lookup under
    shard_map (masked local take + psum over the model axis) -- the same
    vocab-parallel pattern the LM embedding uses; tables of 10^6..10^9 rows
    shard over `model` and are never gathered.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def embedding_bag(table: jnp.ndarray, flat_ids: jnp.ndarray,
                  segment_ids: jnp.ndarray, n_segments: int,
                  mode: str = "sum",
                  weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Pooled lookup: out[s] = pool_{i: seg[i]=s} table[flat_ids[i]].

    flat_ids (M,) int32 (negative = padding); segment_ids (M,) int32.
    """
    ok = flat_ids >= 0
    rows = table[jnp.clip(flat_ids, 0, table.shape[0] - 1)]
    if weights is not None:
        rows = rows * weights[:, None]
    rows = jnp.where(ok[:, None], rows, 0.0)
    seg = jnp.where(ok, segment_ids, n_segments)
    out = jax.ops.segment_sum(rows, seg, num_segments=n_segments + 1)[:n_segments]
    if mode == "mean":
        cnt = jax.ops.segment_sum(ok.astype(rows.dtype), seg,
                                  num_segments=n_segments + 1)[:n_segments]
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def sharded_lookup(table: jnp.ndarray, ids: jnp.ndarray,
                   mesh: Optional[Mesh], model_axis: Optional[str],
                   batch_axes: tuple = ()) -> jnp.ndarray:
    """Row-sharded table[ids]: masked local take + psum over `model_axis`.

    ids may have any shape (leading dim sharded over batch_axes); the table
    is sharded P(model_axis, None).  Without a mesh: plain take.
    """
    if mesh is None or model_axis is None or model_axis not in mesh.axis_names:
        return table[jnp.clip(ids, 0, table.shape[0] - 1)]
    from jax.experimental.shard_map import shard_map
    tp = mesh.devices.shape[mesh.axis_names.index(model_axis)]
    v_local = table.shape[0] // tp
    ba = batch_axes if batch_axes else None
    id_spec = P(ba, *([None] * (ids.ndim - 1)))
    out_spec = P(ba, *([None] * ids.ndim))

    def body(tab_l, ids_l):
        off = jax.lax.axis_index(model_axis) * v_local
        loc = ids_l.astype(jnp.int32) - off
        ok = (loc >= 0) & (loc < v_local)
        rows = tab_l[jnp.clip(loc, 0, v_local - 1)]
        rows = jnp.where(ok[..., None], rows, 0.0)
        return jax.lax.psum(rows, model_axis)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(model_axis, None), id_spec),
                     out_specs=out_spec, check_rep=False)(table, ids)
