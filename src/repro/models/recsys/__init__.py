"""RecSys: DIN (Deep Interest Network) + sharded embedding substrate."""
