"""Small pytree helpers (we do not depend on flax/optax/chex)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)
