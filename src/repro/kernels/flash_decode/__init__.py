from .ops import flash_decode  # noqa: F401
from .ref import flash_decode_ref  # noqa: F401
