"""Paper Fig. 8: effect of alpha (intra-block path bound, build + search)."""
from . import common


def run(regime: str = "sift-like", alphas=(1, 2, 3, 5)) -> None:
    for a in alphas:
        idx = common.bamg_index(regime, alpha=a)
        sw = common.sweep(idx, regime, ls=(48,))
        l, recall, nio, qps, g, v = sw[0]
        deg = idx.degree_stats()
        common.emit(f"fig8_alpha.{regime}.a{a}", round(nio, 2),
                    f"recall={recall:.3f};qps={qps:.0f};"
                    f"deg={deg['total']:.1f}")


if __name__ == "__main__":
    run()
