"""Data determinism + pipeline restart safety + roofline parser."""
import numpy as np
import pytest

from repro.data.pipeline import ShardedPipeline, shard_rows
from repro.data.synthetic import (clustered_vectors, din_batch, lm_batch,
                                  make_vector_dataset, molecules_batch,
                                  random_graph)


def test_lm_batch_deterministic_per_step():
    a1, b1 = lm_batch(5, 4, 16, 100, seed=1)
    a2, b2 = lm_batch(5, 4, 16, 100, seed=1)
    np.testing.assert_array_equal(a1, a2)
    a3, _ = lm_batch(6, 4, 16, 100, seed=1)
    assert not np.array_equal(a1, a3)
    # labels are next-token shifted
    full1, _ = lm_batch(5, 4, 16, 100, seed=1)
    assert (b1[:, :-1] == a1[:, 1:]).all()


def test_pipeline_random_access_equals_iteration():
    pipe = ShardedPipeline(lambda s: {"x": np.full((4,), s)})
    seen = dict(pipe.iterate(3, 5))
    for s in range(3, 8):
        np.testing.assert_array_equal(seen[s]["x"], pipe.batch_at(s)["x"])


def test_shard_rows_partition():
    batch = {"x": np.arange(12).reshape(12, 1)}
    parts = [shard_rows(i, 4)(batch)["x"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), batch["x"])


def test_vector_dataset_gt_exact():
    ds = make_vector_dataset("t", 200, 8, 5, k_gt=3, seed=2)
    d = ((ds.queries[:, None] - ds.base[None]) ** 2).sum(-1)
    ref = np.argsort(d, axis=1)[:, :3]
    assert (ds.gt[:, :3] == ref).mean() > 0.99


def test_graph_generators_shapes():
    g = random_graph(50, 200, d_feat=6, seed=0)
    assert g.node_feat.shape == (50, 6) and len(g.edge_src) == 200
    g2 = random_graph(30, 120, d_feat=4, seed=0, geometric=True)
    assert g2.pos.shape == (30, 3)
    mol, gid = molecules_batch(3, 10, 24, seed=0)
    assert mol.pos.shape == (30, 3) and gid.max() == 2


def test_din_batch_label_correlation():
    hi, hc, hl, ti, tc, y = din_batch(0, 4096, 20, 1000, 32, seed=0)
    # labels must correlate with category-in-history (learnable signal)
    mask = np.arange(20)[None] < hl[:, None]
    seen = ((hc == tc[:, None]) & mask).any(1)
    agree = (seen == (y > 0.5)).mean()
    assert agree > 0.8


def test_hlo_parser_on_scan_matmul():
    import jax
    import jax.numpy as jnp
    from repro.roofline.hlo_parse import analyze_compiled_text
    w = jnp.ones((5, 64, 64), jnp.float32)
    x0 = jnp.ones((64, 64), jnp.float32)

    def f(x0, w):
        return jax.lax.scan(lambda x, wi: (x @ wi, None), x0, w)[0]

    res = analyze_compiled_text(jax.jit(f).lower(x0, w).compile().as_text())
    exp = 5 * 2 * 64 ** 3
    assert 0.9 < res["flops"] / exp < 1.1
    assert res["traffic_bytes"] > 0


def test_roofline_terms():
    from repro.roofline.analysis import Roofline
    r = Roofline(flops=197e12, traffic_bytes=819e9 / 2,
                 collective_bytes=50e9 / 4, collectives={},
                 model_flops=100e12 * 256, n_devices=256)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.t_collective == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.mfu_bound == pytest.approx(100e12 / 197e12, rel=1e-6)
