"""Attention: chunked causal GQA prefill/train + KV-cache decode.

Three compute paths, one semantics (tests cross-check them):
  * `causal_attention`      -- chunked (flash-style) online-softmax scan over
                               KV blocks, pure jnp: the dry-run / CPU path
                               and the under-jit TPU fallback.
  * `kernels.flash_decode`  -- Pallas TPU decode kernel (interpret-validated).
  * `decode_attention`      -- dispatches decode to the kernel (or ref) and,
                               when the KV cache is *sequence-sharded*,
                               merges per-shard partial softmax states with a
                               log-sum-exp psum (the distributed flash-decode
                               of DESIGN.md §2, for long_500k / kv_heads not
                               divisible by TP).

Supports GQA/MQA (h = g * h_kv) and sliding-window attention (danube).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels.flash_decode import flash_decode
from ..kernels.flash_decode.ref import flash_decode_ref

NEG_INF = -1e30


def repeat_kv(k: jnp.ndarray, g: int) -> jnp.ndarray:
    """(B, S, Hkv, Dh) -> (B, S, Hkv*g, Dh) by head repetition."""
    if g == 1:
        return k
    b, s, hkv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, g, dh)).reshape(
        b, s, hkv * g, dh)


def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     q_offset: jnp.ndarray | int = 0,
                     window: Optional[int] = None,
                     chunk_q: int = 512, chunk_kv: int = 1024,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """Causal (optionally sliding-window) GQA attention, memory-bounded.

    q (B, Sq, H, Dh); k, v (B, Skv, Hkv, Dh).  Query position i attends to
    kv position j iff j <= i + q_offset and (window is None or
    i + q_offset - j < window).  Online softmax over KV chunks keeps the
    live score tile at (B, chunk_q, H, chunk_kv).
    """
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = dh ** -0.5 if scale is None else scale
    cq = min(chunk_q, sq)
    ck = min(chunk_kv, skv)
    nq = -(-sq // cq)
    nk = -(-skv // ck)
    pad_q = nq * cq - sq
    pad_k = nk * ck - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    kf = repeat_kv(k, g)
    vf = repeat_kv(v, g)
    qf = (q.astype(jnp.float32) * scale)
    # (nq, B, cq, H, Dh)
    qs = qf.reshape(b, nq, cq, h, dh).transpose(1, 0, 2, 3, 4)
    ks = kf.astype(jnp.float32).reshape(b, nk, ck, h, dh).transpose(1, 0, 2, 3, 4)
    vs = vf.astype(jnp.float32).reshape(b, nk, ck, h, dh).transpose(1, 0, 2, 3, 4)

    q_pos_base = jnp.asarray(q_offset, jnp.int32)

    def q_chunk_body(carry, qi_inp):
        qi_idx, qc = qi_inp                               # (), (B, cq, H, Dh)
        q_pos = q_pos_base + qi_idx * cq + jnp.arange(cq, dtype=jnp.int32)

        def kv_body(state, kv_inp):
            m, l, acc = state
            kj_idx, kc, vc = kv_inp
            k_pos = kj_idx * ck + jnp.arange(ck, dtype=jnp.int32)
            s = jnp.einsum("bqhd,bkhd->bqhk", qc, kc)     # (B, cq, H, ck)
            ok = k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                ok &= (q_pos[:, None] - k_pos[None, :]) < window
            ok &= (k_pos < skv)[None, :]                  # kv padding
            s = jnp.where(ok[None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(ok[None, :, None, :], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bqhk,bkhd->bqhd", p, vc)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, cq, h), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, cq, h), jnp.float32)
        a0 = jnp.zeros((b, cq, h, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (jnp.arange(nk, dtype=jnp.int32), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out

    # checkpoint per q-chunk: the backward otherwise saves every inner
    # kv-scan carry for every q chunk (measured GiBs on 32k prefill)
    _, outs = jax.lax.scan(jax.checkpoint(q_chunk_body), None,
                           (jnp.arange(nq, dtype=jnp.int32), qs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * cq, h, dh)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode (one new token against a KV cache)
# ---------------------------------------------------------------------------
def decode_attention_local(q: jnp.ndarray, cache_k: jnp.ndarray,
                           cache_v: jnp.ndarray, cache_len: jnp.ndarray,
                           backend: str = "auto") -> jnp.ndarray:
    """Per-device decode attention. q (B, H, Dh); caches (B, S, Hkv, Dh)."""
    return flash_decode(q, cache_k, cache_v, cache_len, backend=backend)


def _decode_partial(q, cache_k, cache_v, cache_len, scale):
    """Unnormalized local attention + softmax stats for cross-shard merge.

    Returns (acc (B,H,Dh) = sum_j exp(s_j - m) v_j, m (B,H), l (B,H)).
    """
    b, h, dh = q.shape
    s, hkv = cache_k.shape[1], cache_k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, dh) * scale
    kf = cache_k.astype(jnp.float32)
    vf = cache_v.astype(jnp.float32)
    scores = jnp.einsum("bngd,bsnd->bngs", qf, kf)
    ok = jnp.arange(s)[None, :] < cache_len[:, None]
    scores = jnp.where(ok[:, None, None, :], scores, NEG_INF)
    m = scores.max(-1)                                     # (B, Hkv, G)
    p = jnp.exp(scores - m[..., None])
    p = jnp.where(ok[:, None, None, :], p, 0.0)
    l = p.sum(-1)
    acc = jnp.einsum("bngs,bsnd->bngd", p, vf)
    return (acc.reshape(b, h, dh), m.reshape(b, h), l.reshape(b, h))


def decode_attention_seqsharded(q, cache_k, cache_v, cache_len, axis_names,
                                scale: Optional[float] = None):
    """Decode attention with the KV cache sequence-sharded over `axis_names`
    (call inside shard_map).  Each shard computes a partial softmax over its
    KV slice; partials merge with a log-sum-exp psum -- one small collective
    of (B, H, Dh + 2) per layer, the TPU analogue of the paper's "one I/O
    per monotone step".

    cache_len here is the *local* valid length of this shard's slice.
    """
    dh = q.shape[-1]
    scale = dh ** -0.5 if scale is None else scale
    acc, m, l = _decode_partial(q, cache_k, cache_v, cache_len, scale)
    m_glob = jax.lax.pmax(m, axis_names)                   # (B, H)
    w = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * w, axis_names)
    acc_glob = jax.lax.psum(acc * w[..., None], axis_names)
    return (acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]).astype(q.dtype)


def shard_lengths(total_len: jnp.ndarray, shard_idx: jnp.ndarray,
                  shard_size: int) -> jnp.ndarray:
    """Local valid length of shard `shard_idx` for a prefix of `total_len`."""
    start = shard_idx * shard_size
    return jnp.clip(total_len - start, 0, shard_size)
