"""`GraphBuilder`: the construction facade consumed by the engine layer.

Selects between two backends for the three expensive build stages:

- ``backend="host"``: the original per-node numpy/heapq builders in
  `repro.core.graph_build` / `repro.core.bamg` -- the reference oracle
  (exact paper semantics, used by the parity tests).
- ``backend="batched"``: jit'd fixed-shape pipelines -- whole node batches
  run the candidate beam (`repro.build.frontier`), the RobustPrune scan
  (`repro.build.prune`) and the Algorithm-2 intra-block probes
  (`repro.build.bamg_refine`) as array programs.

Batched semantics vs host: NSG and the BAMG refinement are node-order
independent, so the batched NSG differs from the host's only through the
frontier's fixed-hop termination (recall-equivalent; the refinement is
bit-identical given the same base graph).  Batched Vamana applies each
batch's edge updates after searching the whole batch on one graph snapshot
(DiskANN-style batch insertion), where the host updates after every node.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import graph_build as host
from repro.core.bamg import BAMGGraph, build_bamg_from
from repro.core.block_assign import bnf_blocks
from repro.core.distances import knn_graph, medoid

from .bamg_refine import refine_bamg_batched
from .chunking import map_chunks
from .frontier import frontier_pools
from .knn import clustered_knn_graph
from .prune import robust_prune_batch

BACKENDS = ("host", "batched")
FRONTIER_BACKENDS = ("batched", "fused", "fused_pallas", "fused_interpret",
                     "fused_ref", "fused_stream", "fused_stream_interpret")


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    backend: str = "host"        # "host" (reference oracle) | "batched"
    batch_size: int = 256        # nodes per jitted frontier/prune step
    pair_chunk: int = 4096       # (v, q) probe pairs per jitted BAMG chunk
    beam_width: int = 8          # frontier expansions per hop
    max_hops: int | None = None  # frontier hops (default: ~ef/beam_width)
    knn_mode: str = "clustered"  # batched NSG kNN stage: "clustered"|"exact"
    # candidate-beam implementation for the batched backend: "batched"
    # (seen-mask beam) or "fused"/"fused_pallas"/"fused_interpret"/
    # "fused_ref"/"fused_stream"/"fused_stream_interpret" (the serve
    # engine's fused hop kernel at width 1, repro.kernels.beam_fused;
    # beam_width is then ignored -- the fused_stream* modes stream the
    # corpus from HBM so construction frontiers scale past VMEM too)
    frontier_backend: str = "batched"

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")
        if self.frontier_backend not in FRONTIER_BACKENDS:
            raise ValueError(
                f"frontier_backend must be one of {FRONTIER_BACKENDS}, "
                f"got {self.frontier_backend!r}")
        if self.knn_mode not in ("clustered", "exact"):
            raise ValueError(f"knn_mode must be 'clustered' or 'exact', "
                             f"got {self.knn_mode!r}")


class GraphBuilder:
    """Facade over the host and batched construction pipelines."""

    def __init__(self, config: BuildConfig = BuildConfig()):
        self.config = config

    # -- helpers ------------------------------------------------------------
    def _prune(self, x, p_ids, cand_ids, r: int, alpha: float) -> np.ndarray:
        """Chunked batched RobustPrune with last-chunk padding, so one
        compilation per candidate width serves the whole build.  `x` may be
        a preloaded jnp array (no per-chunk upload); independent chunks are
        pipelined two-deep."""
        b = self.config.batch_size
        p_ids = np.asarray(p_ids, np.int64)
        cand_ids = np.asarray(cand_ids, np.int32)
        out = np.empty((len(p_ids), r), np.int32)

        def run(s):
            p = p_ids[s : s + b]
            c = cand_ids[s : s + b]
            pad = b - len(p)
            if pad:
                p = np.concatenate([p, np.zeros(pad, p.dtype)])
                c = np.concatenate(
                    [c, -np.ones((pad, c.shape[1]), c.dtype)])
            kept = robust_prune_batch(x, p, c, None, r=r, alpha=alpha)
            out[s : s + b - pad] = kept[: len(out) - s]

        map_chunks(list(range(0, len(p_ids), b)), run)
        return out

    # -- Vamana (DiskANN) ----------------------------------------------------
    def build_vamana(self, x: np.ndarray, r: int = 32, l_build: int = 64,
                     alpha: float = 1.2, seed: int = 0,
                     passes: int = 2) -> tuple[np.ndarray, int]:
        if self.config.backend == "host":
            return host.build_vamana(x, r=r, l_build=l_build, alpha=alpha,
                                     seed=seed, passes=passes)
        n = len(x)
        rng = np.random.default_rng(seed)
        neighbors = [rng.choice(n, size=min(r, n - 1), replace=False)
                     for _ in range(n)]
        neighbors = [row[row != i][:r] for i, row in enumerate(neighbors)]
        adj = host._pad_adj([np.asarray(v, np.int32) for v in neighbors], r)
        med = medoid(x)
        bs = self.config.batch_size
        xj = jnp.asarray(x, jnp.float32)
        n2 = jnp.sum(xj * xj, axis=1)
        alphas = [1.0] * (passes - 1) + [alpha]
        for a in alphas:
            order = rng.permutation(n)
            for s in range(0, n, bs):
                nodes = order[s : s + bs]
                pool_ids, _ = frontier_pools(
                    x, adj, [med], nodes, ef=l_build,
                    max_hops=self.config.max_hops, batch=bs,
                    width=self.config.beam_width,
                    device_arrays=(xj, n2, jnp.asarray(adj, jnp.int32)),
                    backend=self.config.frontier_backend)
                cand = np.concatenate([pool_ids, adj[nodes]], axis=1)
                kept = self._prune(xj, nodes, cand, r=r, alpha=a)
                for bi, p in enumerate(nodes.tolist()):
                    row = kept[bi]
                    row = row[row >= 0]
                    adj[p] = -1
                    adj[p, : len(row)] = row
                # reverse edges; rows that overflow collect for a batched
                # re-prune instead of the host's per-insert prune
                pending: dict[int, list[int]] = {}
                for bi, p in enumerate(nodes.tolist()):
                    for v in kept[bi][kept[bi] >= 0].tolist():
                        row = adj[v]
                        if p in row[row >= 0] or p in pending.get(v, ()):
                            continue
                        slot = np.nonzero(row < 0)[0]
                        if len(slot):
                            adj[v, slot[0]] = p
                        else:
                            pending.setdefault(v, []).append(p)
                if pending:
                    vs = np.asarray(sorted(pending), np.int64)
                    # bucket the candidate width (power of two) so the jit
                    # cache sees a handful of shapes, not one per batch
                    need = max(len(v) for v in pending.values())
                    pad2 = 4
                    while pad2 < need:
                        pad2 *= 2
                    cand2 = -np.ones((len(vs), r + pad2), np.int32)
                    for i, v in enumerate(vs.tolist()):
                        merged = adj[v][adj[v] >= 0].tolist() + pending[v]
                        cand2[i, : len(merged)] = merged
                    kept2 = self._prune(xj, vs, cand2, r=r, alpha=a)
                    for i, v in enumerate(vs.tolist()):
                        row = kept2[i]
                        row = row[row >= 0]
                        adj[v] = -1
                        adj[v, : len(row)] = row
        return adj, med

    # -- NSG -----------------------------------------------------------------
    def build_nsg(self, x: np.ndarray, r: int = 32, l_build: int = 64,
                  knn_k: int = 32, seed: int = 0) -> tuple[np.ndarray, int]:
        if self.config.backend == "host":
            return host.build_nsg(x, r=r, l_build=l_build, knn_k=knn_k,
                                  seed=seed)
        n = len(x)
        if self.config.knn_mode == "clustered":
            knn = clustered_knn_graph(x, knn_k, seed=seed)
        else:
            knn = knn_graph(x, knn_k)
        med = medoid(x)
        xj = jnp.asarray(x, jnp.float32)
        n2 = jnp.sum(xj * xj, axis=1)
        pool_ids, _ = frontier_pools(
            x, knn, [med], np.arange(n), ef=l_build,
            max_hops=self.config.max_hops, batch=self.config.batch_size,
            width=self.config.beam_width,
            device_arrays=(xj, n2, jnp.asarray(knn, jnp.int32)),
            backend=self.config.frontier_backend)
        cand = np.concatenate([pool_ids, knn], axis=1)
        kept = self._prune(xj, np.arange(n), cand, r=r, alpha=1.0)
        adj = host._pad_adj([row[row >= 0] for row in kept], r)
        host.connect_to_entry(x, adj, med)
        return adj, med

    # -- BAMG ----------------------------------------------------------------
    def refine_bamg(self, x: np.ndarray, nsg_adj: np.ndarray, entry: int,
                    blocks: np.ndarray, capacity: int, alpha: int = 3,
                    beta: float = 1.0, occlusion_ref: str = "rule",
                    sibling_edges: bool = True,
                    max_degree: int | None = None) -> BAMGGraph:
        """Algorithm 2 given a prebuilt base graph + block assignment.

        The batched backend is bit-identical to the host given the same
        inputs (only the intra-block probes move to device)."""
        if self.config.backend == "host":
            return build_bamg_from(x, nsg_adj, entry, blocks, capacity,
                                   alpha=alpha, beta=beta,
                                   occlusion_ref=occlusion_ref,
                                   sibling_edges=sibling_edges,
                                   max_degree=max_degree)
        return refine_bamg_batched(x, nsg_adj, entry, blocks, capacity,
                                   alpha=alpha, beta=beta,
                                   occlusion_ref=occlusion_ref,
                                   sibling_edges=sibling_edges,
                                   max_degree=max_degree,
                                   pair_chunk=self.config.pair_chunk)

    def build_bamg(self, x: np.ndarray, capacity: int, alpha: int = 3,
                   beta: float = 1.0, r: int = 32, l_build: int = 64,
                   knn_k: int = 32, seed: int = 0,
                   occlusion_ref: str = "rule", sibling_edges: bool = True,
                   max_degree: int | None = None) -> BAMGGraph:
        """build_BAMG(X, alpha, beta) -- Algorithm 2 end to end."""
        nsg_adj, entry = self.build_nsg(x, r=r, l_build=l_build,
                                        knn_k=knn_k, seed=seed)
        blocks = bnf_blocks(nsg_adj, capacity, seed=seed)
        return self.refine_bamg(x, nsg_adj, entry, blocks, capacity,
                                alpha=alpha, beta=beta,
                                occlusion_ref=occlusion_ref,
                                sibling_edges=sibling_edges,
                                max_degree=max_degree)
