"""FreshService: the read-write serving facade over base + delta.

One object owns the whole freshness lifecycle:

    svc = FreshService(root)
    svc.bootstrap(x0)                  # gen-0 build, published + promoted
    eid = svc.insert(vec)              # lands in the delta overlay
    svc.delete(eid)                    # tombstone
    svc.search_batch(queries, k)       # base+delta unified, always correct
    svc.consolidate("gen-1")           # fold -> publish -> validate ->
                                       # promote -> hot swap -> fresh delta

External ids are stable for the lifetime of a point: the bootstrap corpus
gets `0..n0-1`, every insert gets the next integer, and consolidation --
which compacts the *internal* id space -- remaps the bookkeeping through
`old2new` so the same external id resolves to the same vector before and
after the swap.  Searches return external ids.

Consolidated builds flow through the exact blue/green lifecycle offline
builds use (`repro.serve.deploy`): publish writes a checksummed artifact,
`validate` smoke-tests recall against exact ground truth computed on the
*live* corpus (inserts present, deletes gone), promote atomically moves
the ACTIVE pointer, and `BlueGreenEngine.refresh()` swaps the serving
engine only after the new index is fully constructed -- reads before the
swap see base+delta, reads after see the consolidated index, and there is
no point in between where a delete resurfaces or an insert vanishes.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.distances import exact_knn
from repro.core.engine import BAMGIndex, BAMGParams
from repro.serve.ann_engine import EngineConfig
from repro.serve.deploy import BlueGreenEngine, DeploymentManager

from .consolidate import consolidate
from .engine import FreshBAMGEngine
from .layer import DeltaLayer, DeltaParams


class FreshService:
    """Insert/delete/search over a blue/green-deployed BAMG index."""

    def __init__(self, root: str,
                 params: Optional[BAMGParams] = None,
                 config: Optional[EngineConfig] = None,
                 delta_params: Optional[DeltaParams] = None):
        self.manager = DeploymentManager(root)
        self.params = params if params is not None else BAMGParams()
        self.config = config if config is not None else EngineConfig()
        self.delta_params = delta_params
        self.bg: Optional[BlueGreenEngine] = None
        self.delta: Optional[DeltaLayer] = None
        self.fresh: Optional[FreshBAMGEngine] = None
        self._ext_of_int = np.empty(0, np.int64)
        self._int_of_ext: dict[int, int] = {}
        self._next_ext = 0
        self.last_validation_recall: Optional[float] = None

    # --- lifecycle ----------------------------------------------------------
    def _wire(self) -> None:
        """(Re)attach the delta overlay + unified engine to the ACTIVE
        build; called at bootstrap and after every hot swap."""
        self.delta = DeltaLayer(self.bg.index, self.delta_params)
        self.fresh = FreshBAMGEngine(self.bg.index, self.delta,
                                     engine=self.bg.engine)

    def bootstrap(self, x0: Optional[np.ndarray] = None,
                  build_id: str = "gen-0", *,
                  index: Optional[BAMGIndex] = None) -> str:
        """Build + publish + promote generation 0; start an empty delta.

        Pass either the corpus `x0` (built here with `self.params`) or a
        pre-built `index` (reused as-is, e.g. a cached benchmark build)."""
        if self.bg is not None:
            raise RuntimeError("bootstrap: service already running")
        if (x0 is None) == (index is None):
            raise ValueError("bootstrap: pass exactly one of x0 / index")
        idx = (index if index is not None
               else BAMGIndex.build(np.asarray(x0, np.float32), self.params))
        self.manager.publish(idx, build_id, meta={"generation": 0})
        self.manager.promote(build_id)   # promote() verifies the checksum
        self.bg = BlueGreenEngine(self.manager, self.config, keep_index=True)
        n0 = len(idx.x)
        self._ext_of_int = np.arange(n0, dtype=np.int64)
        self._int_of_ext = {e: e for e in range(n0)}
        self._next_ext = n0
        self._wire()
        return build_id

    @property
    def n_live(self) -> int:
        return self.delta.n_total - len(self.delta.tombstones)

    def stats(self) -> dict:
        """Freshness health snapshot: overlay size vs the frozen base,
        plus whether the overlay-pressure guard has tripped (the operator
        signal that a `consolidate()` epoch is overdue)."""
        d = self.delta
        return {
            "generation": len(self.manager.history()) - 1,
            "n_base": d.n_base,
            "n_delta": d.n_delta,
            "n_tombstones": len(d.tombstones),
            "n_live": self.n_live,
            "overlay_fraction": d.overlay_fraction,
            "overlay_pressure": d.overlay_pressure,
            "warn_fraction": d.params.warn_fraction,
            "overlay_memory_bytes": d.memory_bytes(),
        }

    def live_corpus(self) -> tuple[np.ndarray, np.ndarray]:
        """(vectors, external ids) of every live point, internal order --
        the corpus an equivalent from-scratch build would be given."""
        n = self.delta.n_total
        ids = np.arange(n, dtype=np.int64)
        if self.delta.tombstones:
            dead = np.fromiter(self.delta.tombstones, np.int64,
                               len(self.delta.tombstones))
            ids = ids[~np.isin(ids, dead)]
        return self.delta.vectors(ids), self._ext_of_int[ids]

    # --- writes -------------------------------------------------------------
    def insert_batch(self, vecs: np.ndarray) -> np.ndarray:
        """Insert vectors; returns their (stable) external ids."""
        int_ids = self.delta.insert_batch(vecs)
        ext = np.arange(self._next_ext, self._next_ext + len(int_ids),
                        dtype=np.int64)
        self._next_ext += len(int_ids)
        self._ext_of_int = np.concatenate([self._ext_of_int, ext])
        for e, i in zip(ext.tolist(), int_ids.tolist()):
            self._int_of_ext[e] = i
        return ext

    def insert(self, vec: np.ndarray) -> int:
        return int(self.insert_batch(np.asarray(vec)[None, :])[0])

    def delete(self, ext_id: int) -> None:
        """Tombstone by external id; takes effect on the next search."""
        i = self._int_of_ext.get(int(ext_id))
        if i is None:
            raise KeyError(f"delete: unknown or already-deleted external id "
                           f"{ext_id}")
        self.delta.delete(i)
        del self._int_of_ext[int(ext_id)]

    # --- reads --------------------------------------------------------------
    def _to_ext(self, ids: np.ndarray) -> np.ndarray:
        return np.where(ids >= 0, self._ext_of_int[np.clip(ids, 0, None)], -1)

    def search(self, q: np.ndarray, k: int, l: int = 48):
        """Host-path unified search; returns (external ids, exact dists)."""
        ids, d = self.fresh.search(q, k, l=l)
        return self._to_ext(ids), d

    def search_batch(self, queries: np.ndarray, k: int, *,
                     l: Optional[int] = None,
                     max_hops: Optional[int] = None):
        """Batched-path unified search; returns (external ids, dists)."""
        ids, d = self.fresh.search_batch(queries, k, l=l, max_hops=max_hops)
        return self._to_ext(ids), d

    # --- consolidation ------------------------------------------------------
    def consolidate(self, build_id: str,
                    queries: Optional[np.ndarray] = None,
                    k: int = 10, min_recall: float = 0.8,
                    keep_builds: Optional[int] = None) -> str:
        """Fold the delta into a fresh build and swap it live.

        publish -> verify -> validate (recall against exact ground truth
        on the live corpus, when `queries` given) -> promote ->
        `refresh()` hot swap -> new empty delta.  A build that fails
        validation raises and changes nothing: ACTIVE keeps serving the
        old base and the delta overlay stays in place, so reads never
        regress.  `keep_builds` prunes old artifacts afterwards (the
        ACTIVE build and rollback target are always retained)."""
        gen = len(self.manager.history())
        idx, old2new = consolidate(self.bg.index, self.delta, self.params)
        self.manager.publish(idx, build_id,
                             meta={"generation": gen,
                                   "n_delta": int(self.delta.n_delta),
                                   "n_deleted": len(self.delta.tombstones)})
        self.manager.verify(build_id)
        if queries is not None:
            _, gt = exact_knn(idx.x, np.asarray(queries, np.float32), k)
            self.last_validation_recall = self.manager.validate(
                build_id, queries, gt, k=k,
                min_recall=min_recall, config=self.config)
        self.manager.promote(build_id)
        self.bg.refresh()
        # remap external-id bookkeeping onto the compacted id space
        live = np.nonzero(old2new >= 0)[0]
        self._ext_of_int = self._ext_of_int[live]
        self._int_of_ext = {int(e): i
                            for i, e in enumerate(self._ext_of_int.tolist())}
        self._wire()
        if keep_builds is not None:
            self.manager.prune(keep=keep_builds)
        return build_id
