"""h2o-danube-3-4b [arXiv:2401.16818]: llama+mistral-style dense decoder
with sliding-window attention.  24L d=3840 32H (GQA kv=8) ff=10240 v=32000.

SWA consequences here: the KV cache is a ring buffer of `sliding_window`
positions, so decode_32k / long_500k decode cost is O(window) -- this arch
runs long_500k with a 4096-entry cache.  kv_heads=8 < tp=16, so decode
uses the sequence-sharded cache mode (DESIGN.md §5).
"""
from repro.models.transformer import LMConfig

from .base import LM_SHAPES

ARCH_ID = "h2o-danube-3-4b"
FAMILY = "lm"
SHAPES = LM_SHAPES
TRAIN_ACCUM = 4  # microbatches for train_4k (memory lever)


def model_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(name=ARCH_ID + "-smoke", n_layers=2, d_model=128,
                        n_heads=8, n_kv_heads=2, d_head=16, d_ff=320,
                        vocab=512, sliding_window=64, remat="none",
                        loss_chunks=2, dtype="float32")
    return LMConfig(
        name=ARCH_ID, n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
        d_head=120, d_ff=10240, vocab=32000, sliding_window=4096,
        norm="rmsnorm", activation="silu", rope_theta=10000.0,
        remat="full", loss_chunks=64)
