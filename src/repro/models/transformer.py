"""Decoder-only LM: config, init, sharded forward/loss, prefill + decode.

Distribution (DESIGN.md §4):
  * params: Megatron tensor parallelism over the `model` axis (QKV/in-proj
    column-sharded, O/out-proj row-sharded, vocab sharded on embed + head);
    MoE experts sharded over `model` (see models/moe.py).
  * activations: batch over ("pod","data"), TP dims over "model",
    enforced with with_sharding_constraint.
  * embedding lookup: explicit Megatron vocab-parallel gather + psum under
    shard_map (GSPMD's default gather strategy may replicate a multi-GB
    embedding -- we do not let it).
  * layers run under lax.scan with configurable remat; the logits/loss is
    scanned over sequence chunks so the (B, S, V) tensor never materializes.
  * decode: KV cache either head-sharded (kv_heads % tp == 0, zero-comm) or
    sequence-sharded with the distributed flash-decode LSE merge
    (models/attention.py) -- required for danube (kv=8 < tp=16) and for
    long_500k where the cache must spread over every chip.
  * sliding-window models (danube) use a ring-buffer KV cache of size
    `window`: decode at 500k context touches 4096 positions, not 524288.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .attention import (causal_attention, decode_attention_local,
                        decode_attention_seqsharded, shard_lengths)
from .layers import (apply_norm, apply_rope, constrain, dense_init,
                     embed_init, gated_mlp, norm_param, softmax_xent_chunked)
from .moe import MoEConfig, moe_ffn
from repro.utils.sharding import bound_axis_size


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    norm: str = "rmsnorm"            # rmsnorm | rmsnorm_gemma | nonparam_ln
    activation: str = "silu"         # silu (SwiGLU) | gelu_tanh (GeGLU)
    sliding_window: Optional[int] = None
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: x *= sqrt(d_model)
    dtype: str = "bfloat16"
    remat: str = "full"              # none | full | dots
    loss_chunks: int = 8
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def n_params(self) -> int:
        """Total parameter count (dense equivalent; MoE counts all experts)."""
        d, v, l = self.d_model, self.vocab, self.n_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.moe:
            m = self.moe
            ffn = (d * m.n_experts  # router
                   + m.n_experts * 3 * d * m.d_ff_expert
                   + (3 * d * m.d_ff_shared if m.n_shared else 0))
        else:
            ffn = 3 * d * self.d_ff
        emb = v * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ffn) + emb

    def n_active_params(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if not self.moe:
            return self.n_params()
        d, v, l, m = self.d_model, self.vocab, self.n_layers, self.moe
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ffn = (d * m.n_experts + m.top_k * 3 * d * m.d_ff_expert
               + (3 * d * m.d_ff_shared if m.n_shared else 0))
        emb = v * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ffn) + emb


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh context handed to model code; None mesh = unsharded smoke path."""
    mesh: Optional[Mesh] = None
    model_axis: Optional[str] = "model"

    @property
    def batch_axes(self) -> tuple:
        if self.mesh is None:
            return ()
        names = self.mesh.axis_names
        return tuple(a for a in ("pod", "data") if a in names)

    @property
    def tp(self) -> int:
        if self.mesh is None or self.model_axis not in self.mesh.axis_names:
            return 1
        return self.mesh.devices.shape[self.mesh.axis_names.index(self.model_axis)]

    def spec(self, *dims) -> Optional[P]:
        if self.mesh is None:
            return None
        return P(*dims)

    def batch_spec(self, *rest) -> Optional[P]:
        if self.mesh is None:
            return None
        ba = self.batch_axes
        return P(ba if ba else None, *rest)

    def axis_prod(self, axes) -> int:
        if axes is None:
            return 1
        axes = (axes,) if isinstance(axes, str) else axes
        n = 1
        for a in axes:
            if a in self.mesh.axis_names:
                n *= self.mesh.devices.shape[self.mesh.axis_names.index(a)]
        return n

    def sanitize(self, spec: Optional[P], shape) -> Optional[P]:
        """Drop sharding on any dim whose size is not divisible by its mesh
        axes (batch=1 serving cells, tiny decode token counts, ...)."""
        if self.mesh is None or spec is None:
            return spec
        dims = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for d, s in zip(dims, shape):
            out.append(d if d is None or (s >= self.axis_prod(d)
                                          and s % self.axis_prod(d) == 0)
                       else None)
        return P(*out)

    def constrain(self, x, spec: Optional[P]):
        """with_sharding_constraint with an explicit NamedSharding (works
        without any ambient mesh context; no-op when unsharded).  Specs are
        sanitized against the array shape."""
        if self.mesh is None or spec is None:
            return x
        from jax.sharding import NamedSharding
        spec = self.sanitize(spec, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


# ---------------------------------------------------------------------------
# Init + param specs
# ---------------------------------------------------------------------------
def init_lm_params(cfg: LMConfig, key: jax.Array) -> dict:
    keys = jax.random.split(key, 16)
    d, l = cfg.d_model, cfg.n_layers

    def stack(fn, key, *shape_args):
        ks = jax.random.split(key, l)
        return jnp.stack([fn(ks[i], *shape_args) for i in range(l)])

    layers: dict[str, Any] = {
        "attn_norm": _stack_norm(cfg, l),
        "mlp_norm": _stack_norm(cfg, l),
        "wq": stack(dense_init, keys[0], d, cfg.q_dim),
        "wk": stack(dense_init, keys[1], d, cfg.kv_dim),
        "wv": stack(dense_init, keys[2], d, cfg.kv_dim),
        "wo": stack(dense_init, keys[3], cfg.q_dim, d),
    }
    if cfg.moe:
        m = cfg.moe
        e = m.n_experts_padded
        def estack(key, d_in, d_out):
            ks = jax.random.split(key, l)
            return jnp.stack([
                jnp.stack([dense_init(k2, d_in, d_out)
                           for k2 in jax.random.split(ks[i], e)])
                for i in range(l)])
        layers["router"] = stack(dense_init, keys[4], d, m.n_experts)
        layers["we_gate"] = estack(keys[5], d, m.d_ff_expert)
        layers["we_in"] = estack(keys[6], d, m.d_ff_expert)
        layers["we_out"] = estack(keys[7], m.d_ff_expert, d)
        if m.n_shared:
            layers["ws_gate"] = stack(dense_init, keys[8], d, m.d_ff_shared)
            layers["ws_in"] = stack(dense_init, keys[9], d, m.d_ff_shared)
            layers["ws_out"] = stack(dense_init, keys[10], m.d_ff_shared, d)
    else:
        layers["w_gate"] = stack(dense_init, keys[5], d, cfg.d_ff)
        layers["w_in"] = stack(dense_init, keys[6], d, cfg.d_ff)
        layers["w_out"] = stack(dense_init, keys[7], cfg.d_ff, d)

    params = {
        "embed": embed_init(keys[11], cfg.vocab, d),
        "final_norm": norm_param(cfg.norm, d),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[12], d, cfg.vocab)
    return params


def _stack_norm(cfg: LMConfig, l: int):
    p = norm_param(cfg.norm, cfg.d_model)
    return None if p is None else jnp.stack([p] * l)


def lm_param_specs(cfg: LMConfig, ctx: ShardCtx,
                   fsdp_axis: Optional[str] = None) -> dict:
    """PartitionSpec tree matching init_lm_params output.

    fsdp_axis (training): additionally shard every weight over that axis on
    its first free divisible dim -- 2D (FSDP x TP) parameter layout.  GSPMD
    then all-gathers each layer's slice inside the scan (forward) and
    reduce-scatters its gradient (backward), and the AdamW state inherits
    the fully-sharded layout (ZeRO-3-style memory: params+moments / N_mesh).
    """
    if ctx.mesh is None:
        return jax.tree.map(lambda _: None, jax.eval_shape(
            lambda: init_lm_params(cfg, jax.random.PRNGKey(0))))
    mdl = ctx.model_axis
    layers: dict[str, Any] = {
        "attn_norm": None if cfg.norm == "nonparam_ln" else P(None, None),
        "mlp_norm": None if cfg.norm == "nonparam_ln" else P(None, None),
        "wq": P(None, None, mdl),
        "wk": P(None, None, mdl),
        "wv": P(None, None, mdl),
        "wo": P(None, mdl, None),
    }
    if cfg.moe:
        layers["router"] = P(None, None, None)
        layers["we_gate"] = P(None, mdl, None, None)
        layers["we_in"] = P(None, mdl, None, None)
        layers["we_out"] = P(None, mdl, None, None)
        if cfg.moe.n_shared:
            layers["ws_gate"] = P(None, None, mdl)
            layers["ws_in"] = P(None, None, mdl)
            layers["ws_out"] = P(None, mdl, None)
    else:
        layers["w_gate"] = P(None, None, mdl)
        layers["w_in"] = P(None, None, mdl)
        layers["w_out"] = P(None, mdl, None)
    specs = {
        "embed": P(mdl, None),
        "final_norm": None if cfg.norm == "nonparam_ln" else P(None),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, mdl)
    if fsdp_axis is not None:
        shapes = jax.eval_shape(
            lambda: init_lm_params(cfg, jax.random.PRNGKey(0)))
        ax_size = ctx.axis_prod(fsdp_axis)

        def add_fsdp(spec, shaped):
            if spec is None or shaped.ndim < 2:
                return spec
            dims = list(spec) + [None] * (shaped.ndim - len(spec))
            for i, d in enumerate(dims):
                if d is None and shaped.shape[i] % ax_size == 0 \
                        and shaped.shape[i] >= ax_size:
                    dims[i] = fsdp_axis
                    return P(*dims)
            return spec

        specs = jax.tree.map(add_fsdp, specs, shapes,
                             is_leaf=lambda x: x is None or isinstance(x, P))
    return specs


# ---------------------------------------------------------------------------
# Embedding (vocab-parallel)
# ---------------------------------------------------------------------------
def embed_lookup(embed: jnp.ndarray, tokens: jnp.ndarray, cfg: LMConfig,
                 ctx: ShardCtx) -> jnp.ndarray:
    """(V, d) x (B, S) -> (B, S, d); Megatron vocab-parallel under shard_map."""
    if ctx.mesh is None or ctx.tp == 1:
        out = embed[tokens]
    else:
        from jax.experimental.shard_map import shard_map
        mdl = ctx.model_axis
        v_local = cfg.vocab // ctx.tp

        def body(emb_l, tok):
            off = jax.lax.axis_index(mdl) * v_local
            loc = tok.astype(jnp.int32) - off
            ok = (loc >= 0) & (loc < v_local)
            rows = emb_l[jnp.clip(loc, 0, v_local - 1)]
            rows = jnp.where(ok[..., None], rows, 0.0)
            return jax.lax.psum(rows, mdl)

        tok_spec = ctx.sanitize(ctx.batch_spec(None), tokens.shape)
        out_spec = P(*(list(tok_spec) + [None]))
        out = shard_map(body, mesh=ctx.mesh,
                        in_specs=(P(mdl, None), tok_spec),
                        out_specs=out_spec,
                        check_rep=False)(embed, tokens)
    out = out.astype(cfg.compute_dtype)
    if cfg.embed_scale:
        out = out * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    return out


# ---------------------------------------------------------------------------
# One transformer layer (shared by train / prefill / decode)
# ---------------------------------------------------------------------------
def _attn_qkv(x, lp, cfg: LMConfig, ctx: ShardCtx, positions):
    b, s, _ = x.shape
    h = apply_norm(cfg.norm, x, lp["attn_norm"])
    q = ctx.constrain(h @ lp["wq"].astype(h.dtype), ctx.batch_spec(None, ctx.model_axis))
    k = ctx.constrain(h @ lp["wk"].astype(h.dtype), ctx.batch_spec(None, ctx.model_axis))
    v = ctx.constrain(h @ lp["wv"].astype(h.dtype), ctx.batch_spec(None, ctx.model_axis))
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(x, lp, cfg: LMConfig, ctx: ShardCtx):
    h = apply_norm(cfg.norm, x, lp["mlp_norm"])
    if cfg.moe:
        out, aux = moe_ffn(h, lp, cfg.moe, mesh=ctx.mesh,
                           batch_axes=ctx.batch_axes or None,
                           model_axis=ctx.model_axis if ctx.tp > 1 else None,
                           activation=cfg.activation)
        return out, aux
    hidden_spec = ctx.batch_spec(None, ctx.model_axis)
    g = ctx.constrain(h @ lp["w_gate"].astype(h.dtype), hidden_spec)
    i = ctx.constrain(h @ lp["w_in"].astype(h.dtype), hidden_spec)
    from .layers import act_fn
    out = (act_fn(cfg.activation)(g) * i) @ lp["w_out"].astype(h.dtype)
    return out, jnp.float32(0.0)


def layer_forward(x, lp, cfg: LMConfig, ctx: ShardCtx, positions):
    """Full-sequence layer (train / prefill). Returns (x, aux, (k, v))."""
    q, k, v = _attn_qkv(x, lp, cfg, ctx, positions)
    att = causal_attention(q, k, v, q_offset=0, window=cfg.sliding_window,
                           chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
    b, s, _, _ = att.shape
    att = att.reshape(b, s, cfg.q_dim)
    x = x + ctx.constrain(att @ lp["wo"].astype(att.dtype),
                          ctx.batch_spec(None, None))
    ffn_out, aux = _ffn(x, lp, cfg, ctx)
    x = x + ffn_out
    x = ctx.constrain(x, ctx.batch_spec(None, None))
    return x, aux, (k, v)


def _remat_wrap(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Forward / loss (training)
# ---------------------------------------------------------------------------
def compute_cast(tree, dtype):
    """Cast float params to the compute dtype *before* the layer scan: the
    FSDP all-gathers that XLA hoists out of the loop then move bf16, not
    f32 (measured 12.4 -> 3.1 GiB on moonshot train), and it is standard
    mixed precision (f32 master weights live only in the optimizer)."""
    return jax.tree.map(
        lambda a: a.astype(dtype)
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating)
        else a, tree)


def _layer_scan(params_layers, x, cfg: LMConfig, step_fn):
    """Scan over layers with the configured remat strategy.

    remat="2level": sqrt-remat -- layers regrouped (outer, inner); only the
    outer carries are saved (outer count ~ sqrt(L)), the inner scan is
    recomputed inside each outer backward step.  Cuts the saved-activation
    stack from L to outer+inner carries.
    """
    if cfg.remat == "2level":
        l = cfg.n_layers
        outer = max(f for f in range(1, int(l ** 0.5) + 1) if l % f == 0)
        inner = l // outer
        grouped = jax.tree.map(
            lambda a: a.reshape((outer, inner) + a.shape[1:]), params_layers)

        def outer_body(carry, lp_group):
            def inner_body(c, lp):
                return step_fn(c, lp), None
            c, _ = jax.lax.scan(inner_body, carry, lp_group)
            return c, None

        return jax.lax.scan(jax.checkpoint(outer_body), x, grouped)[0]
    body = _remat_wrap(lambda c, lp: (step_fn(c, lp), None), cfg.remat)
    return jax.lax.scan(body, x, params_layers)[0]


def forward_hidden(params, cfg: LMConfig, tokens, ctx: ShardCtx):
    """tokens (B, S) -> final hidden (B, S, d) + summed moe aux loss."""
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, cfg, ctx)
    x = ctx.constrain(x, ctx.batch_spec(None, None))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def step(carry, lp):
        x, aux = carry
        x, a, _ = layer_forward(x, lp, cfg, ctx, positions)
        return (x, aux + a)

    layers_c = compute_cast(params["layers"], cfg.compute_dtype)
    x, aux = _layer_scan(layers_c, (x, jnp.float32(0.0)), cfg, step)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    return x, aux


def lm_head_logits(params, cfg: LMConfig, x, ctx: ShardCtx):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ w.astype(x.dtype)
    return ctx.constrain(logits, ctx.batch_spec(None, ctx.model_axis))


def lm_loss(params, cfg: LMConfig, tokens, labels, ctx: ShardCtx):
    """Mean next-token cross entropy (+ MoE aux). tokens/labels (B, S)."""
    x, aux = forward_hidden(params, cfg, tokens, ctx)
    ce = softmax_xent_chunked(
        lambda xc: lm_head_logits(params, cfg, xc, ctx),
        x, labels, n_chunks=min(cfg.loss_chunks, x.shape[1]))
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------
def cache_len_for(cfg: LMConfig, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: LMConfig, batch: int, seq_len: int, dtype=None):
    """(k, v) caches (L, B, S_c, Hkv, Dh) + lengths (B,)."""
    sc = cache_len_for(cfg, seq_len)
    dtype = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch, sc, cfg.n_kv_heads, cfg.d_head)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
            jnp.zeros((batch,), jnp.int32))


def cache_specs(cfg: LMConfig, ctx: ShardCtx, mode: str):
    """PartitionSpecs for (cache_k, cache_v, lengths).

    mode: "head" -- kv heads over model (requires divisibility);
          "seq"  -- cache sequence over model;
          "seq_all" -- cache sequence over every mesh axis (batch=1 cells).
    """
    if ctx.mesh is None:
        return None, None, None
    ba = ctx.batch_axes
    mdl = ctx.model_axis
    if mode == "head":
        spec = P(None, ba, None, mdl, None)
    elif mode == "seq":
        spec = P(None, ba, mdl, None, None)
    elif mode == "seq_all":
        spec = P(None, None, tuple(list(ba) + [mdl]), None, None)
    else:
        raise ValueError(mode)
    len_spec = P(ba) if mode != "seq_all" else P(None)
    return spec, spec, len_spec


def serve_prefill(params, cfg: LMConfig, tokens, ctx: ShardCtx):
    """Prefill: (B, S) -> (last-token logits (B, V), caches, lengths)."""
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, cfg, ctx)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    sc = cache_len_for(cfg, s)

    def body(carry, lp):
        x, aux = carry
        x, a, (k, v) = layer_forward(x, lp, cfg, ctx, positions)
        if sc < s:
            # sliding window: keep the trailing window, laid out in *ring*
            # order (slot = position % sc) so decode_step's ring writes
            # land consistently after wraparound
            k, v = k[:, s - sc:], v[:, s - sc:]
            off = (s - sc) % sc
            if off:
                k = jnp.roll(k, off, axis=1)
                v = jnp.roll(v, off, axis=1)
        return (x, aux + a), (k, v)

    body = _remat_wrap(body, cfg.remat if cfg.remat != "2level" else "full")
    (x, _), (ck, cv) = jax.lax.scan(
        body, (x, jnp.float32(0.0)),
        compute_cast(params["layers"], cfg.compute_dtype))
    x = apply_norm(cfg.norm, x, params["final_norm"])
    logits = lm_head_logits(params, cfg, x[:, -1:], ctx)[:, 0]
    lengths = jnp.full((b,), sc, jnp.int32)
    return logits, (ck, cv), lengths


def _write_cache_local(ck, cv, k_new, v_new, write_pos):
    """Per-batch dynamic row write. ck (B, S, Hkv, Dh), write_pos (B,)."""
    def upd(c, n, p):
        return jax.lax.dynamic_update_slice(c, n, (p, 0, 0))
    ck = jax.vmap(upd)(ck, k_new, write_pos)
    cv = jax.vmap(upd)(cv, v_new, write_pos)
    return ck, cv


def decode_step(params, cfg: LMConfig, tokens, positions, caches,
                ctx: ShardCtx, kv_mode: str = "head"):
    """One decode step.

    tokens (B, 1) int32; positions (B,) absolute positions of the new token;
    caches = (ck, cv, lengths) with ck/cv (L, B, Sc, Hkv, Dh).
    Returns (logits (B, V), new caches).
    """
    ck_all, cv_all, lengths = caches
    b = tokens.shape[0]
    sc = ck_all.shape[2]
    x = embed_lookup(params["embed"], tokens, cfg, ctx)
    pos2d = positions[:, None]
    write_pos = (positions % sc).astype(jnp.int32)  # ring buffer under SWA
    new_len = jnp.minimum(positions + 1, sc).astype(jnp.int32)

    layers_c = compute_cast(params["layers"], cfg.compute_dtype)

    def body(carry, li):
        # caches ride in the scan *carry* with per-layer dynamic-slice
        # updates: XLA keeps the multi-GiB cache stacks in place instead of
        # double-buffering them through scan xs->ys
        x, ck_all, cv_all = carry
        lp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, li, 0, keepdims=False),
            layers_c)
        ck = jax.lax.dynamic_index_in_dim(ck_all, li, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, li, 0, keepdims=False)
        q, k, v = _attn_qkv(x, lp, cfg, ctx, pos2d)
        q1 = q[:, 0]                                  # (B, H, Dh)
        if ctx.mesh is None or kv_mode == "local":
            ck, cv = _write_cache_local(ck, cv, k, v, write_pos)
            att = decode_attention_local(q1, ck, cv, new_len, backend="ref")
        elif kv_mode == "head":
            ck, cv = _write_cache_local(ck, cv, k, v, write_pos)
            att = decode_attention_local(q1, ck, cv, new_len, backend="auto")
        else:
            seq_axes = (tuple(list(ctx.batch_axes) + [ctx.model_axis])
                        if kv_mode == "seq_all" else (ctx.model_axis,))
            ck, cv, att = _decode_seqsharded(
                q1, k, v, ck, cv, write_pos, new_len, ctx, kv_mode, seq_axes)
        ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, li, 0)
        cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, li, 0)
        att = att.astype(x.dtype).reshape(b, 1, cfg.q_dim)
        x = x + ctx.constrain(att @ lp["wo"].astype(att.dtype),
                              ctx.batch_spec(None, None))
        ffn_out, _ = _ffn(x, lp, cfg, ctx)
        return (x + ffn_out, ck_all, cv_all), None

    (x, ck_new, cv_new), _ = jax.lax.scan(
        body, (x, ck_all, cv_all),
        jnp.arange(cfg.n_layers, dtype=jnp.int32))
    x = apply_norm(cfg.norm, x, params["final_norm"])
    logits = lm_head_logits(params, cfg, x, ctx)[:, 0]
    return logits, (ck_new, cv_new, new_len)


def _decode_seqsharded(q1, k_new, v_new, ck, cv, write_pos, new_len, ctx,
                       kv_mode, seq_axes):
    """Sequence-sharded cache write + distributed flash-decode merge."""
    from jax.experimental.shard_map import shard_map
    mesh = ctx.mesh
    ba = ctx.batch_axes
    cache_spec = (P(ba, ctx.model_axis, None, None) if kv_mode == "seq"
                  else P(None, seq_axes, None, None))
    b_spec = P(ba) if kv_mode == "seq" else P(None)
    q_spec = (P(ba, None, None) if kv_mode == "seq" else P(None, None, None))
    kv_new_spec = (P(ba, None, None, None) if kv_mode == "seq"
                   else P(None, None, None, None))

    def body(q_l, kn, vn, ck_l, cv_l, wp, nl):
        s_l = ck_l.shape[1]
        idx = jnp.int32(0)
        for ax in seq_axes:
            idx = idx * bound_axis_size(ax) + jax.lax.axis_index(ax)
        start = idx * s_l
        loc = jnp.clip(wp - start, 0, s_l - 1)
        mine = (wp >= start) & (wp < start + s_l)

        def upd(c, n, p, m):
            cur = jax.lax.dynamic_slice(c, (p, 0, 0), (1,) + c.shape[1:])
            row = jnp.where(m, n, cur)
            return jax.lax.dynamic_update_slice(c, row, (p, 0, 0))

        ck_l = jax.vmap(upd)(ck_l, kn, loc, mine)
        cv_l = jax.vmap(upd)(cv_l, vn, loc, mine)
        local_len = shard_lengths(nl, idx, s_l)
        att = decode_attention_seqsharded(q_l, ck_l, cv_l, local_len,
                                          seq_axes)
        return ck_l, cv_l, att

    return shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, kv_new_spec, kv_new_spec, cache_spec, cache_spec,
                  b_spec, b_spec),
        out_specs=(cache_spec, cache_spec, q_spec),
        check_rep=False,
    )(q1, k_new, v_new, ck, cv, write_pos, new_len)
