"""Public index API: build / save / load / search for the three compared
systems (DiskANN, Starling-style, BAMG), all on the same I/O simulator.

    idx = BAMGIndex.build(x, BAMGParams(alpha=3, beta=1.05))
    res = idx.search(q, k=10, l=64)          # one query
    out = idx.search_batch(queries, k=10, l=64)  # stats aggregated

This is the host (exact-semantics) engine: one Python query at a time, every
block fetch routed through the I/O simulator so NIO/recall match the paper's
accounting.

I/O knobs (all three systems; see `repro.core.io_sim` for the two metric
domains):

* ``cache_policy`` ('lru' | 'fifo' | 'clock' | '2q') and ``cache_blocks``
  select the block-cache replacement policy and capacity; BAMG additionally
  has ``vec_cache_blocks`` for the decoupled vector region and
  ``pin_nav_blocks`` -- a budget of hot navigation-entry graph blocks pinned
  in memory forever (Starling-style; pins count against ``cache_blocks``).
* ``qd`` is the io_uring-style queue depth of the pipelined `IOScheduler`;
  ``batch_io=True`` makes search issue batched submissions (per-hop frontier
  prefetch + one-shot re-rank reads).  Accounting (NIO, recall, cache hits)
  is bit-identical to the serial path -- only `BatchStats.mean_service_us`
  (pipelined) vs `mean_serial_us` (sequential) and the derived
  `qps_pipelined` change.
* ``search_batch(..., warm_cache=True)`` keeps the block cache warm across
  the queries of a batch (cross-query serving mode); the default cold cache
  per query matches the paper's NIO accounting.

The TPU-native batched engine lives in
`repro.serve.ann_engine.BatchedANNEngine` -- it consumes the fixed-shape
arrays exported by `BAMGIndex.batch_arrays()` and processes a whole query
batch per jitted step (no I/O simulation; pure device compute).  The
scatter-gather front-end over sharded sub-indexes is
`repro.serve.frontend.ShardedFrontend`.  Search-path knobs (`l`, `max_hops`)
mean the same thing in both engines.
"""
from __future__ import annotations

import dataclasses
import io
from typing import Optional, Sequence

import numpy as np

from repro.build import BuildConfig, GraphBuilder
from repro.utils.faults import FaultPlan, FaultSpec, RetryPolicy

from .bamg import BAMGGraph
from .block_assign import bnf_blocks, block_members
from .distances import recall_at_k
from .graph_build import build_vamana, degree_stats
from .io_sim import BLOCK_SIZE, CostModel
from .navgraph import (NavGraph, build_navgraph, nav_pin_gblocks, search_nav)
from .pq import PQCodec, train_pq
from .search import SearchResult, search_bamg, search_coupled
from .storage import (CoupledStorage, DecoupledStorage, coupled_nodes_per_block,
                      max_capacity_for)


def _batch(search_one, queries, gt, k: int, cost: CostModel,
           warm_cache: bool) -> BatchStats:
    """Shared batch loop: `search_one(i, q, drop_cache)` per query; a warm
    cache drops only before the first query (cross-query serving mode)."""
    res = [search_one(i, q, (not warm_cache) or i == 0)
           for i, q in enumerate(queries)]
    return _aggregate(res, gt, k, cost)


# configure_io sentinel: None is a meaningful value for the fault/deadline
# knobs (it *disables* them), so "leave unchanged" needs its own marker
_KEEP = object()


def _update_io_params(p, updates: dict, keep_updates: dict | None = None) -> None:
    """None-means-unchanged in-place update of an index's params; entries in
    `keep_updates` use the _KEEP sentinel instead (None is meaningful)."""
    for name, val in updates.items():
        if val is not None:
            setattr(p, name, val)
    for name, val in (keep_updates or {}).items():
        if val is not _KEEP:
            setattr(p, name, val)


def _fault_plan(p) -> Optional[FaultPlan]:
    """The index's seeded fault plan (None when fault injection is off)."""
    return FaultPlan(p.faults, seed=p.fault_seed) if p.faults is not None else None


def _cost_for(p) -> CostModel:
    return CostModel(qd=p.qd, timeout_us=p.timeout_us, hedge_us=p.hedge_us)


def _configure_coupled_io(idx, cache_policy, cache_blocks, qd, batch_io,
                          faults=_KEEP, fault_seed=None, retry=_KEEP,
                          timeout_us=_KEEP, hedge_us=_KEEP):
    """Rebuild only the coupled storage/scheduler with new I/O knobs (the
    graph, PQ codes, and layout are untouched) -- cheap sweeps."""
    _update_io_params(idx.params, dict(
        cache_policy=cache_policy, cache_blocks=cache_blocks, qd=qd,
        batch_io=batch_io, fault_seed=fault_seed),
        dict(faults=faults, retry=retry, timeout_us=timeout_us,
             hedge_us=hedge_us))
    p = idx.params
    idx.store = CoupledStorage(idx.x, idx.adj, order=idx.store.layout,
                               policy=p.cache_policy,
                               cache_blocks=p.cache_blocks,
                               cost=_cost_for(p), faults=_fault_plan(p),
                               retry=p.retry)
    idx.cost = idx.store.scheduler.cost
    return idx


def _builder_for(params) -> GraphBuilder:
    """GraphBuilder from an index params dataclass (`build_backend`:
    "host" keeps the numpy reference pipeline, "batched" routes the
    expensive stages through `repro.build`'s jit'd fixed-shape programs)."""
    knn = getattr(params, "build_knn", "clustered")  # BAMG-only knob:
    # Vamana (DiskANN/Starling) has no kNN stage, so only BAMGParams
    # carries it
    return GraphBuilder(BuildConfig(backend=params.build_backend,
                                    batch_size=params.build_batch,
                                    knn_mode=knn))


def _pick_pq_m(d: int, target: int | None = None) -> int:
    """Largest M <= target dividing d (PQ subspace count).

    Default target scales with dimension (~d/16, clamped to [16, 64]) --
    high-d corpora need more subspaces or ADC noise swamps the distance
    ordering (faiss uses the same ballpark)."""
    if target is None:
        target = min(64, max(16, d // 16))
    for m in range(min(target, d), 0, -1):
        if d % m == 0:
            return m
    return 1


@dataclasses.dataclass
class BatchStats:
    recall: float
    mean_nio: float
    mean_graph_reads: float
    mean_vector_reads: float
    mean_hops: float
    mean_n_dist: float
    mean_n_pq: float
    qps: float
    mean_service_us: float = 0.0   # pipelined I/O wall-clock (qd-overlapped)
    mean_serial_us: float = 0.0    # same demand misses, strictly serial
    cache_hit_rate: float = 0.0    # hits / (hits + NIO) over the batch
    qps_pipelined: float = 0.0     # QPS with the pipelined service time
    # resilience (fault injection; all zero on a clean run)
    degraded_fraction: float = 0.0  # queries that lost >=1 block to faults
    mean_failed_reads: float = 0.0  # undeliverable blocks skipped per query
    mean_retries: float = 0.0       # extra read attempts per query
    mean_hedges: float = 0.0        # hedged duplicate reads per query
    p99_service_us: float = 0.0     # tail of the pipelined service time


def _aggregate(results: list[SearchResult], gt: Optional[np.ndarray], k: int,
               cost: CostModel) -> BatchStats:
    nio = float(np.mean([r.nio for r in results]))
    nd = float(np.mean([r.n_dist for r in results]))
    npq = float(np.mean([r.n_pq for r in results]))
    rec = -1.0
    if gt is not None:
        idm = np.full((len(results), k), -1, np.int64)   # short results pad
        for i, r in enumerate(results):
            m = min(k, len(r.ids))
            idm[i, :m] = r.ids[:m]
        rec = recall_at_k(idm, gt, k)
    service_all = np.asarray([r.service_us for r in results], np.float64)
    service = float(service_all.mean())
    hits = float(np.sum([r.cache_hits for r in results]))
    total_nio = float(np.sum([r.nio for r in results]))
    return BatchStats(
        recall=rec, mean_nio=nio,
        mean_graph_reads=float(np.mean([r.graph_reads for r in results])),
        mean_vector_reads=float(np.mean([r.vector_reads for r in results])),
        mean_hops=float(np.mean([r.hops for r in results])),
        mean_n_dist=nd, mean_n_pq=npq, qps=cost.qps(nio, nd, npq),
        mean_service_us=service,
        mean_serial_us=float(np.mean([r.serial_us for r in results])),
        cache_hit_rate=hits / (hits + total_nio) if hits + total_nio else 0.0,
        qps_pipelined=cost.qps_from_io_us(service, nd, npq),
        degraded_fraction=float(np.mean([r.degraded for r in results])),
        mean_failed_reads=float(np.mean([r.failed_reads for r in results])),
        mean_retries=float(np.mean([r.retries for r in results])),
        mean_hedges=float(np.mean([r.hedges for r in results])),
        p99_service_us=float(np.percentile(service_all, 99)))


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DiskANNParams:
    r: int = 32
    l_build: int = 64
    alpha: float = 1.2
    pq_m: Optional[int] = None
    cache_policy: str = "lru"        # block-cache replacement policy
    cache_blocks: int = 256          # block-cache capacity
    qd: int = 1                      # I/O queue depth (pipelined scheduler)
    batch_io: bool = False           # batched submissions + prefetch
    build_backend: str = "host"      # graph construction: "host" | "batched"
    build_batch: int = 256           # nodes per batched-build step
    faults: Optional[FaultSpec] = None   # fault injection (None = clean disk)
    fault_seed: int = 0              # seed of the deterministic fault plan
    retry: Optional[RetryPolicy] = None  # bounded-retry policy (None = default)
    timeout_us: Optional[float] = None   # abandon an attempt past this
    hedge_us: Optional[float] = None     # duplicate-read hedge age
    seed: int = 0


class DiskANNIndex:
    """Vamana graph + coupled layout in graph order + Alg. 1 search."""

    kind = "diskann"

    def __init__(self, x, adj, entry, codec, codes, store, params=None):
        self.x, self.adj, self.entry = x, adj, entry
        self.codec, self.codes, self.store = codec, codes, store
        self.params = params if params is not None else DiskANNParams()
        self.cost = store.scheduler.cost

    @classmethod
    def build(cls, x: np.ndarray, params: DiskANNParams = DiskANNParams()):
        params = dataclasses.replace(params)   # configure_io mutates in place
        adj, entry = _builder_for(params).build_vamana(
            x, r=params.r, l_build=params.l_build, alpha=params.alpha,
            seed=params.seed)
        m = params.pq_m or _pick_pq_m(x.shape[1])
        codec = train_pq(x, m=m, seed=params.seed)
        codes = codec.encode(x)
        store = CoupledStorage(x, adj, policy=params.cache_policy,
                               cache_blocks=params.cache_blocks,
                               cost=_cost_for(params),
                               faults=_fault_plan(params), retry=params.retry)
        return cls(x, adj, entry, codec, codes, store, params)

    def configure_io(self, cache_policy: Optional[str] = None,
                     cache_blocks: Optional[int] = None,
                     qd: Optional[int] = None,
                     batch_io: Optional[bool] = None,
                     faults=_KEEP, fault_seed: Optional[int] = None,
                     retry=_KEEP, timeout_us=_KEEP,
                     hedge_us=_KEEP) -> "DiskANNIndex":
        """Rebuild only the storage/scheduler with new I/O knobs."""
        return _configure_coupled_io(self, cache_policy, cache_blocks, qd,
                                     batch_io, faults=faults,
                                     fault_seed=fault_seed, retry=retry,
                                     timeout_us=timeout_us, hedge_us=hedge_us)

    def search(self, q: np.ndarray, k: int, l: int,
               drop_cache: bool = True,
               exclude: Optional[set] = None) -> SearchResult:
        table = self.codec.adc_table(q)
        bs = max(2, self.params.qd) if self.params.batch_io else None
        return search_coupled(self.store, self.codes, table, q, self.entry,
                              k, l, block_level=False, batch_submit=bs,
                              drop_cache=drop_cache, exclude=exclude)

    def search_batch(self, queries: np.ndarray, k: int, l: int,
                     gt: Optional[np.ndarray] = None,
                     warm_cache: bool = False,
                     exclude: Optional[set] = None) -> BatchStats:
        return _batch(lambda i, q, dc: self.search(q, k, l, drop_cache=dc,
                                                   exclude=exclude),
                      queries, gt, k, self.cost, warm_cache)

    def degree_stats(self):
        blocks = (self.store.pos // self.store.npb).astype(np.int64)
        return degree_stats(self.adj, blocks)

    def index_bytes(self) -> int:
        return self.store.device.total_bytes

    def memory_bytes(self) -> int:
        return self.codes.nbytes + self.codec.codebooks.nbytes


@dataclasses.dataclass
class StarlingParams:
    r: int = 32
    l_build: int = 64
    alpha: float = 1.2
    pq_m: Optional[int] = None
    nav_sample: float = 0.05     # random in-memory nav sample fraction
    cache_policy: str = "lru"
    cache_blocks: int = 256
    qd: int = 1
    batch_io: bool = False
    build_backend: str = "host"  # graph construction: "host" | "batched"
    build_batch: int = 256       # nodes per batched-build step
    faults: Optional[FaultSpec] = None   # fault injection (None = clean disk)
    fault_seed: int = 0              # seed of the deterministic fault plan
    retry: Optional[RetryPolicy] = None  # bounded-retry policy (None = default)
    timeout_us: Optional[float] = None   # abandon an attempt past this
    hedge_us: Optional[float] = None     # duplicate-read hedge age
    seed: int = 0


class StarlingIndex:
    """Vamana graph + BNF block-shuffled coupled layout + block-level search
    + random-sample in-memory navigation graph (Starling [38])."""

    kind = "starling"

    def __init__(self, x, adj, entry, codec, codes, store, nav_vids, nav_adj,
                 params=None):
        self.x, self.adj, self.entry = x, adj, entry
        self.codec, self.codes, self.store = codec, codes, store
        self.nav_vids, self.nav_adj = nav_vids, nav_adj
        self.params = params if params is not None else StarlingParams()
        self.cost = store.scheduler.cost

    @classmethod
    def build(cls, x: np.ndarray, params: StarlingParams = StarlingParams()):
        params = dataclasses.replace(params)   # configure_io mutates in place
        adj, entry = _builder_for(params).build_vamana(
            x, r=params.r, l_build=params.l_build, alpha=params.alpha,
            seed=params.seed)
        npb = coupled_nodes_per_block(x.shape[1], params.r)
        blocks = bnf_blocks(adj, npb, seed=params.seed)
        order = np.argsort(blocks, kind="stable").astype(np.int64)
        m = params.pq_m or _pick_pq_m(x.shape[1])
        codec = train_pq(x, m=m, seed=params.seed)
        codes = codec.encode(x)
        store = CoupledStorage(x, adj, order=order,
                               policy=params.cache_policy,
                               cache_blocks=params.cache_blocks,
                               cost=_cost_for(params),
                               faults=_fault_plan(params), retry=params.retry)
        # Starling nav graph: random sample + Vamana over the sample
        rng = np.random.default_rng(params.seed)
        ns = max(16, int(len(x) * params.nav_sample))
        nav_vids = np.sort(rng.choice(len(x), size=min(ns, len(x)), replace=False))
        if len(nav_vids) > 8:
            nav_adj, _ = build_vamana(x[nav_vids], r=min(16, len(nav_vids) - 1),
                                      l_build=32, alpha=1.2, seed=params.seed)
        else:
            nav_adj = -np.ones((len(nav_vids), 1), np.int32)
        return cls(x, adj, entry, codec, codes, store, nav_vids, nav_adj,
                   params)

    def configure_io(self, cache_policy: Optional[str] = None,
                     cache_blocks: Optional[int] = None,
                     qd: Optional[int] = None,
                     batch_io: Optional[bool] = None,
                     faults=_KEEP, fault_seed: Optional[int] = None,
                     retry=_KEEP, timeout_us=_KEEP,
                     hedge_us=_KEEP) -> "StarlingIndex":
        """Rebuild only the storage/scheduler with new I/O knobs."""
        return _configure_coupled_io(self, cache_policy, cache_blocks, qd,
                                     batch_io, faults=faults,
                                     fault_seed=fault_seed, retry=retry,
                                     timeout_us=timeout_us, hedge_us=hedge_us)

    def _nav_entries(self, table: np.ndarray, n_entry: int = 4) -> list[int]:
        # greedy over the sampled nav graph using PQ distances
        from .navgraph import NavLayer, _greedy_layer
        layer = NavLayer(vids=self.nav_vids.astype(np.int64), adj=self.nav_adj, entry=0)

        def pq_dist(vids):
            c = self.codes[vids].astype(np.int64)
            return table[np.arange(table.shape[0])[None, :], c].sum(1)

        ids, _ = _greedy_layer(layer, [0], pq_dist, ef=16)
        return [int(self.nav_vids[i]) for i in ids[:n_entry]] or [self.entry]

    def search(self, q: np.ndarray, k: int, l: int,
               drop_cache: bool = True,
               exclude: Optional[set] = None) -> SearchResult:
        table = self.codec.adc_table(q)
        entries = self._nav_entries(table)
        bs = max(2, self.params.qd) if self.params.batch_io else None
        return search_coupled(self.store, self.codes, table, q, entries,
                              k, l, block_level=True, batch_submit=bs,
                              drop_cache=drop_cache, exclude=exclude)

    def search_batch(self, queries: np.ndarray, k: int, l: int,
                     gt: Optional[np.ndarray] = None,
                     warm_cache: bool = False,
                     exclude: Optional[set] = None) -> BatchStats:
        return _batch(lambda i, q, dc: self.search(q, k, l, drop_cache=dc,
                                                   exclude=exclude),
                      queries, gt, k, self.cost, warm_cache)

    def degree_stats(self):
        blocks = (self.store.pos // self.store.npb).astype(np.int64)
        return degree_stats(self.adj, blocks)

    def index_bytes(self) -> int:
        return self.store.device.total_bytes

    def memory_bytes(self) -> int:
        # Starling keeps an id<->block map in memory (paper §5.2.5)
        return (self.codes.nbytes + self.codec.codebooks.nbytes
                + self.nav_adj.nbytes + self.nav_vids.nbytes
                + self.store.pos.nbytes + self.store.layout.nbytes
                + self.x.shape[1] * 4 * len(self.nav_vids))  # nav raw vectors


# ---------------------------------------------------------------------------
# BAMG
# ---------------------------------------------------------------------------
def _make_decoupled_store(x, graph, nav, p) -> DecoupledStorage:
    """Decoupled storage from a built graph + the I/O knobs in params."""
    pins = ()
    if p.pin_nav_blocks > 0:
        budget = min(p.pin_nav_blocks, max(0, p.cache_blocks))
        pins = nav_pin_gblocks(nav, graph.blocks, budget, entry=graph.entry)
    return DecoupledStorage(
        x, graph.adj, graph.blocks, graph.members,
        cache_blocks=p.cache_blocks, vec_cache_blocks=p.vec_cache_blocks,
        policy=p.cache_policy,
        vec_policy=p.vec_cache_policy, pinned_gblocks=pins,
        cost=_cost_for(p), faults=_fault_plan(p), retry=p.retry)


@dataclasses.dataclass
class BAMGParams:
    alpha: int = 3
    beta: float = 1.05
    r: int = 32
    l_build: int = 64
    knn_k: int = 32
    gamma: int = 256
    capacity: Optional[int] = None   # default: max for 4 KB graph block
    pq_m: Optional[int] = None
    use_nav: bool = True
    use_bmrng_prune: bool = True     # ablation: BAMG w/o BMRNG rule
    sibling_edges: bool = True
    cache_policy: str = "lru"        # graph block cache policy
    vec_cache_policy: Optional[str] = None   # default: same as cache_policy
    cache_blocks: int = 256          # graph block cache capacity
    vec_cache_blocks: int = 256      # vector block cache capacity
    qd: int = 1                      # I/O queue depth (pipelined scheduler)
    batch_io: bool = False           # batched submissions (top-alpha + rerank)
    pin_nav_blocks: int = 0          # nav-entry graph blocks pinned in memory
    build_backend: str = "host"      # graph construction: "host" | "batched"
    build_batch: int = 256           # nodes per batched-build step
    build_knn: str = "clustered"     # batched kNN stage: "clustered"|"exact"
    faults: Optional[FaultSpec] = None   # fault injection (None = clean disk)
    fault_seed: int = 0              # seed of the deterministic fault plan
    retry: Optional[RetryPolicy] = None  # bounded-retry policy (None = default)
    timeout_us: Optional[float] = None   # abandon an attempt past this
    hedge_us: Optional[float] = None     # duplicate-read hedge age
    seed: int = 0


class BAMGIndex:
    """The paper's system: BAMG graph + decoupled layout + nav graph +
    block-first search (Alg. 2/3/4)."""

    kind = "bamg"

    def __init__(self, x, graph: BAMGGraph, codec, codes, store, nav, params):
        self.x, self.graph = x, graph
        self.codec, self.codes, self.store = codec, codes, store
        self.nav = nav
        self.params = params
        self.cost = store.scheduler.cost

    @classmethod
    def build(cls, x: np.ndarray, params: BAMGParams = BAMGParams()):
        p = dataclasses.replace(params)        # configure_io mutates in place
        builder = _builder_for(p)
        nsg_adj, entry = builder.build_nsg(x, r=p.r, l_build=p.l_build,
                                           knn_k=p.knn_k, seed=p.seed)
        capacity = p.capacity or max_capacity_for(p.r)
        blocks = bnf_blocks(nsg_adj, capacity, seed=p.seed)
        if p.use_bmrng_prune:
            graph = builder.refine_bamg(x, nsg_adj, entry, blocks, capacity,
                                        alpha=p.alpha, beta=p.beta,
                                        sibling_edges=p.sibling_edges,
                                        max_degree=p.r)
        else:  # ablation: same layout, no block-aware pruning
            graph = BAMGGraph(adj=nsg_adj, blocks=np.asarray(blocks, np.int32),
                              members=block_members(blocks, capacity),
                              entry=entry, capacity=capacity,
                              alpha=p.alpha, beta=p.beta)
        m = p.pq_m or _pick_pq_m(x.shape[1])
        codec = train_pq(x, m=m, seed=p.seed)
        codes = codec.encode(x)
        nav = None
        if p.use_nav:
            nav = build_navgraph(x, graph, alpha=p.alpha, beta=p.beta,
                                 gamma=p.gamma, capacity=capacity, seed=p.seed)
        store = _make_decoupled_store(x, graph, nav, p)
        return cls(x, graph, codec, codes, store, nav, p)

    @classmethod
    def from_graph(cls, x: np.ndarray, graph: BAMGGraph,
                   params: BAMGParams = BAMGParams()) -> "BAMGIndex":
        """Index from an already-built BAMG graph (streaming consolidation:
        the graph comes out of delta-fold + Alg-2 refine, not a fresh
        `build`).  Trains PQ, builds the nav graph, and lays out storage
        exactly as `build` would."""
        p = dataclasses.replace(params)        # configure_io mutates in place
        m = p.pq_m or _pick_pq_m(x.shape[1])
        codec = train_pq(x, m=m, seed=p.seed)
        codes = codec.encode(x)
        nav = None
        if p.use_nav:
            nav = build_navgraph(x, graph, alpha=p.alpha, beta=p.beta,
                                 gamma=p.gamma, capacity=graph.capacity,
                                 seed=p.seed)
        store = _make_decoupled_store(x, graph, nav, p)
        return cls(x, graph, codec, codes, store, nav, p)

    def configure_io(self, cache_policy: Optional[str] = None,
                     vec_cache_policy: Optional[str] = None,
                     cache_blocks: Optional[int] = None,
                     vec_cache_blocks: Optional[int] = None,
                     qd: Optional[int] = None,
                     batch_io: Optional[bool] = None,
                     pin_nav_blocks: Optional[int] = None,
                     faults=_KEEP, fault_seed: Optional[int] = None,
                     retry=_KEEP, timeout_us=_KEEP,
                     hedge_us=_KEEP) -> "BAMGIndex":
        """Rebuild only the storage/scheduler with new I/O knobs (graph, PQ
        codes, and nav graph untouched) -- cheap policy/QD/pinning sweeps."""
        _update_io_params(self.params, dict(
            cache_policy=cache_policy, vec_cache_policy=vec_cache_policy,
            cache_blocks=cache_blocks, vec_cache_blocks=vec_cache_blocks,
            qd=qd, batch_io=batch_io, pin_nav_blocks=pin_nav_blocks,
            fault_seed=fault_seed),
            dict(faults=faults, retry=retry, timeout_us=timeout_us,
                 hedge_us=hedge_us))
        self.store = _make_decoupled_store(self.x, self.graph, self.nav,
                                           self.params)
        self.cost = self.store.scheduler.cost
        return self

    def _pq_dist_fn(self, table: np.ndarray):
        m_sub = table.shape[0]

        def fn(vids: np.ndarray) -> np.ndarray:
            c = self.codes[np.asarray(vids, np.int64)].astype(np.int64)
            return table[np.arange(m_sub)[None, :], c].sum(1)
        return fn

    def entries_for(self, table: np.ndarray, n_entry: int = 4) -> list[int]:
        if self.nav is not None and self.nav.layers:
            seeds, _ = search_nav(self.nav, self._pq_dist_fn(table), n_entry)
            if seeds:
                return seeds
        return [self.graph.entry]

    def search(self, q: np.ndarray, k: int, l: int,
               alpha: Optional[int] = None,
               rerank_margin: Optional[float] = None,
               random_entry_seed: Optional[int] = None,
               max_hops: Optional[int] = None,
               batch_io: Optional[bool] = None,
               drop_cache: bool = True,
               exclude: Optional[set] = None) -> SearchResult:
        table = self.codec.adc_table(q)
        if random_entry_seed is not None:  # ablation "BAMG w/o NG"
            rng = np.random.default_rng(random_entry_seed)
            entries = rng.choice(len(self.x), size=4, replace=False).tolist()
        else:
            entries = self.entries_for(table)
        a = alpha if alpha is not None else self.params.alpha
        batched = self.params.batch_io if batch_io is None else batch_io
        # batched mode: each pop submits the top-alpha unchecked candidates'
        # graph blocks together (demand + speculative prefetch)
        bs = max(2, a) if batched else None
        return search_bamg(self.store, self.codes, table, q, entries, k, l,
                           alpha=a, rerank_margin=rerank_margin,
                           max_hops=max_hops, batch_submit=bs,
                           drop_cache=drop_cache, exclude=exclude)

    def search_batch(self, queries: np.ndarray, k: int, l: int,
                     gt: Optional[np.ndarray] = None,
                     alpha: Optional[int] = None,
                     rerank_margin: Optional[float] = None,
                     random_entry: bool = False,
                     max_hops: Optional[int] = None,
                     batch_io: Optional[bool] = None,
                     warm_cache: bool = False,
                     exclude: Optional[set] = None) -> BatchStats:
        return _batch(
            lambda i, q, dc: self.search(
                q, k, l, alpha=alpha, rerank_margin=rerank_margin,
                random_entry_seed=(i if random_entry else None),
                max_hops=max_hops, batch_io=batch_io, drop_cache=dc,
                exclude=exclude),
            queries, gt, k, self.cost, warm_cache)

    def batch_arrays(self, n_entry_cands: int = 256) -> dict:
        """Fixed-shape numpy views for the batched TPU engine.

        Returns adjacency as padded `(N, R)` neighbor VIDs (-1 pad), the PQ
        codes/codebooks, the raw vectors, and `entry_cands`: a pool of entry
        candidate VIDs for query-sensitive entry selection (the finest nav
        layer when a navigation graph was built, else an evenly strided
        sample), capped at `n_entry_cands` by even striding so candidates
        stay spread across the corpus.
        """
        if self.nav is not None and self.nav.layers:
            cands = np.asarray(self.nav.layers[-1].vids, np.int64)
        else:
            cands = np.arange(len(self.x), dtype=np.int64)
        if len(cands) > n_entry_cands:
            cands = cands[np.linspace(0, len(cands) - 1, n_entry_cands,
                                      dtype=np.int64)]
        return {
            "x": np.asarray(self.x, np.float32),
            "adj": np.asarray(self.graph.adj, np.int32),
            "codes": np.asarray(self.codes, np.uint8),
            "codebooks": np.asarray(self.codec.codebooks, np.float32),
            "entry_cands": cands,
        }

    def degree_stats(self):
        return degree_stats(self.graph.adj, self.graph.blocks)

    def index_bytes(self) -> int:
        return self.store.graph_bytes + self.store.vector_bytes

    def memory_bytes(self) -> int:
        nav = self.nav.memory_bytes() if self.nav else 0
        return self.codes.nbytes + self.codec.codebooks.nbytes + nav

    # --- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        nav_layers = self.nav.layers if self.nav else []
        blobs = {
            "x": self.x, "adj": self.graph.adj, "blocks": self.graph.blocks,
            "members": self.graph.members,
            "entry": np.asarray(self.graph.entry),
            "capacity": np.asarray(self.graph.capacity),
            "alpha": np.asarray(self.params.alpha),
            "beta": np.asarray(self.params.beta),
            "codebooks": self.codec.codebooks, "codes": self.codes,
            "n_nav": np.asarray(len(nav_layers)),
        }
        for i, layer in enumerate(nav_layers):
            blobs[f"nav{i}_vids"] = layer.vids
            blobs[f"nav{i}_adj"] = layer.adj
            blobs[f"nav{i}_entry"] = np.asarray(layer.entry)
        np.savez_compressed(path, **blobs)

    @classmethod
    def load(cls, path: str) -> "BAMGIndex":
        from .navgraph import NavLayer
        with np.load(path) as z:
            x = z["x"]
            graph = BAMGGraph(adj=z["adj"], blocks=z["blocks"],
                              members=z["members"], entry=int(z["entry"]),
                              capacity=int(z["capacity"]),
                              alpha=int(z["alpha"]), beta=float(z["beta"]))
            codec = PQCodec(codebooks=z["codebooks"])
            codes = z["codes"]
            layers = [NavLayer(vids=z[f"nav{i}_vids"], adj=z[f"nav{i}_adj"],
                               entry=int(z[f"nav{i}_entry"]))
                      for i in range(int(z["n_nav"]))]
        params = BAMGParams(alpha=graph.alpha, beta=graph.beta,
                            capacity=graph.capacity)
        nav = NavGraph(layers=layers) if layers else None
        store = _make_decoupled_store(x, graph, nav, params)
        return cls(x, graph, codec, codes, store, nav, params)
