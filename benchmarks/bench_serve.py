"""Serving throughput: batched fixed-shape engine vs the host query loop.

Rows: host-engine wall-clock qps, then the batched engine's qps at batch
sizes {1, 8, 64, 256} (same index, same search budget l) with p50/p99
per-call latency, plus recall of both so the speedup is apples-to-apples.
The acceptance bar for the serving layer is batched-qps(B=64) > host-qps.

The tail isolates the hop loop: per-hop latency of the unfused scan vs
the fused beam kernel (`EngineConfig(backend="fused")`; auto-resolves to
the jnp fused oracle on CPU, the Pallas program on TPU) by differencing
engine wall time across two hop budgets -- entry selection, re-rank and
dispatch overheads subtract out.

Resilience rows (degraded-mode serving + blue/green deploy):
  serve.degraded.*     sharded front-end with one shard killed via the
                       engine fault hook -- recall/qps of the partial
                       answers plus the health snapshot; then healed and
                       asserted bit-identical to the clean run.
  serve.deploy.*       full blue/green round-trip on a temp root:
                       publish -> validate -> promote -> hot swap ->
                       rollback, serving correct top-k at every stage.

`run_load_sweep` (registered as `load_sweep` in run.py) drives the
distributed runtime through the continuous-batching scheduler under an
open-loop arrival process: offered QPS x SLO -> achieved p50/p99, recall,
deadline-hit and degraded/shrunk fractions per load point.  Acceptance
(asserted): at the lowest offered load the deadline scheduler holds the
configured p99 SLO and recall matches the unscheduled runtime path.
Knobs: REPRO_BENCH_QPS_GRID, REPRO_BENCH_SLO_MS, REPRO_BENCH_LOAD_REQS.
"""
import os
import tempfile
import time

import numpy as np

from . import common
from repro.core.distances import recall_at_k
from repro.core.engine import BAMGParams
from repro.serve import (BatchedANNEngine, BeamTier, BlueGreenEngine,
                         DeploymentManager, EngineConfig, Scheduler,
                         SchedulerConfig, ServeRuntime, ShardedFrontend,
                         make_requests, summarize)

K = 10
L = 48
BATCHES = (1, 8, 64, 256)
HOP_SPLIT = (8, 32)        # hop budgets differenced for per-hop timing


def run() -> None:
    regime = "sift-like"
    ds = common.dataset(regime)
    idx = common.default_bamg(regime)

    t0 = time.perf_counter()
    st = idx.search_batch(ds.queries, k=K, l=L, gt=ds.gt)
    host_s = time.perf_counter() - t0
    host_qps = len(ds.queries) / host_s
    common.emit("serve.host_loop.qps", round(host_qps, 1),
                f"recall={st.recall:.3f}")

    eng = BatchedANNEngine.from_index(idx, EngineConfig(l=L, max_hops=32))
    ids, _ = eng.search_batch(ds.queries, K)
    common.emit("serve.batched.recall", round(recall_at_k(ids, ds.gt, K), 3),
                f"l={L}")

    nq = len(ds.queries)
    for b in BATCHES:
        q = np.tile(ds.queries, (-(-b // nq), 1))[:b]
        eng.search_batch(q, K)                       # compile + warm
        reps = max(4, 256 // b)
        lat = np.empty(reps)
        for i in range(reps):
            t0 = time.perf_counter()
            eng.search_batch(q, K)
            lat[i] = time.perf_counter() - t0
        qps = b * reps / lat.sum()
        p50, p99 = np.percentile(lat, [50, 99]) * 1e3
        common.emit(f"serve.batched.b{b}.qps", round(qps, 1),
                    f"p50_ms={p50:.2f} p99_ms={p99:.2f} "
                    f"speedup_vs_host={qps / host_qps:.2f}x")

    # --- per-hop latency, unfused scan vs fused beam kernel (B=64)
    q = np.tile(ds.queries, (-(-64 // nq), 1))[:64]
    per_hop = {}
    for backend in ("ref", "fused"):
        times = []
        for hops in HOP_SPLIT:
            e = BatchedANNEngine.from_index(
                idx, EngineConfig(l=L, max_hops=hops, backend=backend))
            e.search_batch(q, K)                     # compile + warm
            reps = 8
            t0 = time.perf_counter()
            for _ in range(reps):
                e.search_batch(q, K)
            times.append((time.perf_counter() - t0) / reps)
        per_hop[backend] = ((times[1] - times[0])
                            / (HOP_SPLIT[1] - HOP_SPLIT[0]) * 1e6)
        common.emit(f"serve.{backend}.b64.hop_us",
                    round(per_hop[backend], 1), f"l={L}")
    common.emit("serve.fused.b64.hop_speedup",
                round(per_hop["ref"] / per_hop["fused"], 2),
                "unfused_scan_vs_fused_kernel")

    # --- resident/streaming crossover of the auto backend -----------------
    # the resident footprint is linear in N; report the corpus size where
    # backend="auto" on TPU would switch from "fused" to "fused_stream"
    # for this index's serving shape, plus both footprints at the bench N
    from repro.kernels import beam_fused
    arrs = idx.batch_arrays()
    n, r = arrs["adj"].shape
    m = arrs["codes"].shape[1]
    dims = dict(m=m, k=256, l=L, max_hops=32)
    budget = beam_fused.vmem_budget_bytes()
    base = beam_fused.vmem_bytes(0, r, **dims)
    per_row = (r + m) * 4
    cross_n = max(0, budget - base) // per_row + 1
    common.emit("serve.fused.vmem_crossover_n", int(cross_n),
                f"budget={budget};resident_at_bench_n="
                f"{beam_fused.vmem_bytes(n, r, **dims)};stream_at_bench_n="
                f"{beam_fused.stream_vmem_bytes(n, r, **dims)};n={n};r={r};"
                f"m={m}")

    # --- streaming parity: the HBM-streaming hop program (interpret mode
    # on CPU) must land on the identical top-k as the unfused scan
    scfg = dict(l=16, max_hops=8)
    e_ref = BatchedANNEngine.from_index(
        idx, EngineConfig(backend="ref", **scfg))
    e_str = BatchedANNEngine.from_index(
        idx, EngineConfig(backend="fused_stream_interpret", **scfg))
    qs = ds.queries[:8]
    t0 = time.perf_counter()
    sids, _ = e_str.search_batch(qs, K)
    stream_s = time.perf_counter() - t0
    rids, _ = e_ref.search_batch(qs, K)
    assert (sids == rids).all(), "streaming engine diverged from unfused"
    common.emit("serve.fused_stream.parity",
                round(recall_at_k(sids, ds.gt[:8], K), 3),
                f"bit_identical=1;l={scfg['l']};compile_plus_run_s="
                f"{stream_s:.1f}")

    # --- degraded-mode serving: kill one shard of a sharded front-end -----
    fe = ShardedFrontend.build(ds.base, n_shards=3,
                               params=BAMGParams(r=16, l_build=32, seed=0),
                               config=EngineConfig(l=L, max_hops=32))
    ids, _ = fe.search_batch(ds.queries, K)
    clean_rec = recall_at_k(ids, ds.gt, K)
    common.emit("serve.degraded.clean.recall", round(clean_rec, 3),
                f"shards_up={fe.health()['shards_up']}/3")
    fe.engines[0].inject_fault()
    t0 = time.perf_counter()
    dids, _, status = fe.search_batch(ds.queries, K, with_status=True)
    dt = time.perf_counter() - t0
    h = fe.health()
    common.emit("serve.degraded.1down.recall",
                round(recall_at_k(dids, ds.gt, K), 3),
                f"shards_up={h['shards_up']}/3;"
                f"degraded_frac={status.degraded.mean():.2f};"
                f"qps={len(ds.queries) / dt:.1f}")
    assert status.degraded.all() and h["shards_up"] == 2, \
        "killed shard must be skipped and reported"
    fe.engines[0].heal()
    fe.mark_up(0)
    rids, _ = fe.search_batch(ds.queries, K)
    assert (rids == ids).all(), "healed fleet must serve bit-identically"
    common.emit("serve.degraded.healed.recall",
                round(recall_at_k(rids, ds.gt, K), 3), "bit_identical=1")

    # --- blue/green deploy round-trip -------------------------------------
    cfg = EngineConfig(l=L, max_hops=32)
    with tempfile.TemporaryDirectory() as root:
        dm = DeploymentManager(root)
        t0 = time.perf_counter()
        man = dm.deploy(ds.base, "v1", ds.queries, ds.gt[:, :K],
                        params=BAMGParams(r=16, l_build=32, seed=0),
                        k=K, min_recall=0.5, config=cfg)
        common.emit("serve.deploy.v1.s", round(time.perf_counter() - t0, 2),
                    f"recall={man.meta['validated_recall']:.3f};"
                    f"active={dm.active()}")
        bg = BlueGreenEngine(dm, cfg)
        v1_ids, _ = bg.search_batch(ds.queries, K)
        dm.deploy(ds.base, "v2", ds.queries, ds.gt[:, :K],
                  params=BAMGParams(r=16, l_build=32, seed=1),
                  k=K, min_recall=0.5, config=cfg)
        swapped = bg.refresh()
        v2_ids, _ = bg.search_batch(ds.queries, K)
        common.emit("serve.deploy.v2.recall",
                    round(recall_at_k(v2_ids, ds.gt, K), 3),
                    f"swapped={int(swapped)};active={dm.active()}")
        assert swapped and dm.active() == "v2"
        dm.rollback()
        bg.refresh()
        rb_ids, _ = bg.search_batch(ds.queries, K)
        assert (rb_ids == v1_ids).all(), \
            "rollback must restore bit-identical serving"
        common.emit("serve.deploy.rollback.recall",
                    round(recall_at_k(rb_ids, ds.gt, K), 3),
                    f"active={dm.active()};bit_identical=1")


def run_load_sweep() -> None:
    """Offered QPS x SLO -> achieved p50/p99, recall, degraded fraction.

    Open-loop arrivals through the continuous-batching scheduler on a
    3-shard ServeRuntime.  Asserted at the lowest grid point: p99 holds
    the SLO and recall matches the unscheduled runtime path (within 2pp;
    shrunk beams may legitimately trade recall at higher loads)."""
    regime = "sift-like"
    ds = common.dataset(regime)
    qps_grid = sorted(float(v) for v in os.environ.get(
        "REPRO_BENCH_QPS_GRID", "50,200,800").split(","))
    slo = float(os.environ.get("REPRO_BENCH_SLO_MS", "500")) / 1e3
    n_reqs = int(os.environ.get("REPRO_BENCH_LOAD_REQS", "192"))

    rt = ServeRuntime.build(ds.base, n_shards=3,
                            params=BAMGParams(r=16, l_build=32, seed=0),
                            config=EngineConfig(l=L, max_hops=32))
    ref_ids, _ = rt.serve_batch(ds.queries, K)
    ref_rec = recall_at_k(ref_ids, ds.gt, K)
    common.emit("serve.load.unscheduled.recall", round(ref_rec, 3),
                f"shards=3;l={L}")

    sched = Scheduler(rt, SchedulerConfig(
        k=K, max_batch=32, slo=slo,
        tiers=(BeamTier(), BeamTier(l=16, max_hops=8))))
    nq = len(ds.queries)
    gt = np.tile(ds.gt, (-(-n_reqs // nq), 1))[:n_reqs]
    for qi, qps in enumerate(qps_grid):
        reqs = make_requests(ds.queries, qps=qps, slo=slo, n=n_reqs, seed=qi)
        done = sched.run(reqs)
        s = summarize(done)
        ids = np.stack([c.ids for c in done])   # sorted by rid = query order
        rec = recall_at_k(ids, gt, K)
        common.emit(f"serve.load.qps{qps:g}.p99_ms", round(s["p99_ms"], 2),
                    f"p50_ms={s['p50_ms']:.2f};recall={rec:.3f};"
                    f"deadline_hit={s['deadline_hit']:.2f};"
                    f"degraded_frac={s['degraded_frac']:.2f};"
                    f"shrunk_frac={s['shrunk_frac']:.2f};"
                    f"achieved_qps={s['achieved_qps']:.1f};"
                    f"slo_ms={slo * 1e3:g}")
        if qi == 0:
            assert s["p99_ms"] <= slo * 1e3, \
                (f"lowest load ({qps:g} qps): p99 {s['p99_ms']:.1f}ms "
                 f"blew the {slo * 1e3:g}ms SLO")
            assert rec >= ref_rec - 0.02, \
                (f"lowest load ({qps:g} qps): scheduled recall {rec:.3f} "
                 f"fell below unscheduled {ref_rec:.3f}")


if __name__ == "__main__":
    run()
    run_load_sweep()
