"""Production mesh construction (DESIGN.md §4).

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") --
            the pod axis carries cross-pod data parallelism (compressed
            gradient exchange, train/compression.py).

A function, not a module constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax

from repro.utils.sharding import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model: int = 1, data: int | None = None):
    """Small mesh over whatever devices exist (tests / examples).

    The factorization is validated up front: `model` larger than the
    device count used to silently derive a 0-sized data axis
    (`data = n // model`), surfacing later as an opaque mesh-shape error.
    """
    n = len(jax.devices())
    if model < 1:
        raise ValueError(f"make_host_mesh: model={model}; axis sizes must "
                         f"be >= 1")
    if model > n:
        raise ValueError(
            f"make_host_mesh: model={model} exceeds the {n} available "
            f"device(s) -- the derived data axis n // model would be "
            f"zero-sized.  Shrink model or launch with more devices "
            f"(e.g. XLA_FLAGS=--xla_force_host_platform_device_count=N).")
    if data is None:
        data = n // model
    if data < 1:
        raise ValueError(f"make_host_mesh: data={data}; axis sizes must "
                         f"be >= 1")
    if data * model > n:
        raise ValueError(
            f"make_host_mesh: a ({data}, {model}) mesh needs "
            f"{data * model} devices but only {n} exist")
    return make_mesh_compat((data, model), ("data", "model"))
