"""Block assignment (Definition 2): BNF block shuffling [Starling], plus
uniform / random baselines.

BNF greedily packs blocks of capacity c: seed an empty block with an
unassigned node, then repeatedly pull in the unassigned node with the most
edges into the current block (its block-neighbor frequency), tie-broken by
graph order. Near-linear via a lazy max-heap keyed on frequency counts.
"""
from __future__ import annotations

import heapq

import numpy as np


def uniform_blocks(n: int, c: int) -> np.ndarray:
    """Nodes 0..n-1 in graph order, c per block."""
    return (np.arange(n) // c).astype(np.int32)


def random_blocks(n: int, c: int, seed: int = 0) -> np.ndarray:
    perm = np.random.default_rng(seed).permutation(n)
    out = np.empty(n, np.int32)
    out[perm] = (np.arange(n) // c).astype(np.int32)
    return out


def undirected_neighbor_lists(adj: np.ndarray) -> list[list[int]]:
    """Deduplicated undirected view of a padded adjacency (n, R).

    A symmetric edge (u->v and v->u both present) contributes each endpoint
    to the other's list exactly once -- naive per-directed-edge insertion
    would add it twice and inflate block-neighbor frequencies.
    """
    n = adj.shape[0]
    valid = adj >= 0
    src = np.repeat(np.arange(n, dtype=np.int64), adj.shape[1])[valid.ravel()]
    dst = adj.ravel()[valid.ravel()].astype(np.int64)
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    keep = lo != hi                       # drop self loops
    edges = np.unique(np.stack([lo[keep], hi[keep]], axis=1), axis=0)
    und: list[list[int]] = [[] for _ in range(n)]
    for a, b in edges.tolist():
        und[a].append(b)
        und[b].append(a)
    return und


def bnf_blocks(adj: np.ndarray, c: int, seed: int = 0) -> np.ndarray:
    """Starling-style BNF block shuffling on a padded adjacency (n, R)."""
    n = adj.shape[0]
    und = undirected_neighbor_lists(adj)
    blocks = -np.ones(n, np.int32)
    freq = np.zeros(n, np.int64)
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    oi = 0
    bid = 0
    while True:
        # seed next block with the first unassigned node in random order
        while oi < n and blocks[order[oi]] >= 0:
            oi += 1
        if oi >= n:
            break
        seed_node = int(order[oi])
        members = [seed_node]
        blocks[seed_node] = bid
        heap: list[tuple[int, int]] = []  # (-freq, node), lazy
        def bump(node: int) -> None:
            for w in und[node]:
                if blocks[w] < 0:
                    freq[w] += 1
                    heapq.heappush(heap, (-int(freq[w]), w))
        bump(seed_node)
        while len(members) < c and heap:
            nf, w = heapq.heappop(heap)
            if blocks[w] >= 0 or -nf != freq[w]:
                continue  # stale entry
            blocks[w] = bid
            members.append(w)
            freq[w] = 0
            bump(w)
        # block underfull with no connected candidates: fill from order
        while len(members) < c:
            while oi < n and blocks[order[oi]] >= 0:
                oi += 1
            if oi >= n:
                break
            w = int(order[oi])
            blocks[w] = bid
            members.append(w)
            freq[w] = 0
            bump(w)
        bid += 1
    return blocks


def block_members(blocks: np.ndarray, c: int) -> np.ndarray:
    """(m, c) int32 member table padded with -1, rows = block ids."""
    m = int(blocks.max()) + 1
    out = -np.ones((m, c), np.int32)
    fill = np.zeros(m, np.int64)
    for v, b in enumerate(blocks.tolist()):
        out[b, fill[b]] = v
        fill[b] += 1
    return out


def intra_edge_fraction(adj: np.ndarray, blocks: np.ndarray) -> float:
    valid = adj >= 0
    n, r = adj.shape
    src = np.repeat(np.arange(n), r)[valid.ravel()]
    dst = adj.ravel()[valid.ravel()]
    if len(src) == 0:
        return 0.0
    return float((blocks[src] == blocks[dst]).mean())
