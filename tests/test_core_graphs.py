"""Graph-construction properties: RNG/MRNG/BMRNG (paper §2-3)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.block_assign import (block_members, bnf_blocks, random_blocks,
                                     undirected_neighbor_lists,
                                     uniform_blocks)
from repro.core.bmrng import build_bmrng, io_length, monotonic_io_path
from repro.core.distances import exact_knn, knn_graph, pairwise_sq_l2
from repro.core.graph_build import build_nsg, build_vamana, degree_stats
from repro.core.rng_rules import has_monotonic_path, mrng_edges, rng_edges


def _points(n, d, seed):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def test_rng_subset_of_mrng_outedges():
    x = _points(30, 3, 0)
    rng_adj = rng_edges(x)
    mrng_adj = mrng_edges(x)
    # every undirected RNG edge appears in MRNG (MRNG keeps strictly more)
    assert np.all(mrng_adj[rng_adj]), "MRNG must contain RNG edges"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_mrng_monotonic_property(seed):
    """Theorem 3 of [15]: MRNG admits a monotone path between any pair."""
    x = _points(18, 3, seed)
    d = pairwise_sq_l2(x, x)
    adj = mrng_edges(x, d)
    n = len(x)
    for u in range(0, n, 5):
        for q in range(n):
            if u != q:
                assert has_monotonic_path(adj, d, u, q), (u, q)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 8))
def test_bmrng_theorem1_monotonic_io_path(seed, cap):
    """Theorem 1: BMRNG admits a monotonic I/O path between any two nodes."""
    x = _points(20, 3, seed)
    blocks = random_blocks(len(x), cap, seed=seed)
    g = build_bmrng(x, blocks)
    for u in range(0, len(x), 4):
        for q in range(len(x)):
            if u == q:
                continue
            path = monotonic_io_path(g.adj, g.dist, g.blocks, u, q)
            assert path is not None, f"no monotonic I/O path {u}->{q}"
            # Definition 3: edges exist; intra-segment steps strictly
            # decrease; consecutive block-segment END nodes strictly decrease
            dq = g.dist[:, q]
            seg_end_prev = np.inf
            for i, (a, b) in enumerate(zip(path, path[1:])):
                assert g.adj[a, b], f"non-edge {a}->{b}"
                if g.blocks[a] == g.blocks[b]:
                    assert dq[b] < dq[a], "intra-block step must decrease"
                else:
                    assert dq[a] < seg_end_prev, "segment end must decrease"
                    seg_end_prev = dq[a]
            assert dq[path[-1]] == 0.0 or path[-1] == q
            assert io_length(path, g.blocks) >= 1


def test_bmrng_sparser_than_mrng_same_io():
    """Block-awareness should remove cross-block edges vs plain MRNG."""
    x = _points(40, 4, 7)
    blocks = uniform_blocks(len(x), 8)
    g = build_bmrng(x, blocks)
    m = mrng_edges(x)
    same = blocks[:, None] == blocks[None, :]
    cross_bmrng = int((g.adj & ~same).sum())
    cross_mrng = int((m & ~same).sum())
    assert cross_bmrng <= cross_mrng


def test_bnf_blocks_partition_and_locality():
    x = _points(200, 8, 1)
    adj = knn_graph(x, 8)
    c = 10
    blocks = bnf_blocks(adj, c, seed=0)
    assert blocks.min() >= 0 and len(blocks) == 200
    counts = np.bincount(blocks)
    assert counts.max() <= c
    members = block_members(blocks, c)
    got = sorted(v for row in members for v in row if v >= 0)
    assert got == list(range(200))
    # BNF should beat random assignment on intra-block edge fraction
    from repro.core.block_assign import intra_edge_fraction
    rnd = random_blocks(200, c, seed=0)
    assert (intra_edge_fraction(adj, blocks)
            > intra_edge_fraction(adj, rnd))


def test_vamana_and_nsg_reachability():
    x = _points(300, 8, 3)
    for builder in (build_vamana, build_nsg):
        adj, entry = builder(x, r=12, l_build=24)
        # BFS from entry reaches (almost) everything
        seen = np.zeros(len(x), bool)
        stack = [entry]
        seen[entry] = True
        while stack:
            v = stack.pop()
            for u in adj[v]:
                if u >= 0 and not seen[u]:
                    seen[u] = True
                    stack.append(int(u))
        assert seen.mean() > 0.98, builder.__name__


def test_degree_stats_split():
    adj = np.array([[1, 2], [0, -1], [-1, -1]], np.int32)
    blocks = np.array([0, 0, 1], np.int32)
    s = degree_stats(adj, blocks)
    assert s["total"] == pytest.approx(1.0)
    assert s["intra"] == pytest.approx(2 / 3)
    assert s["cross"] == pytest.approx(1 / 3)


def test_exact_knn_matches_bruteforce():
    x = _points(100, 5, 9)
    q = _points(7, 5, 10)
    d, ids = exact_knn(x, q, 5)
    ref = np.argsort(((q[:, None] - x[None]) ** 2).sum(-1), axis=1)[:, :5]
    assert (ids == ref).mean() > 0.99
