"""Deterministic synthetic data + restart-safe sharded host pipeline."""
from . import pipeline, synthetic  # noqa: F401
