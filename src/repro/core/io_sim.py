"""Byte-accurate block-device simulator with LRU cache and exact NIO counting.

The container has no TPU and no SSD-under-test; the paper's primary I/O
metric (NIO = blocks read per query) is *exact* under simulation, and QPS is
reported through a calibrated cost model (DESIGN.md §2).  All three compared
systems (DiskANN, Starling-style, BAMG) run on this one simulator, so NIO
comparisons are apples-to-apples.

Cost model (defaults match the paper's hardware: SATA SSD, 4 KB reads):
  t_query = NIO * t_read + t_cpu
  t_read  ~ 100 us per 4 KB random read (SATA SSD)
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional

BLOCK_SIZE = 4096  # OS page / logical disk block


@dataclasses.dataclass
class IOStats:
    """Per-query (or per-run) I/O accounting."""

    graph_reads: int = 0    # graph-index block fetches
    vector_reads: int = 0   # raw-vector block fetches (BAMG decoupled layout)
    cache_hits: int = 0

    @property
    def nio(self) -> int:
        """The paper's NIO: total data-block reads (graph + vector)."""
        return self.graph_reads + self.vector_reads

    def reset(self) -> None:
        self.graph_reads = 0
        self.vector_reads = 0
        self.cache_hits = 0

    def add(self, other: "IOStats") -> None:
        self.graph_reads += other.graph_reads
        self.vector_reads += other.vector_reads
        self.cache_hits += other.cache_hits


class BlockDevice:
    """A fixed-block-size device: a list of payload blocks + an LRU cache.

    `blocks` holds the serialized payload of each block (bytes or any
    immutable object whose serialized size is <= block_size; serialization
    size is validated by the storage layer, not here).  Reads go through an
    LRU cache of `cache_blocks` entries; a miss costs one I/O.
    """

    def __init__(self, blocks: list, block_size: int = BLOCK_SIZE,
                 cache_blocks: int = 128, kind: str = "graph"):
        self.blocks = blocks
        self.block_size = block_size
        self.kind = kind
        self.cache_blocks = cache_blocks
        self._cache: OrderedDict[int, object] = OrderedDict()
        self.stats = IOStats()

    def __len__(self) -> int:
        return len(self.blocks)

    @property
    def total_bytes(self) -> int:
        return len(self.blocks) * self.block_size

    def reset(self, drop_cache: bool = True) -> None:
        self.stats.reset()
        if drop_cache:
            self._cache.clear()

    def read(self, block_id: int):
        """Fetch one block; counts an I/O on cache miss."""
        if block_id < 0 or block_id >= len(self.blocks):
            raise IndexError(f"block {block_id} out of range [0,{len(self.blocks)})")
        hit = self._cache.pop(block_id, None)
        if hit is not None:
            self._cache[block_id] = hit  # refresh LRU position
            self.stats.cache_hits += 1
            return hit
        payload = self.blocks[block_id]
        if self.kind == "graph":
            self.stats.graph_reads += 1
        else:
            self.stats.vector_reads += 1
        self._cache[block_id] = payload
        while len(self._cache) > self.cache_blocks:
            self._cache.popitem(last=False)
        return payload

    def read_range(self, start: int, count: int) -> list:
        """Sequential multi-block read (each block still counted)."""
        return [self.read(b) for b in range(start, start + count)]


@dataclasses.dataclass
class CostModel:
    """Calibrated wall-clock model for simulated QPS (DESIGN.md §2).

    Defaults approximate the paper's testbed (SATA SSD, o_direct 4 KB reads,
    8 search threads).  We report NIO (exact) as the primary metric and
    simulated QPS as the derived one.
    """

    read_us: float = 100.0      # per random 4 KB read
    dist_us: float = 0.05       # per full-precision distance computation
    pq_dist_us: float = 0.005   # per PQ ADC distance estimate
    threads: int = 8

    def query_time_us(self, nio: int, n_dist: int, n_pq: int) -> float:
        return nio * self.read_us + n_dist * self.dist_us + n_pq * self.pq_dist_us

    def qps(self, nio: float, n_dist: float, n_pq: float) -> float:
        t = self.query_time_us(nio, n_dist, n_pq)
        return 1e6 * self.threads / max(t, 1e-9)
