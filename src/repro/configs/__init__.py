"""Assigned-architecture configs + registry (one module per arch)."""
from .registry import ARCHS, get_arch  # noqa: F401
