"""Multi-layer in-memory navigation graph (§4.3, Algorithm 3).

Layer 0 is the disk-resident BAMG.  Each upper layer is built by selecting,
from every block of the layer below, representatives of its intra-block
connected components (zero-in-degree nodes first, then greedy coverage), and
rebuilding a BAMG over the selected subset; recursion stops at <= gamma
nodes.  Every block of the layer below is therefore reachable from the upper
layer via one I/O.

Layers keep only neighbor lists (no raw vectors) -- in-memory footprint is
tiny; distances during navigation use the PQ codes (also in memory).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .bamg import BAMGGraph, build_bamg


@dataclasses.dataclass
class NavLayer:
    vids: np.ndarray     # (n_l,) original dataset ids of this layer's nodes
    adj: np.ndarray      # (n_l, R) padded adjacency in layer-local indices
    entry: int           # layer-local entry node (medoid of the subset)


@dataclasses.dataclass
class NavGraph:
    layers: list[NavLayer]       # [0] = topmost (smallest) layer

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def memory_bytes(self) -> int:
        return sum(l.adj.nbytes + l.vids.nbytes for l in self.layers)


def select_block_representatives(g: BAMGGraph) -> np.ndarray:
    """Alg. 3 lines 5-12: per block, zero-in-degree seeds + greedy coverage
    of the remaining intra-block connected structure.  Local indices."""
    n = g.adj.shape[0]
    blocks = g.blocks
    # intra-block out-neighbor lists + in-degree (intra-block edges only)
    indeg = np.zeros(n, np.int64)
    intra: list[list[int]] = [[] for _ in range(n)]
    for u in range(n):
        for v in g.adj[u]:
            v = int(v)
            if v >= 0 and blocks[v] == blocks[u]:
                intra[u].append(v)
                indeg[v] += 1

    def cover_from(seeds: list[int], covered: np.ndarray) -> None:
        stack = list(seeds)
        for s in seeds:
            covered[s] = True
        while stack:
            a = stack.pop()
            for b in intra[a]:
                if not covered[b]:
                    covered[b] = True
                    stack.append(b)

    selected: list[int] = []
    for b in range(g.members.shape[0]):
        row = g.members[b]
        mem = row[row >= 0].tolist()
        if not mem:
            continue
        covered = np.zeros(n, bool)
        seeds = [u for u in mem if indeg[u] == 0]
        if not seeds:  # fully cyclic block: fall back to min in-degree node
            seeds = [min(mem, key=lambda u: (indeg[u], u))]
        cover_from(seeds, covered)
        selected.extend(seeds)
        # greedy: pick uncovered (min in-degree) until the block is covered
        while True:
            unc = [u for u in mem if not covered[u]]
            if not unc:
                break
            u = min(unc, key=lambda t: (indeg[t], t))
            selected.append(u)
            cover_from([u], covered)
    return np.asarray(sorted(set(selected)), np.int64)


def build_navgraph(
    x: np.ndarray,
    base: BAMGGraph,
    alpha: int,
    beta: float,
    gamma: int = 256,
    capacity: int | None = None,
    r: int = 24,
    l_build: int = 48,
    knn_k: int = 24,
    seed: int = 0,
    max_layers: int = 8,
) -> NavGraph:
    """Algorithm 3.  `base` is the already-built disk BAMG over all of x."""
    capacity = capacity if capacity is not None else base.capacity
    layers: list[NavLayer] = []
    cur_graph = base
    cur_vids = np.arange(len(x), dtype=np.int64)
    for _ in range(max_layers):
        sel_local = select_block_representatives(cur_graph)
        sel_vids = cur_vids[sel_local]
        if len(sel_vids) >= len(cur_vids):  # no reduction: stop (degenerate)
            break
        sub_x = x[sel_vids]
        if len(sel_vids) <= max(gamma, 8) or len(sel_vids) <= capacity:
            # final (topmost) layer: small enough to search directly
            g = build_bamg(sub_x, capacity=min(capacity, max(2, len(sel_vids))),
                           alpha=alpha, beta=beta, r=min(r, len(sel_vids) - 1),
                           l_build=l_build, knn_k=min(knn_k, len(sel_vids) - 1),
                           seed=seed)
            layers.append(NavLayer(vids=sel_vids, adj=g.adj, entry=g.entry))
            break
        g = build_bamg(sub_x, capacity=capacity, alpha=alpha, beta=beta,
                       r=min(r, len(sel_vids) - 1), l_build=l_build,
                       knn_k=min(knn_k, len(sel_vids) - 1), seed=seed)
        layers.append(NavLayer(vids=sel_vids, adj=g.adj, entry=g.entry))
        cur_graph = g
        cur_vids = sel_vids
        if len(sel_vids) <= gamma:
            break
    layers.reverse()  # [0] = topmost
    return NavGraph(layers=layers)


def nav_pin_gblocks(nav: NavGraph | None, blocks: np.ndarray, budget: int,
                    entry: int | None = None) -> np.ndarray:
    """Disk graph blocks worth pinning in memory (Starling-style).

    Every disk search enters through the finest navigation layer's nodes, so
    their graph blocks are the hottest in the whole index: with a per-query
    cold cache each would cost one NIO at the start of every query.  Rank
    blocks by how many finest-layer vids they host and return the top
    `budget` block ids (for `DecoupledStorage(pinned_gblocks=...)` /
    `PinnedCache`).  Falls back to the entry node's block when no navigation
    graph exists.
    """
    blocks = np.asarray(blocks, np.int64)
    if budget <= 0:
        return np.empty(0, np.int64)
    if nav is not None and nav.layers:
        vids = np.asarray(nav.layers[-1].vids, np.int64)
    elif entry is not None:
        vids = np.asarray([entry], np.int64)
    else:
        return np.empty(0, np.int64)
    hot, counts = np.unique(blocks[vids], return_counts=True)
    order = np.argsort(-counts, kind="stable")
    return hot[order][:budget].astype(np.int64)


def search_nav(
    nav: NavGraph,
    pq_dist_fn,
    n_entry: int = 4,
    ef: int = 16,
) -> tuple[list[int], int]:
    """Descend the navigation layers with greedy beam search (PQ distances,
    zero I/O).  Returns (entry vids for the disk search, n_pq_used)."""
    n_pq = 0
    if not nav.layers:
        return [], 0
    # top layer: start from its entry node
    seeds_vids = [int(nav.layers[0].vids[nav.layers[0].entry])]
    for layer in nav.layers:
        vid_to_local = {int(v): i for i, v in enumerate(layer.vids.tolist())}
        starts = [vid_to_local.get(v) for v in seeds_vids]
        starts = [s for s in starts if s is not None] or [layer.entry]
        ids, used = _greedy_layer(layer, starts, pq_dist_fn, max(ef, n_entry))
        n_pq += used
        seeds_vids = [int(layer.vids[i]) for i in ids[: max(n_entry, 1)]]
    return seeds_vids[:n_entry], n_pq


def _greedy_layer(layer: NavLayer, starts: list[int], pq_dist_fn, ef: int):
    """Best-first beam over one in-memory layer (local indices)."""
    import bisect
    vids = layer.vids
    d0 = pq_dist_fn(vids[np.asarray(starts, np.int64)])
    n_pq = len(starts)
    pd: list[float] = []
    pid: list[int] = []
    checked: list[bool] = []
    seen = set()
    for s, dv in zip(starts, np.asarray(d0).tolist()):
        if s in seen:
            continue
        i = bisect.bisect_right(pd, dv)
        pd.insert(i, dv); pid.insert(i, s); checked.insert(i, False)
        seen.add(s)
    while True:
        ui = next((i for i, c in enumerate(checked) if not c and i < ef), -1)
        if ui < 0:
            break
        checked[ui] = True
        v = pid[ui]
        nn = layer.adj[v]
        nn = nn[nn >= 0]
        new = [int(u) for u in nn.tolist() if u not in seen]
        if not new:
            continue
        seen.update(new)
        dd = pq_dist_fn(vids[np.asarray(new, np.int64)])
        n_pq += len(new)
        bound = pd[ef - 1] if len(pd) >= ef else np.inf
        for u, du in zip(new, np.asarray(dd).tolist()):
            if du < bound or len(pd) < ef:
                i = bisect.bisect_right(pd, du)
                pd.insert(i, du); pid.insert(i, u); checked.insert(i, False)
                if len(pd) > 4 * ef:
                    pd.pop(); pid.pop(); checked.pop()
    return pid, n_pq
