"""MACE: higher-order equivariant message passing (ACE) [arXiv:2206.07697].

Assigned config: 2 layers, 128 channels, l_max=2, correlation order 3,
n_rbf=8.  Per layer:

  1. atomic basis  A_i^(l) = sum_j R_l(|r_ij|) * CG . (h_j (x) Y(r_ij))
     (one-particle basis -- same contraction as a NequIP message)
  2. product basis B: channel-wise CG products of A up to correlation 3:
        order 1:  A^(l)
        order 2:  (A (x) A)^(l)      via real CG
        order 3:  ((A (x) A) (x) A)^(l)
     each order/path gets a learned channel mixing; this is the
     O(L^6)->O(L^3)-style contraction done path-by-path (kernel_taxonomy:
     irrep tensor-product regime).
  3. message m_i = sum over basis elements (linear) ; update h <- lin(m)+res.

Readout: scalars -> atom energy; total = segment_sum over graphs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .common import bessel_rbf, edge_mask, edge_vectors, init_mlp, mlp_apply
from .so3 import DIMS, real_cg, sph_harm_jax


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    channels: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 8
    radial_hidden: int = 64


def _paths(l_max: int):
    return [(l1, l2, l3)
            for l1 in range(l_max + 1) for l2 in range(l_max + 1)
            for l3 in range(l_max + 1) if real_cg(l1, l2, l3) is not None]


def init_params(cfg: MACEConfig, key: jax.Array) -> dict:
    paths = _paths(cfg.l_max)
    ks = jax.random.split(key, 4 + cfg.n_layers * (len(paths) + 3 * len(paths) + 4))
    c = cfg.channels
    params = {"embed": jax.random.normal(ks[0], (cfg.n_species, c)) * 0.5,
              "readout": init_mlp(ks[1], [c, c, 1]), "layers": []}
    ki = 2
    for _ in range(cfg.n_layers):
        lp = {"radial": {}, "mix_a": {}, "mix_b2": {}, "mix_b3": {}, "upd": {}}
        for (l1, l2, l3) in paths:
            lp["radial"][f"{l1}{l2}{l3}"] = init_mlp(
                ks[ki], [cfg.n_rbf, cfg.radial_hidden, c]); ki += 1
            lp["mix_b2"][f"{l1}{l2}{l3}"] = (
                jax.random.normal(ks[ki], (c, c)) / np.sqrt(c)); ki += 1
            lp["mix_b3"][f"{l1}{l2}{l3}"] = (
                jax.random.normal(ks[ki], (c, c)) / np.sqrt(c)); ki += 1
        for l in range(cfg.l_max + 1):
            lp["mix_a"][str(l)] = (jax.random.normal(ks[ki], (c, c))
                                   / np.sqrt(c)); ki += 1
            lp["upd"][str(l)] = (jax.random.normal(ks[ki], (c, c))
                                 / np.sqrt(c)); ki += 1
        params["layers"].append(lp)
    return params


def forward_energy(params, cfg: MACEConfig, batch,
                   gather_fn=None, scatter_fn=None) -> jnp.ndarray:
    take = gather_fn or (lambda t, i: t[jnp.clip(i, 0, t.shape[0] - 1)])

    def _default_scat(vals, ix, rows):
        dump2 = jnp.where(ix >= 0, ix, rows)
        return jax.ops.segment_sum(vals, dump2, num_segments=rows + 1)[:rows]
    scat = scatter_fn or _default_scat
    species, pos = batch["species"], batch["pos"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = species.shape[0]
    mask = edge_mask(src)
    unit, r = edge_vectors(pos, src, dst)
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff) * mask[:, None]
    ylm = {l: sph_harm_jax(l, unit) for l in range(cfg.l_max + 1)}
    paths = _paths(cfg.l_max)
    s_clip = jnp.clip(src, 0, n - 1)
    dump = jnp.where(mask, dst, n)
    c = cfg.channels

    feats = {0: params["embed"][jnp.clip(species, 0, cfg.n_species - 1)][:, None, :]}
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((n, DIMS[l], c))

    for lp in params["layers"]:
        # --- 1. atomic basis A ------------------------------------------------
        a = {l: jnp.zeros((n, DIMS[l], c)) for l in range(cfg.l_max + 1)}
        for (l1, l2, l3) in paths:
            cg = jnp.asarray(real_cg(l1, l2, l3), jnp.float32)
            w = mlp_apply(lp["radial"][f"{l1}{l2}{l3}"], rbf)
            f2d = feats[l1].reshape(n, -1)
            v = take(f2d, s_clip).reshape(
                s_clip.shape[0], *feats[l1].shape[1:])
            m = jnp.einsum("kij,eic,ej,ec->ekc", cg, v, ylm[l2], w)
            m = jnp.where(mask[:, None, None], m, 0.0)
            km = m.shape[1]
            agg = scat(m.reshape(m.shape[0], -1),
                       jnp.where(mask, dst, -1), n)
            a[l3] = a[l3] + agg.reshape(n, km, c)
        a = {l: jnp.einsum("nic,cd->nid", a[l], lp["mix_a"][str(l)])
             for l in a}
        # --- 2. product basis B (correlation 2 and 3, channel-wise) -----------
        b = {l: a[l] for l in a}                               # order 1
        a2 = {l: jnp.zeros((n, DIMS[l], c)) for l in a}        # order 2
        for (l1, l2, l3) in paths:
            cg = jnp.asarray(real_cg(l1, l2, l3), jnp.float32)
            t = jnp.einsum("kij,nic,njc->nkc", cg, a[l1], a[l2])
            a2[l3] = a2[l3] + jnp.einsum("nkc,cd->nkd", t,
                                         lp["mix_b2"][f"{l1}{l2}{l3}"])
        if cfg.correlation >= 3:
            for (l1, l2, l3) in paths:
                cg = jnp.asarray(real_cg(l1, l2, l3), jnp.float32)
                t = jnp.einsum("kij,nic,njc->nkc", cg, a2[l1], a[l2])
                b[l3] = b[l3] + jnp.einsum("nkc,cd->nkd", t,
                                           lp["mix_b3"][f"{l1}{l2}{l3}"])
        for l in a2:
            b[l] = b[l] + a2[l]
        # --- 3. update ---------------------------------------------------------
        feats = {l: (feats[l] + jnp.einsum("nic,cd->nid", b[l],
                                           lp["upd"][str(l)]))
                 for l in b}
        feats[0] = jax.nn.silu(feats[0])

    e_atom = mlp_apply(params["readout"], feats[0][:, 0, :])[:, 0]
    gid = batch.get("graph_ids")
    if gid is None:
        return jnp.sum(e_atom, keepdims=True)
    # n_graphs must be static under jit: taken from the energy target shape
    return jax.ops.segment_sum(e_atom, gid, num_segments=batch["energy"].shape[0])


def loss_fn(params, cfg: MACEConfig, batch, gather_fn=None,
            scatter_fn=None) -> jnp.ndarray:
    e = forward_energy(params, cfg, batch, gather_fn=gather_fn,
                       scatter_fn=scatter_fn)
    return jnp.mean((e - batch["energy"].astype(jnp.float32)) ** 2)
