"""Streaming freshness: inserts/deletes over a live BAMG index.

    PYTHONPATH=src python examples/fresh_serving.py

The FreshDiskANN pattern over BAMG (`repro.index.delta`): the disk
index stays frozen; writes land in an in-memory overlay -- inserts are
wired by incremental RobustPrune into copy-on-write adjacency rows,
deletes become tombstones that stay navigable but can never surface.
Every query is served *unified* (frozen base + overlay, one exact
top-k), so a write is visible on the very next read.  A background
`consolidate()` folds the overlay into a fresh build -- edge repair
around deleted nodes, then BNF block re-assignment + block-aware
refinement -- and publishes it through the blue/green deployment
lifecycle: reads never pause, and the swap is atomic.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.distances import exact_knn  # noqa: E402
from repro.core.engine import BAMGParams  # noqa: E402
from repro.data.synthetic import make_vector_dataset  # noqa: E402
from repro.index.delta import DeltaParams, FreshService  # noqa: E402
from repro.serve import EngineConfig  # noqa: E402

K, L = 10, 48


def recall(svc, queries, k=K):
    live_x, live_ext = svc.live_corpus()
    _, rows = exact_knn(live_x, queries, k)
    gt = live_ext[rows]
    ids, _ = svc.search_batch(queries, k, l=L)
    hits = sum(len(set(r.tolist()) & set(g.tolist()))
               for r, g in zip(ids, gt))
    return hits / (len(gt) * k)


def main() -> None:
    ds = make_vector_dataset("fresh", n=2000, d=32, nq=16, k_gt=K,
                             n_clusters=16, seed=0)
    svc = FreshService(tempfile.mkdtemp(prefix="fresh-"),
                       params=BAMGParams(r=16, l_build=32, seed=0),
                       config=EngineConfig(l=L, max_hops=24),
                       delta_params=DeltaParams(r=16, ef=48))

    t0 = time.time()
    svc.bootstrap(ds.base, "gen-0")
    print(f"gen-0: built+published+promoted {len(ds.base)} vectors "
          f"in {time.time()-t0:.0f}s (ACTIVE={svc.manager.active()})")

    # --- writes are visible on the next read --------------------------------
    rng = np.random.default_rng(1)
    new = (ds.base[rng.integers(0, len(ds.base), 100)]
           + 0.02 * rng.standard_normal((100, 32)).astype(np.float32))
    t0 = time.time()
    ext = svc.insert_batch(new)
    print(f"inserted 100 vectors in {time.time()-t0:.2f}s "
          f"(overlay={svc.delta.memory_bytes()/2**10:.0f} KiB)")
    ids, d = svc.search_batch(new[0][None, :], K)
    assert ids[0, 0] == ext[0]
    print(f"new vector findable immediately: id={ids[0, 0]} d={d[0, 0]:.4f}")

    victim = int(ds.gt[0, 0])              # the top-1 of query 0
    svc.delete(victim)
    svc.delete(int(ext[1]))                # deleting fresh writes works too
    ids, _ = svc.search_batch(ds.queries, K)
    assert victim not in set(ids.ravel().tolist())
    print(f"deleted id {victim} gone from results on the next read; "
          f"unified recall@{K}={recall(svc, ds.queries):.3f}")

    # --- consolidation: fold the overlay, swap blue/green -------------------
    t0 = time.time()
    svc.consolidate("gen-1", queries=ds.queries, k=K, min_recall=0.5,
                    keep_builds=2)
    print(f"gen-1: consolidated {svc.n_live} live vectors in "
          f"{time.time()-t0:.0f}s -- published, validated "
          f"(recall={svc.last_validation_recall:.3f}), promoted, hot-swapped")
    print(f"post-swap recall@{K}={recall(svc, ds.queries):.3f}; "
          f"builds kept: {svc.manager.builds()} "
          f"(rollback target {svc.manager.rollback_target()})")

    ids, _ = svc.search_batch(new[0][None, :], K)
    assert ids[0, 0] == ext[0], "external ids are stable across the swap"
    print("external ids stable across id-space compaction -- done")


if __name__ == "__main__":
    main()
