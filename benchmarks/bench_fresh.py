"""Streaming freshness: delta-layer write throughput + recall vs delta size.

Rows (name,value,derived):

  fresh.insert.per_s        overlay insert throughput (beam + RobustPrune
                            wiring + copy-on-write reverse edges)
  fresh.delete.per_s        tombstone throughput (O(1) set insert)
  fresh.delta_<p>pct.*      recall@10 and qps of the *unified* base+delta
                            batched path as the overlay grows to p% of
                            the frozen corpus (exact GT recomputed on the
                            live corpus at every step)
  fresh.delta.memory_mb     overlay footprint at its largest
  fresh.consolidate.wall_s  fold -> publish -> verify -> validate ->
                            promote -> hot swap, end to end
  fresh.post.recall         recall served by the consolidated build
  fresh.scratch.recall      recall of a from-scratch build on the same
                            live corpus -- the parity baseline

Acceptance (asserted, mirrored from tests/test_fresh.py at CI scale):
tombstoned ids never surface at any stage, and post-consolidation recall
matches the from-scratch rebuild within PARITY_TOL (coarser than the
0.01 test bound only because the CI grid runs a handful of queries).
Knobs: REPRO_BENCH_FRESH_INS (total inserts), REPRO_BENCH_N/NQ (common).
"""
import os
import tempfile
import time

import numpy as np

from . import common
from repro.core.distances import exact_knn
from repro.core.engine import BAMGIndex
from repro.index.delta import DeltaParams, FreshService
from repro.serve import BatchedANNEngine, EngineConfig

K = 10
L = 48
PARITY_TOL = float(os.environ.get("REPRO_BENCH_FRESH_TOL", "0.05"))


def _ext_recall(ids: np.ndarray, gt: np.ndarray) -> float:
    hits = sum(len(set(r[:K].tolist()) & set(g[:K].tolist()))
               for r, g in zip(ids, gt))
    return hits / (len(gt) * K)


def _live_gt(svc, queries):
    live_x, live_ext = svc.live_corpus()
    _, rows = exact_knn(live_x, queries, K)
    return live_ext[rows]


def run() -> None:
    regime = "sift-like"
    ds = common.dataset(regime)
    base_idx = common.default_bamg(regime)
    n = len(ds.base)
    n_ins = int(os.environ.get("REPRO_BENCH_FRESH_INS",
                               str(max(48, n // 16))))
    rng = np.random.default_rng(0)

    svc = FreshService(tempfile.mkdtemp(prefix="bench-fresh-"),
                       params=base_idx.params,
                       config=EngineConfig(l=L, max_hops=24, backend="ref"),
                       delta_params=DeltaParams(r=16, ef=48))
    svc.bootstrap(index=base_idx, build_id="gen-0")

    # --- recall-vs-delta-size sweep: grow the overlay in thirds ------------
    ins_vecs = (ds.base[rng.integers(0, n, n_ins)]
                + 0.02 * rng.standard_normal((n_ins, ds.base.shape[1]))
                .astype(np.float32))
    per = max(1, n_ins // 3)
    t_ins, ins_ext = 0.0, []
    for lo in range(0, n_ins, per):
        chunk = ins_vecs[lo:lo + per]
        t0 = time.perf_counter()
        ins_ext.extend(svc.insert_batch(chunk).tolist())
        t_ins += time.perf_counter() - t0
        gt = _live_gt(svc, ds.queries)
        t0 = time.perf_counter()
        ids, _ = svc.search_batch(ds.queries, K, l=L)
        dt = time.perf_counter() - t0
        pct = round(100.0 * svc.delta.n_delta / n, 1)
        common.emit(f"fresh.delta_{pct}pct.recall",
                    round(_ext_recall(ids, gt), 4), f"n_delta={svc.delta.n_delta}")
        common.emit(f"fresh.delta_{pct}pct.qps",
                    round(len(ds.queries) / dt, 1))
    common.emit("fresh.insert.per_s", round(len(ins_ext) / t_ins, 1),
                f"r={svc.delta.params.r};ef={svc.delta.params.ef}")
    common.emit("fresh.delta.memory_mb",
                round(svc.delta.memory_bytes() / 2**20, 3))

    # --- deletes: likely-to-surface base ids + a slice of the fresh ones ---
    dels = sorted(set(ds.gt[:, 0].astype(int).tolist())
                  | set(ins_ext[:len(ins_ext) // 4]))
    t0 = time.perf_counter()
    for e in dels:
        svc.delete(e)
    common.emit("fresh.delete.per_s",
                round(len(dels) / (time.perf_counter() - t0), 1),
                f"n={len(dels)}")
    ids, _ = svc.search_batch(ds.queries, K, l=L)
    assert not (set(ids.ravel().tolist()) & set(dels)), \
        "tombstoned id surfaced pre-consolidation"

    # --- consolidation: fold + full blue/green republish -------------------
    t0 = time.perf_counter()
    svc.consolidate("gen-1", queries=ds.queries, k=K, min_recall=0.0)
    common.emit("fresh.consolidate.wall_s",
                round(time.perf_counter() - t0, 2),
                f"n_live={svc.n_live}")
    assert svc.manager.active() == "gen-1"

    gt = _live_gt(svc, ds.queries)
    ids, _ = svc.search_batch(ds.queries, K, l=L)
    assert not (set(ids.ravel().tolist()) & set(dels)), \
        "tombstoned id surfaced post-consolidation"
    r_post = _ext_recall(ids, gt)
    common.emit("fresh.post.recall", round(r_post, 4),
                f"validated={svc.last_validation_recall:.3f}")

    live_x, live_ext = svc.live_corpus()
    scratch = BAMGIndex.build(live_x, base_idx.params)
    sids, _ = BatchedANNEngine.from_index(
        scratch, svc.config).search_batch(ds.queries, K, l=L)
    r_scratch = _ext_recall(live_ext[sids], gt)
    common.emit("fresh.scratch.recall", round(r_scratch, 4))
    assert abs(r_post - r_scratch) <= PARITY_TOL, \
        (f"consolidation recall parity broken: post={r_post:.4f} "
         f"scratch={r_scratch:.4f} tol={PARITY_TOL}")
