"""Shard placement over a host device mesh.

The alpa device-mesh hierarchy (SNIPPETS.md Snippet 2), collapsed to what
scatter-gather ANN serving needs::

    ServeRuntime                 (the fleet)
    |
    ShardPlacement               (shard/replica -> worker binding)
    |
    MeshWorker                   (one executor pinned to one mesh device)

`ShardPlacement.plan` flattens the device grid of a `repro.launch.mesh`
host mesh (or `jax.devices()` when no mesh is given) into one `MeshWorker`
per device and binds each shard's replica group onto workers round-robin.
Replica 0 of every shard is the caller's engine object *placed*
(`BatchedANNEngine.place`, an in-place device_put) on its worker -- object
identity is preserved so fault hooks (`engine.inject_fault`) and blue/green
hot swaps keep working; replicas > 0 are device-put copies
(`BatchedANNEngine.replicate`).

Health has two granularities.  `ShardHealth` is PR 7's shard-level record
(shared with the `ShardedFrontend` shim: same objects, same `health()`
shape); per-replica up/down lives on the `Replica` itself.  A replica that
raises is marked down and the shard's error counter bumped; the shard only
goes down -- i.e. its RUN/GATHER instructions get masked -- once no
healthy replica remains.  `select()` round-robins query batches over the
healthy replicas of a shard.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np

from ..ann_engine import BatchedANNEngine


@dataclasses.dataclass
class ShardHealth:
    """Mutable per-shard serving state (one entry per replica group)."""
    up: bool = True
    errors: int = 0          # engine calls that raised
    last_error: str = ""


class MeshWorker:
    """One executor bound to a single device of the serving mesh."""

    def __init__(self, worker_id: int, device):
        self.worker_id = worker_id
        self.device = device
        self.replicas: list["Replica"] = []

    def bind(self, replica: "Replica") -> None:
        self.replicas.append(replica)

    def run(self, replica: "Replica", queries: np.ndarray, k: int, *,
            l: Optional[int] = None, max_hops: Optional[int] = None,
            exclude=None):
        """Execute one shard-batch on this worker's engine copy."""
        return replica.engine.search_batch(queries, k, l=l,
                                           max_hops=max_hops,
                                           exclude=exclude)

    def __repr__(self) -> str:
        bound = [(r.shard, r.replica) for r in self.replicas]
        return (f"MeshWorker(id={self.worker_id}, device={self.device}, "
                f"replicas={bound})")


@dataclasses.dataclass
class Replica:
    """One placed copy of a shard's engine, bound to a worker."""
    shard: int
    replica: int
    engine: BatchedANNEngine
    worker: MeshWorker
    up: bool = True
    last_error: str = ""


class ShardPlacement:
    """Binding of S shard replica groups onto mesh workers."""

    def __init__(self, workers: Sequence[MeshWorker],
                 shard_replicas: Sequence[Sequence[Replica]],
                 shard_health: Sequence[ShardHealth]):
        self.workers = list(workers)
        self.shard_replicas = [list(g) for g in shard_replicas]
        self.shard_health = list(shard_health)
        self._rr = [0] * len(self.shard_replicas)

    @classmethod
    def plan(cls, engines: Sequence[BatchedANNEngine], mesh=None,
             n_replicas: int = 1) -> "ShardPlacement":
        """Carve the mesh into workers and bind replica groups round-robin."""
        if n_replicas < 1:
            raise ValueError(f"n_replicas={n_replicas} must be >= 1")
        if not engines:
            raise ValueError("placement needs at least one shard engine")
        devices = (list(mesh.devices.flat) if mesh is not None
                   else list(jax.devices()))
        n_workers = max(1, min(len(devices), len(engines) * n_replicas))
        workers = [MeshWorker(i, d) for i, d in enumerate(devices[:n_workers])]
        groups, health = [], []
        for s, eng in enumerate(engines):
            group = []
            for r in range(n_replicas):
                w = workers[(s * n_replicas + r) % n_workers]
                e = eng.place(w.device) if r == 0 else eng.replicate(w.device)
                rep = Replica(shard=s, replica=r, engine=e, worker=w)
                w.bind(rep)
                group.append(rep)
            groups.append(group)
            health.append(ShardHealth())
        return cls(workers, groups, health)

    @property
    def n_shards(self) -> int:
        return len(self.shard_replicas)

    @property
    def engines(self) -> list[BatchedANNEngine]:
        """Replica-0 engines, shard order (the caller's own objects)."""
        return [g[0].engine for g in self.shard_replicas]

    # --- replica selection --------------------------------------------------
    def select(self, shard: int) -> Optional[Replica]:
        """Next healthy replica of `shard`, round-robin; None if none left."""
        group = self.shard_replicas[shard]
        n = len(group)
        for i in range(n):
            rep = group[(self._rr[shard] + i) % n]
            if rep.up:
                self._rr[shard] = (self._rr[shard] + i + 1) % n
                return rep
        return None

    def record_failure(self, rep: Replica, exc: Exception) -> None:
        """A replica raised: mark it down; the shard masks out only when
        its whole replica group is dead."""
        rep.up, rep.last_error = False, repr(exc)
        h = self.shard_health[rep.shard]
        h.errors, h.last_error = h.errors + 1, repr(exc)
        if not any(r.up for r in self.shard_replicas[rep.shard]):
            h.up = False

    # --- shard-level administration (PR 7 semantics) ------------------------
    def mark_down(self, shard: int, reason: str = "marked down") -> None:
        h = self.shard_health[shard]
        h.up, h.last_error = False, reason

    def mark_up(self, shard: int) -> None:
        """Revive a shard after repair: the whole replica group comes back."""
        self.shard_health[shard].up = True
        for rep in self.shard_replicas[shard]:
            rep.up = True

    def mask(self) -> np.ndarray:
        """(S,) bool: which shards' RUN/GATHER instructions are live."""
        return np.array([h.up for h in self.shard_health], bool)
