"""Pure-jnp oracle: single-token (decode) GQA attention with a KV cache."""
from __future__ import annotations

import jax.numpy as jnp


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     cache_len: jnp.ndarray, scale: float | None = None):
    """q (B, H, Dh); k/v (B, S, Hkv, Dh); cache_len (B,) int32 -> (B, H, Dh).

    H = G * Hkv (grouped-query attention).  Positions >= cache_len masked.
    """
    b, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else dh ** -0.5
    qf = q.astype(jnp.float32).reshape(b, hkv, g, dh) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # scores (B, Hkv, G, S)
    scores = jnp.einsum("bngd,bsnd->bngs", qf, kf)
    mask = jnp.arange(s)[None, :] < cache_len[:, None]       # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jnp.exp(scores - scores.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    out = jnp.einsum("bngs,bsnd->bngd", w, vf)
    return out.reshape(b, h, dh).astype(q.dtype)
