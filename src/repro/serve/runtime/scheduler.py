"""Continuous-batching request scheduler (open-loop arrivals, deadline SLOs).

Continuous batching scaled down to scatter-gather ANN serving: requests
arrive on an *open-loop* timeline (the arrival process never waits for the
server -- the honest way to measure tail latency under offered load, per
the experimental-evaluation literature in PAPERS.md), queue in an
earliest-deadline-first heap, and drain into fixed-shape micro-batches:

- **Formation** pops the `max_batch` earliest deadlines.  A later deadline
  is never served while an earlier one waits (no deadline inversion;
  asserted in tests/test_runtime.py).
- **Padding** tiles every micro-batch up to exactly `max_batch` rows, so
  each beam tier compiles one (B, D) signature for the lifetime of the
  server (the fixed-shape contract of `BatchedANNEngine`).
- **Adaptive beam width** re-triages each popped request by its remaining
  slack: a request whose slack has fallen under `shrink_slack * slo`
  executes on the shrunk `BeamTier` (smaller pool `l` / `max_hops` =
  less work per query), trading recall for latency only when the SLO is
  actually at risk.  Within a formation round the shrunk tier runs first
  (those are the urgent requests).  Shrunk results are flagged
  `degraded` on their `Completion`.

Service time is real wall clock -- the engines actually run -- while only
the arrival timeline is simulated, so a single-process load sweep reports
achieved p50/p99 against offered QPS without a multi-host harness.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Request:
    """One query on the open-loop timeline."""
    rid: int
    query: np.ndarray          # (D,)
    arrival: float             # seconds
    deadline: float            # arrival + SLO


@dataclasses.dataclass(frozen=True)
class BeamTier:
    """Per-call beam overrides (None = the engine's configured value)."""
    l: Optional[int] = None
    max_hops: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    k: int = 10
    max_batch: int = 32        # fixed micro-batch shape (rows are padded)
    slo: float = 0.5           # seconds; deadline = arrival + slo
    shrink_slack: float = 0.5  # slack < shrink_slack*slo -> shrunk tier
    # (full, shrunk) beam tiers; tier l is clamped to >= k at execution
    tiers: tuple = (BeamTier(), BeamTier(l=16, max_hops=8))


@dataclasses.dataclass
class Completion:
    """Served request: answer + timing + how it was served."""
    rid: int
    ids: np.ndarray            # (k,) global ids, -1 pad
    dists: np.ndarray          # (k,) ascending
    arrival: float
    finish: float
    latency: float
    tier: int                  # BeamTier index it executed on
    deadline_met: bool
    degraded: bool             # shrunk beam and/or missed >=1 shard


class RequestQueue:
    """Earliest-deadline-first queue; equal deadlines dequeue FIFO.

    The tie-break is a push-time arrival sequence number, NOT the rid:
    rids are caller-assigned and need not be monotone with arrival order,
    so breaking ties on them would reorder same-deadline requests between
    replays of the same seeded timeline.  The sequence counter makes EDF
    stable by arrival, bit-reproducible run to run."""

    def __init__(self):
        self._heap: list = []
        self._seq = 0              # arrival order of pushes (FIFO tie-break)

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (req.deadline, self._seq, req))
        self._seq += 1

    def pop_batch(self, n: int) -> list[Request]:
        """The n earliest-deadline requests (fewer when the queue drains)."""
        return [heapq.heappop(self._heap)[2]
                for _ in range(min(n, len(self._heap)))]

    def min_deadline(self) -> float:
        return self._heap[0][0] if self._heap else float("inf")

    def __len__(self) -> int:
        return len(self._heap)


def open_loop_arrivals(n: int, qps: float, seed: int = 0,
                       process: str = "poisson") -> np.ndarray:
    """(n,) arrival times at offered `qps` (seeded Poisson or uniform)."""
    if qps <= 0:
        raise ValueError(f"qps={qps} must be > 0")
    if process == "poisson":
        gaps = np.random.default_rng(seed).exponential(1.0 / qps, n)
    elif process == "uniform":
        gaps = np.full(n, 1.0 / qps)
    else:
        raise ValueError(f"unknown arrival process {process!r}")
    return np.cumsum(gaps)


def make_requests(queries: np.ndarray, qps: float, slo: float,
                  n: Optional[int] = None, seed: int = 0,
                  process: str = "poisson") -> list[Request]:
    """Tile `queries` into an n-request open-loop timeline at `qps`."""
    queries = np.atleast_2d(queries)
    n = len(queries) if n is None else n
    arrivals = open_loop_arrivals(n, qps, seed=seed, process=process)
    return [Request(rid=i, query=queries[i % len(queries)],
                    arrival=float(a), deadline=float(a) + slo)
            for i, a in enumerate(arrivals)]


class Scheduler:
    """Drains a RequestQueue into the runtime as deadline-aware batches."""

    def __init__(self, runtime, config: Optional[SchedulerConfig] = None):
        self.runtime = runtime
        self.config = config if config is not None else SchedulerConfig()
        self.queue = RequestQueue()

    # --- triage / formation -------------------------------------------------
    def assign_tier(self, req: Request, now: float) -> int:
        """0 (full beam) unless remaining slack puts the SLO at risk."""
        cfg = self.config
        if len(cfg.tiers) == 1:
            return 0
        slack = req.deadline - now
        return 0 if slack >= cfg.shrink_slack * cfg.slo else len(cfg.tiers) - 1

    def form_microbatches(self, now: float) -> list[tuple[int, list[Request]]]:
        """EDF-pop up to max_batch and group by tier, urgent tiers first.

        Every popped deadline precedes every deadline left in the queue --
        formation never inverts deadlines."""
        popped = self.queue.pop_batch(self.config.max_batch)
        groups: dict[int, list[Request]] = {}
        for r in popped:
            groups.setdefault(self.assign_tier(r, now), []).append(r)
        return [(t, groups[t]) for t in sorted(groups, reverse=True)]

    # --- execution ----------------------------------------------------------
    def _tier_args(self, tier_idx: int) -> dict:
        tier = self.config.tiers[tier_idx]
        l = None if tier.l is None else max(self.config.k, tier.l)
        return {"l": l, "max_hops": tier.max_hops}

    def _execute(self, tier_idx: int, reqs: Sequence[Request]):
        """One fixed-shape runtime call; returns the unpadded rows."""
        cfg = self.config
        q = np.stack([r.query for r in reqs])
        b = len(reqs)
        if b < cfg.max_batch:                    # pad to the compiled shape
            q = np.concatenate([q, np.tile(q[:1], (cfg.max_batch - b, 1))])
        t0 = time.perf_counter()
        ids, dists, status = self.runtime.serve_batch(
            q, cfg.k, with_status=True, **self._tier_args(tier_idx))
        dt = time.perf_counter() - t0
        return ids[:b], dists[:b], status, dt

    def warmup(self, d: int) -> None:
        """Compile every tier's (max_batch, d) signature off the clock."""
        q = np.zeros((self.config.max_batch, d), np.float32)
        for t in range(len(self.config.tiers)):
            self.runtime.serve_batch(q, self.config.k, **self._tier_args(t))

    def run(self, requests: Sequence[Request],
            warmup: bool = True) -> list[Completion]:
        """Serve an open-loop timeline; returns Completions sorted by rid.

        The clock `t` advances by *measured* wall-clock service time of
        each micro-batch; arrivals are admitted whenever `t` passes them,
        so queueing delay under overload shows up in the latencies."""
        reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
        if not reqs:
            return []
        if warmup:
            self.warmup(len(np.atleast_1d(reqs[0].query)))
        out: list[Completion] = []
        t, i, n = 0.0, 0, len(reqs)
        while i < n or len(self.queue):
            if not len(self.queue):              # idle: jump to next arrival
                t = max(t, reqs[i].arrival)
            while i < n and reqs[i].arrival <= t + 1e-12:
                self.queue.push(reqs[i])
                i += 1
            for tier_idx, batch in self.form_microbatches(t):
                ids, dists, status, dt = self._execute(tier_idx, batch)
                t += dt
                for j, r in enumerate(batch):
                    out.append(Completion(
                        rid=r.rid, ids=ids[j], dists=dists[j],
                        arrival=r.arrival, finish=t, latency=t - r.arrival,
                        tier=tier_idx, deadline_met=t <= r.deadline,
                        degraded=bool(status.degraded[j]) or tier_idx > 0))
        out.sort(key=lambda c: c.rid)
        return out


def summarize(completions: Sequence[Completion]) -> dict:
    """Load-sweep row: latency percentiles + service-mix fractions."""
    lat = np.array([c.latency for c in completions])
    span = (max(c.finish for c in completions)
            - min(c.arrival for c in completions))
    p50, p99 = np.percentile(lat, [50, 99])
    return {"n": len(completions),
            "p50_ms": float(p50 * 1e3), "p99_ms": float(p99 * 1e3),
            "achieved_qps": len(completions) / max(span, 1e-12),
            "deadline_hit": float(np.mean([c.deadline_met
                                           for c in completions])),
            "degraded_frac": float(np.mean([c.degraded
                                            for c in completions])),
            "shrunk_frac": float(np.mean([c.tier > 0
                                          for c in completions]))}
