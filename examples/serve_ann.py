"""Batched ANN serving: scatter-gather over sharded BAMG sub-indexes.

    PYTHONPATH=src python examples/serve_ann.py

The distributed serving pattern of DESIGN.md §4: the corpus is partitioned
into S sub-corpora (one per model-parallel shard at scale); each shard
builds its own BAMG sub-index independently (elastic: add/remove shards =
rebuild only the moved partitions); a query fans out to every shard and
the per-shard top-k merge to a global top-k -- one gather per batch, the
TPU analogue of the paper's "every I/O pays for itself".
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.engine import BAMGIndex, BAMGParams  # noqa: E402
from repro.data.synthetic import make_vector_dataset  # noqa: E402


def main() -> None:
    n_shards = 4
    ds = make_vector_dataset("serve", n=4000, d=64, nq=32, k_gt=10, seed=0)

    # partition corpus (round-robin keeps shards balanced)
    owner = np.arange(len(ds.base)) % n_shards
    shards = []
    t0 = time.time()
    for s in range(n_shards):
        ids = np.nonzero(owner == s)[0]
        idx = BAMGIndex.build(ds.base[ids],
                              BAMGParams(alpha=3, beta=1.05, r=16,
                                         l_build=32, knn_k=16, seed=s))
        shards.append((ids, idx))
    print(f"{n_shards} BAMG sub-indexes built in {time.time()-t0:.0f}s "
          f"(independent -> elastic scale-out)")

    k = 10
    hits = 0
    nio = 0
    t0 = time.time()
    for qi, q in enumerate(ds.queries):
        # scatter: local top-k on every shard
        cand_ids, cand_d = [], []
        for ids, idx in shards:
            r = idx.search(q, k=k, l=24)
            cand_ids.append(ids[r.ids])
            cand_d.append(r.dists)
            nio += r.nio
        # gather: merge top-k
        all_ids = np.concatenate(cand_ids)
        all_d = np.concatenate(cand_d)
        top = all_ids[np.argsort(all_d)[:k]]
        hits += len(set(top.tolist()) & set(ds.gt[qi, :k].tolist()))
    n_q = len(ds.queries)
    print(f"global recall@{k}={hits/(n_q*k):.3f}, "
          f"NIO/query (summed over shards)={nio/n_q:.1f}, "
          f"{(time.time()-t0)/n_q*1e3:.1f} ms/query host-side")


if __name__ == "__main__":
    main()
