"""Streaming freshness: delta-layer inserts/deletes over BAMG (ISSUE 9).

Acceptance criteria pinned here:

- **Freshness parity** -- after a seeded insert+delete workload,
  `consolidate()` produces an index whose top-k recall matches a
  from-scratch rebuild on the equivalent live corpus within 0.01 at l=48.
- **Deletes never surface** -- a tombstoned id appears in no pre- or
  post-consolidation result on any path (host Alg-4, batched engine,
  overlay beam).  Fault-injected variants live in test_faults.py.
- **Zero-downtime swap** -- the consolidated build promotes through
  `DeploymentManager` publish -> verify -> validate -> promote and
  `BlueGreenEngine.refresh()`, with correct top-k served *throughout*
  the swap (probed mid-lifecycle, at promote time, before the refresh).

Plus unit coverage of the overlay itself: copy-on-write adjacency (the
frozen base graph is never mutated), bounded overlay degrees, tombstones
navigable-but-masked, stable external ids across compaction.
"""
import numpy as np
import pytest

from repro.core.distances import exact_knn
from repro.core.engine import BAMGIndex, BAMGParams
from repro.index.delta import (DeltaLayer, DeltaParams, FreshBAMGEngine,
                               FreshService, consolidate)
from repro.serve import BatchedANNEngine, EngineConfig

K, L = 10, 48
_CFG = EngineConfig(l=48, max_hops=24, backend="ref")
_PARAMS = BAMGParams(seed=0)


def _ext_recall(ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    """Recall@k over external-id result/gt matrices."""
    hits = sum(len(set(r[:k].tolist()) & set(g[:k].tolist()))
               for r, g in zip(ids, gt))
    return hits / (len(gt) * k)


@pytest.fixture(scope="module")
def base_index(small_corpus):
    return BAMGIndex.build(small_corpus.base, _PARAMS)


# ---------------------------------------------------------------------------
# 1. the delta overlay
# ---------------------------------------------------------------------------
def test_delta_insert_wiring_copy_on_write(small_corpus, base_index):
    ds = small_corpus
    delta = DeltaLayer(base_index, DeltaParams(r=16, ef=48))
    frozen = np.asarray(base_index.graph.adj).copy()
    rng = np.random.default_rng(11)
    picks = rng.integers(0, len(ds.base), 20)
    vecs = ds.base[picks] + 0.02 * rng.standard_normal(
        (20, ds.base.shape[1])).astype(np.float32)
    ids = delta.insert_batch(vecs)
    # inserts get fresh global ids past the frozen corpus
    np.testing.assert_array_equal(
        ids, np.arange(delta.n_base, delta.n_base + 20))
    assert delta.n_delta == 20 and delta.n_total == delta.n_base + 20
    # the frozen base adjacency is never mutated -- overrides shadow it
    np.testing.assert_array_equal(np.asarray(base_index.graph.adj), frozen)
    assert any(u < delta.n_base for u in delta.overrides)  # reverse edges
    # overlay degrees stay bounded by the overlay R
    assert all(len(row) <= 16 for row in delta.overrides.values())
    # every inserted point is immediately findable by its own vector
    for vid, v in zip(ids.tolist(), vecs):
        rids, rd = delta.search(v, k=3)
        assert rids[0] == vid and rd[0] == pytest.approx(0.0, abs=1e-3)
    assert delta.memory_bytes() > 0


def test_delta_tombstone_masked_but_navigable(small_corpus, base_index):
    ds = small_corpus
    delta = DeltaLayer(base_index, DeltaParams(r=16, ef=48))
    # tombstone the exact nearest neighbor of every query: the ids most
    # likely to surface, and hubs whose removal would sever paths
    dead = sorted(set(ds.gt[:, 0].astype(int).tolist()))
    delta.delete_batch(dead)
    assert set(dead) <= delta.tombstones
    for v in dead:
        assert len(delta.neighbors(v)) > 0      # still navigable
    for q, g in zip(ds.queries, ds.gt):
        rids, rd = delta.search(q, k=K)
        assert not (set(rids.tolist()) & set(dead))
        # the beam still walks *through* tombstones: the surviving
        # neighbors behind them are found
        live_gt = [v for v in g.tolist() if v not in delta.tombstones]
        assert set(rids.tolist()) & set(live_gt)
        assert (np.diff(rd) >= 0).all()


def test_delta_delete_validates_and_insert_checks_dim(base_index):
    delta = DeltaLayer(base_index)
    with pytest.raises(KeyError):
        delta.delete(delta.n_total)             # out of range
    with pytest.raises(KeyError):
        delta.delete(-1)
    with pytest.raises(ValueError, match="dim"):
        delta.insert(np.zeros(delta.d + 1, np.float32))


def test_overlay_pressure_warns_once_and_rearms(small_corpus, base_index,
                                                caplog):
    """The pressure guard fires a single warning when inserts + tombstones
    cross `warn_fraction` of the base, stays quiet while pressure
    persists, and re-arms only when the overlay shrinks (fresh layer)."""
    import logging
    ds = small_corpus
    n_base = len(ds.base)
    delta = DeltaLayer(base_index,
                       DeltaParams(r=16, ef=48, warn_fraction=4.5 / n_base))
    rng = np.random.default_rng(5)
    vecs = (ds.base[rng.integers(0, n_base, 6)]
            + 0.02 * rng.standard_normal((6, ds.base.shape[1]))
            .astype(np.float32))
    with caplog.at_level(logging.WARNING, logger="repro.index.delta.layer"):
        delta.insert_batch(vecs[:3])            # 3/n_base: below threshold
        assert not delta.overlay_pressure
        assert caplog.records == []
        delta.delete(0)
        delta.delete(1)                         # 5 writes: crossed
        assert delta.overlay_pressure
        assert delta.overlay_fraction == pytest.approx(5 / n_base)
        warns = [r for r in caplog.records if "overlay" in r.message]
        assert len(warns) == 1
        assert f"{n_base}-point base" in warns[0].message
        delta.insert_batch(vecs[3:])            # still over: no re-warn
        assert len([r for r in caplog.records
                    if "overlay" in r.message]) == 1
    # a fresh layer (what consolidation swaps in) starts re-armed
    fresh = DeltaLayer(base_index, DeltaParams(r=16, warn_fraction=0.25))
    assert fresh.overlay_fraction == 0.0 and not fresh.overlay_pressure


def test_fresh_service_stats(small_corpus, base_index, tmp_path):
    svc = FreshService(str(tmp_path / "depot"), params=_PARAMS,
                       delta_params=DeltaParams(r=16, ef=48,
                                                warn_fraction=0.02))
    svc.bootstrap(index=base_index)
    n0 = len(small_corpus.base)
    s = svc.stats()
    assert s["n_base"] == n0 and s["n_delta"] == 0
    assert s["n_tombstones"] == 0 and s["n_live"] == n0
    assert s["overlay_fraction"] == 0.0 and not s["overlay_pressure"]
    assert s["warn_fraction"] == pytest.approx(0.02)
    assert s["generation"] == 0
    rng = np.random.default_rng(9)
    m = int(np.ceil(0.02 * n0)) + 2
    svc.insert_batch(small_corpus.base[:m]
                     + 0.01 * rng.standard_normal(
                         (m, small_corpus.base.shape[1])).astype(np.float32))
    svc.delete(0)
    s = svc.stats()
    assert s["n_delta"] == m and s["n_tombstones"] == 1
    assert s["n_live"] == n0 + m - 1
    assert s["overlay_fraction"] == pytest.approx((m + 1) / n0)
    assert s["overlay_pressure"]
    assert s["overlay_memory_bytes"] >= svc.delta.memory_bytes()


# ---------------------------------------------------------------------------
# 2. unified base+delta engine (host + batched paths)
# ---------------------------------------------------------------------------
def test_fresh_engine_paths_agree_and_mask_tombstones(small_corpus,
                                                      base_index):
    ds = small_corpus
    delta = DeltaLayer(base_index, DeltaParams(r=16, ef=48))
    eng = BatchedANNEngine.from_index(base_index, _CFG)
    fresh = FreshBAMGEngine(base_index, delta, engine=eng)
    rng = np.random.default_rng(5)
    picks = rng.integers(0, len(ds.base), 30)
    vecs = ds.base[picks] + 0.02 * rng.standard_normal(
        (30, ds.base.shape[1])).astype(np.float32)
    new_ids = delta.insert_batch(vecs)
    dead = set(ds.gt[:, 0].astype(int).tolist()) | set(new_ids[:5].tolist())
    delta.delete_batch(sorted(dead))

    live_x = np.concatenate([ds.base, vecs])
    live_ids = np.asarray([v for v in range(delta.n_total)
                           if v not in dead], np.int64)
    _, gt_rows = exact_knn(live_x[live_ids], ds.queries, K)
    gt = live_ids[gt_rows]

    h_ids = np.stack([fresh.search(q, K, l=L)[0] for q in ds.queries])
    b_ids, b_d = fresh.search_batch(ds.queries, K, l=L)
    for ids in (h_ids, b_ids):
        assert ids.shape == (len(ds.queries), K)
        assert not (set(ids.ravel().tolist()) & dead)   # no tombstone leaks
        assert _ext_recall(ids, gt, K) >= 0.9
    assert (np.diff(np.where(np.isfinite(b_d), b_d, np.inf),
                    axis=1) >= 0).all()
    # a live inserted point dominates a query at its own vector, both paths
    probe = vecs[10]
    assert fresh.search(probe, K, l=L)[0][0] == new_ids[10]
    assert fresh.search_batch(probe[None, :], K)[0][0, 0] == new_ids[10]

    # batched path without an engine is a loud error, not a silent fallback
    with pytest.raises(RuntimeError, match="engine"):
        FreshBAMGEngine(base_index, delta).search_batch(ds.queries, K)


def test_batched_tombstone_mask_matches_exclude_arg(small_corpus, base_index):
    """The engine's standing tombstone mask and the per-call exclude arg
    are the same mechanism: identical results, no recompilation-driven
    drift, and the masked ids never appear."""
    ds = small_corpus
    eng = BatchedANNEngine.from_index(base_index, _CFG)
    dead = ds.gt[:, 0].astype(np.int64)
    a_ids, a_d = eng.search_batch(ds.queries, K, exclude=set(dead.tolist()))
    eng.set_tombstones(dead)
    b_ids, b_d = eng.search_batch(ds.queries, K)
    np.testing.assert_array_equal(a_ids, b_ids)
    np.testing.assert_array_equal(a_d, b_d)
    assert not (set(b_ids.ravel().tolist()) & set(dead.tolist()))
    # clearing the mask restores the unmasked answers
    eng.set_tombstones([])
    c_ids, _ = eng.search_batch(ds.queries, K)
    assert set(c_ids[:, 0].tolist()) & set(dead.tolist())


def test_consolidate_requires_live_points(base_index):
    delta = DeltaLayer(base_index)
    delta.delete_batch(np.arange(delta.n_total))
    with pytest.raises(ValueError, match="live"):
        consolidate(base_index, delta)


# ---------------------------------------------------------------------------
# 3. the full service lifecycle (acceptance criteria)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def lifecycle(small_corpus, tmp_path_factory):
    """One seeded insert+delete workload driven through bootstrap ->
    serve -> consolidate -> hot swap, with probes at every stage."""
    ds = small_corpus
    rng = np.random.default_rng(7)
    d = ds.base.shape[1]
    # 32 probe queries: granularity 1/(32*K) ~ 0.003 << the 0.01 bound
    queries = np.concatenate([
        ds.queries,
        ds.base[rng.integers(0, len(ds.base), 20)]
        + 0.05 * rng.standard_normal((20, d)).astype(np.float32)])

    svc = FreshService(str(tmp_path_factory.mktemp("fresh")),
                       params=_PARAMS, config=_CFG,
                       delta_params=DeltaParams(r=16, ef=48))
    svc.bootstrap(ds.base, "gen-0")

    picks = rng.integers(0, len(ds.base), 60)
    ins_vecs = ds.base[picks] + 0.02 * rng.standard_normal(
        (60, d)).astype(np.float32)
    ins_ext = svc.insert_batch(ins_vecs)
    # delete likely-to-surface base points plus a slice of the fresh ones
    del_ext = sorted(set(ds.gt[:, 0].astype(int).tolist())
                     | set(ins_ext[:10].tolist()))
    for e in del_ext:
        svc.delete(e)

    pre_ids, _ = svc.search_batch(queries, K, l=L)
    pre_host = np.stack([svc.search(q, K, l=L)[0] for q in queries])

    live_x, live_ext = svc.live_corpus()
    _, gt_rows = exact_knn(live_x, queries, K)
    gt_ext = live_ext[gt_rows]

    # probe *during* the swap: at promote time the new build is published
    # and verified but the blue engine has not refreshed -- reads must
    # still come from the old base+delta, bit-identical to before
    probes = {}
    orig_promote = svc.manager.promote

    def probing_promote(build_id):
        probes["during"], _ = svc.search_batch(queries, K, l=L)
        return orig_promote(build_id)

    svc.manager.promote = probing_promote
    try:
        svc.consolidate("gen-1", queries=queries, k=K, min_recall=0.5)
    finally:
        del svc.manager.promote

    post_ids, _ = svc.search_batch(queries, K, l=L)
    post_host = np.stack([svc.search(q, K, l=L)[0] for q in queries])

    scratch = BAMGIndex.build(live_x, _PARAMS)
    scratch_ids = live_ext[np.stack(
        [np.pad(r.ids[:K], (0, K - min(K, len(r.ids))))
         for r in (scratch.search(q, k=K, l=L) for q in queries)])]

    return dict(svc=svc, queries=queries, gt_ext=gt_ext,
                ins_vecs=ins_vecs, ins_ext=ins_ext, del_ext=set(del_ext),
                pre_ids=pre_ids, pre_host=pre_host, probes=probes,
                post_ids=post_ids, post_host=post_host,
                scratch_ids=scratch_ids, n_live=len(live_ext))


def test_deletes_never_surface_any_stage(lifecycle):
    lc = lifecycle
    for ids in (lc["pre_ids"], lc["pre_host"], lc["probes"]["during"],
                lc["post_ids"], lc["post_host"]):
        assert not (set(ids.ravel().tolist()) & lc["del_ext"])


def test_inserts_visible_before_and_after_consolidation(lifecycle):
    lc, svc = lifecycle, lifecycle["svc"]
    live = [i for i in range(len(lc["ins_ext"]))
            if int(lc["ins_ext"][i]) not in lc["del_ext"]][:5]
    for i in live:
        ids, d = svc.search_batch(lc["ins_vecs"][i][None, :], K)
        assert ids[0, 0] == lc["ins_ext"][i]
        assert d[0, 0] == pytest.approx(0.0, abs=1e-3)
        eid, dh = svc.search(lc["ins_vecs"][i], K, l=L)
        assert eid[0] == lc["ins_ext"][i]


def test_freshness_parity_with_from_scratch_rebuild(lifecycle):
    """The acceptance bound: consolidated recall within 0.01 of a
    from-scratch build on the identical live corpus, same l, same k."""
    lc = lifecycle
    r_cons = _ext_recall(lc["post_host"], lc["gt_ext"], K)
    r_scratch = _ext_recall(lc["scratch_ids"], lc["gt_ext"], K)
    assert r_scratch >= 0.9                    # the baseline itself is sane
    assert abs(r_cons - r_scratch) <= 0.01
    # the batched path over the consolidated build holds recall too
    assert _ext_recall(lc["post_ids"], lc["gt_ext"], K) >= r_scratch - 0.05


def test_swap_serves_correct_topk_throughout(lifecycle):
    """Reads probed mid-lifecycle (publish done, promote in flight,
    refresh not yet run) are bit-identical to pre-consolidation state:
    no window where a delete resurfaces or an insert vanishes."""
    lc = lifecycle
    np.testing.assert_array_equal(lc["probes"]["during"], lc["pre_ids"])
    # pre- and post-swap answers are both high-recall against exact truth
    assert _ext_recall(lc["pre_ids"], lc["gt_ext"], K) >= 0.85
    assert _ext_recall(lc["post_ids"], lc["gt_ext"], K) >= 0.85


def test_consolidated_build_promoted_with_lineage(lifecycle):
    svc = lifecycle["svc"]
    dm = svc.manager
    assert dm.active() == "gen-1"
    assert dm.history() == ["gen-0", "gen-1"]
    assert dm.rollback_target() == "gen-0"     # old build kept for rollback
    man = dm.manifest("gen-1")
    assert man.meta["generation"] == 1
    assert man.meta["n_delta"] == 60
    assert svc.last_validation_recall >= 0.5
    assert man.n == lifecycle["n_live"]
    dm.verify("gen-1")                         # artifact checksums hold
    # the service rewired onto an empty overlay after the swap
    assert svc.delta.n_delta == 0 and not svc.delta.tombstones
    assert svc.n_live == lifecycle["n_live"]


def test_external_ids_stable_across_compaction(lifecycle):
    """The same external id resolves to the same vector after the swap."""
    lc, svc = lifecycle, lifecycle["svc"]
    live = [i for i in range(len(lc["ins_ext"]))
            if int(lc["ins_ext"][i]) not in lc["del_ext"]]
    for i in live[::7]:
        e = int(lc["ins_ext"][i])
        internal = svc._int_of_ext[e]
        np.testing.assert_allclose(svc.delta.vector(internal),
                                   lc["ins_vecs"][i], atol=1e-5)
    # deleted external ids are gone from the map entirely
    assert not (set(svc._int_of_ext) & lc["del_ext"])
    with pytest.raises(KeyError):
        svc.delete(next(iter(lc["del_ext"])))


def test_second_epoch_continues_after_swap(lifecycle):
    """The rewired service accepts the next epoch of writes immediately."""
    lc, svc = lifecycle, lifecycle["svc"]
    rng = np.random.default_rng(23)
    v = (lc["ins_vecs"][0] + 0.01
         * rng.standard_normal(len(lc["ins_vecs"][0])).astype(np.float32))
    e = svc.insert(v)
    assert e == svc._next_ext - 1              # counter keeps climbing
    ids, _ = svc.search_batch(v[None, :], K)
    assert ids[0, 0] == e
    svc.delete(e)
    ids, _ = svc.search_batch(v[None, :], K)
    assert e not in set(ids.ravel().tolist())
