"""Deterministic synthetic data generators for every assigned architecture
family + the paper's vector-search workloads.

Everything is a pure function of (seed, step) so the pipeline is
restart-safe: after checkpoint restore at step s, batch s+1 is identical to
what an uninterrupted run would have produced (see data/pipeline.py and
train/checkpoint.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.distances import exact_knn


# ---------------------------------------------------------------------------
# Vector-search corpora (paper §5 regimes)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class VectorDataset:
    name: str
    base: np.ndarray      # (n, d) float32
    queries: np.ndarray   # (nq, d) float32
    gt: np.ndarray        # (nq, k_gt) int64 exact nearest neighbors


def clustered_vectors(n: int, d: int, n_clusters: int = 64, spread: float = 4.0,
                      seed: int = 0) -> np.ndarray:
    """Clustered Gaussian corpus -- the standard ANN difficulty regime."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32) * spread
    assign = rng.integers(0, n_clusters, n)
    return (centers[assign] + rng.normal(size=(n, d)).astype(np.float32)).astype(np.float32)


def make_vector_dataset(name: str, n: int, d: int, nq: int, k_gt: int = 100,
                        n_clusters: int = 64, seed: int = 0) -> VectorDataset:
    """Corpus + held-out queries from the same mixture + exact ground truth."""
    base = clustered_vectors(n + nq, d, n_clusters=n_clusters, seed=seed)
    x, q = base[:n], base[n:]
    _, gt = exact_knn(x, q, min(k_gt, n))
    return VectorDataset(name=name, base=x, queries=q, gt=gt.astype(np.int64))


# Paper-analogue regimes (dimension mirrors the real dataset; n scaled to
# what the host simulator handles comfortably -- DESIGN.md §7).
PAPER_REGIMES = {
    "sift-like": dict(d=128, n_clusters=64),    # SIFT1M
    "gist-like": dict(d=960, n_clusters=32),    # GIST: 4 KB block ~ 1 vector
    "deep-like": dict(d=256, n_clusters=64),    # DEEP1M
    "glove-like": dict(d=100, n_clusters=64),   # GLOVE
    "msong-like": dict(d=420, n_clusters=32),   # MSONG
    "crawl-like": dict(d=300, n_clusters=48),   # CRAWL
}


def paper_dataset(regime: str, n: int = 8000, nq: int = 50, seed: int = 0) -> VectorDataset:
    cfg = PAPER_REGIMES[regime]
    return VectorDataset(
        **{"name": regime,
           **dataclasses.asdict(make_vector_dataset(regime, n, cfg["d"], nq,
                                                    n_clusters=cfg["n_clusters"],
                                                    seed=seed))})


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------
def lm_batch(step: int, batch: int, seq_len: int, vocab: int, seed: int = 0):
    """Deterministic (tokens, labels) for one step: Zipf-ish unigram stream."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # Zipf-like marginal over the vocab (heavy head, long tail)
    u = rng.random((batch, seq_len + 1))
    toks = np.minimum((vocab * (u ** 3)), vocab - 1).astype(np.int32)
    return toks[:, :-1], toks[:, 1:]


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class GraphBatch:
    node_feat: np.ndarray   # (n_nodes, d_feat) float32
    edge_src: np.ndarray    # (n_edges,) int32
    edge_dst: np.ndarray    # (n_edges,) int32
    edge_feat: np.ndarray   # (n_edges, d_edge) float32
    labels: np.ndarray      # (n_nodes,) int32 or (n_nodes, d_out) float32
    pos: np.ndarray | None = None   # (n_nodes, 3) for geometric GNNs


def random_graph(n_nodes: int, n_edges: int, d_feat: int, d_edge: int = 8,
                 n_classes: int = 16, seed: int = 0, geometric: bool = False) -> GraphBatch:
    """Degree-skewed random graph; geometric=True adds 3D positions and
    builds edges by proximity (radius-graph style, molecule regime)."""
    rng = np.random.default_rng(seed)
    if geometric:
        pos = rng.normal(size=(n_nodes, 3)).astype(np.float32) * (n_nodes ** (1 / 3))
        # kNN edges in 3D
        k = max(1, min(n_nodes - 1, n_edges // n_nodes))
        d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        nbr = np.argsort(d2, axis=1)[:, :k]
        src = np.repeat(np.arange(n_nodes), k).astype(np.int32)
        dst = nbr.reshape(-1).astype(np.int32)
        src, dst = src[:n_edges], dst[:n_edges]
        if len(src) < n_edges:  # pad by repeating
            reps = -(-n_edges // len(src))
            src = np.tile(src, reps)[:n_edges]
            dst = np.tile(dst, reps)[:n_edges]
    else:
        pos = None
        # preferential-attachment-ish skew
        w = 1.0 / (1.0 + np.arange(n_nodes))
        w /= w.sum()
        src = rng.choice(n_nodes, size=n_edges, p=w).astype(np.int32)
        dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    node_feat = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    edge_feat = rng.normal(size=(n_edges, d_edge)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    return GraphBatch(node_feat=node_feat, edge_src=src, edge_dst=dst,
                      edge_feat=edge_feat, labels=labels, pos=pos)


def molecules_batch(batch: int, n_nodes: int, n_edges: int, seed: int = 0):
    """Batched small molecules as one disjoint-union graph (+ graph ids)."""
    gs = [random_graph(n_nodes, n_edges, d_feat=16, seed=seed * 1000 + i,
                       geometric=True) for i in range(batch)]
    off = np.arange(batch) * n_nodes
    return GraphBatch(
        node_feat=np.concatenate([g.node_feat for g in gs]),
        edge_src=np.concatenate([g.edge_src + o for g, o in zip(gs, off)]).astype(np.int32),
        edge_dst=np.concatenate([g.edge_dst + o for g, o in zip(gs, off)]).astype(np.int32),
        edge_feat=np.concatenate([g.edge_feat for g in gs]),
        labels=np.concatenate([g.labels for g in gs]),
        pos=np.concatenate([g.pos for g in gs]),
    ), np.repeat(np.arange(batch), n_nodes).astype(np.int32)


# ---------------------------------------------------------------------------
# RecSys event streams (DIN)
# ---------------------------------------------------------------------------
def din_batch(step: int, batch: int, seq_len: int, n_items: int, n_cates: int,
              seed: int = 0):
    """(hist_items, hist_cates, hist_len, target_item, target_cate, label)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 7]))
    u = rng.random((batch, seq_len))
    hist_items = np.minimum(n_items * (u ** 2), n_items - 1).astype(np.int32)
    hist_cates = (hist_items % n_cates).astype(np.int32)
    hist_len = rng.integers(1, seq_len + 1, batch).astype(np.int32)
    target_item = np.minimum(n_items * (rng.random(batch) ** 2), n_items - 1).astype(np.int32)
    target_cate = (target_item % n_cates).astype(np.int32)
    # label correlates with whether target's category appears in history
    mask = np.arange(seq_len)[None, :] < hist_len[:, None]
    seen = ((hist_cates == target_cate[:, None]) & mask).any(1)
    noise = rng.random(batch) < 0.15
    label = (seen ^ noise).astype(np.float32)
    return hist_items, hist_cates, hist_len, target_item, target_cate, label
