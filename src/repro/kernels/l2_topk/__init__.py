from .ops import l2_topk  # noqa: F401
from .ref import l2_topk_ref  # noqa: F401
