"""Paper core: BMRNG/BAMG graph construction, storage layout, search."""
