"""ANN serving driver: build a BAMG index and serve batched queries.

  PYTHONPATH=src python -m repro.launch.serve --n 4000 --d 128 \
      --queries 100 --k 10 --l 40

Builds the full paper stack (NSG -> BNF -> BAMG -> nav graph -> decoupled
layout) on a synthetic corpus, serves queries through Algorithm 4 on the
I/O simulator, and prints recall / NIO / simulated QPS vs the Starling and
DiskANN baselines (--compare).
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--l", type=int, default=40)
    ap.add_argument("--alpha", type=int, default=3)
    ap.add_argument("--beta", type=float, default=1.05)
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--save", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..core.engine import (BAMGIndex, BAMGParams, DiskANNIndex,
                               DiskANNParams, StarlingIndex, StarlingParams)
    from ..data.synthetic import make_vector_dataset

    ds = make_vector_dataset("serve", args.n, args.d, args.queries,
                             k_gt=args.k, seed=args.seed)
    t0 = time.time()
    idx = BAMGIndex.build(ds.base, BAMGParams(alpha=args.alpha,
                                              beta=args.beta, seed=args.seed))
    print(f"BAMG built in {time.time()-t0:.1f}s: "
          f"{idx.graph.members.shape[0]} blocks x {idx.graph.capacity} cap, "
          f"nav layers={idx.nav.n_layers if idx.nav else 0}, "
          f"index {idx.index_bytes()/2**20:.1f} MiB, "
          f"memory {idx.memory_bytes()/2**20:.1f} MiB")
    st = idx.search_batch(ds.queries, k=args.k, l=args.l, gt=ds.gt)
    print(f"BAMG     recall@{args.k}={st.recall:.3f} NIO={st.mean_nio:.1f} "
          f"(graph {st.mean_graph_reads:.1f} + vec {st.mean_vector_reads:.1f}) "
          f"QPS~{st.qps:.0f}")
    if args.save:
        idx.save(args.save)
        print(f"saved -> {args.save}")

    if args.compare:
        t0 = time.time()
        sl = StarlingIndex.build(ds.base, StarlingParams(seed=args.seed))
        ss = sl.search_batch(ds.queries, k=args.k, l=args.l, gt=ds.gt)
        print(f"Starling recall@{args.k}={ss.recall:.3f} NIO={ss.mean_nio:.1f} "
              f"QPS~{ss.qps:.0f}  (built {time.time()-t0:.0f}s)")
        t0 = time.time()
        da = DiskANNIndex.build(ds.base, DiskANNParams(seed=args.seed))
        sd = da.search_batch(ds.queries, k=args.k, l=args.l, gt=ds.gt)
        print(f"DiskANN  recall@{args.k}={sd.recall:.3f} NIO={sd.mean_nio:.1f} "
              f"QPS~{sd.qps:.0f}  (built {time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
