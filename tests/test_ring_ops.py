"""Distributed ring gather / scatter primitives vs dense oracles.

Subprocess-based (needs 8 fake devices before jax init), like
test_sharded.py.
"""
import os
import subprocess
import sys

FLAGS = "--xla_force_host_platform_device_count=8"


def _run(snippet: str, timeout=900):
    env = dict(os.environ, XLA_FLAGS=FLAGS, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=timeout,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.models.gnn.ring_gather import ring_gather, ring_scatter_add
from repro.utils.sharding import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
E, d, T = 64, 16, 200
table = jnp.asarray(rng.normal(size=(E, d)), jnp.float32)
idx = jnp.asarray(rng.integers(-1, E, (T,)), jnp.int32)
AX = ("data", "model")
"""


def test_ring_gather_fwd_and_vjp():
    _run(PRELUDE + """
def f(tab, ix):
    return shard_map(lambda t, i: ring_gather(t, i, AX), mesh=mesh,
                     in_specs=(P(AX, None), P(AX)), out_specs=P(AX, None),
                     check_rep=False)(tab, ix)
out = jax.jit(f)(table, idx)
ref = jnp.where(idx[:, None] >= 0, table[jnp.clip(idx, 0, E-1)], 0.0)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
g = jax.jit(jax.grad(lambda t: jnp.sum(f(t, idx) ** 2)))(table)
g_ref = jax.grad(lambda t: jnp.sum(jnp.where(
    idx[:, None] >= 0, t[jnp.clip(idx, 0, E-1)], 0.0) ** 2))(table)
np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-5,
                           atol=1e-5)
print("ok")
""")


def test_ring_scatter_fwd_and_vjp():
    _run(PRELUDE + """
vals = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
def f(v, ix):
    return shard_map(lambda vv, i: ring_scatter_add(vv, i, AX, E // 8),
                     mesh=mesh, in_specs=(P(AX, None), P(AX)),
                     out_specs=P(AX, None), check_rep=False)(v, ix)
out = jax.jit(f)(vals, idx)
ref = jnp.zeros((E, d)).at[jnp.where(idx >= 0, idx, E)].add(
    jnp.where(idx[:, None] >= 0, vals, 0.0), mode="drop")
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                           atol=1e-5)
g = jax.jit(jax.grad(lambda v: jnp.sum(f(v, idx) ** 2)))(vals)
g_ref = jax.grad(lambda v: jnp.sum(jnp.zeros((E, d)).at[
    jnp.where(idx >= 0, idx, E)].add(
    jnp.where(idx[:, None] >= 0, v, 0.0), mode="drop") ** 2))(vals)
np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-5,
                           atol=1e-5)
print("ok")
""")
