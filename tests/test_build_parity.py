"""Seeded parity suite: the batched builders (`repro.build`) pinned to the
host reference oracle (`repro.core.graph_build` / `repro.core.bamg`).

Three tiers of agreement:
- vectorized RobustPrune: *identical* kept edge lists given the same
  candidate pools;
- batched BAMG refinement: *bit-identical* adjacency given the same base
  graph + blocks (only the intra-block probes move to device);
- full `backend="batched"` vs `backend="host"` builds: recall@10 within
  +/-0.01 under identical search parameters (the frontier's fixed-hop
  termination makes candidate pools a near-superset, not a bit-copy).
"""
import numpy as np
import pytest

from repro.build import BuildConfig, GraphBuilder, robust_prune_batch
from repro.build.bamg_refine import refine_bamg_batched
from repro.build.frontier import frontier_pools
from repro.build.knn import clustered_knn_graph
from repro.core.bamg import build_bamg_from
from repro.core.block_assign import bnf_blocks
from repro.core.distances import knn_graph, medoid
from repro.core.graph_build import (_dists_to, build_nsg, greedy_search,
                                    robust_prune)


def _points(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


@pytest.fixture(scope="module")
def base_nsg(small_corpus):
    """Host NSG + BNF blocks on the shared test corpus."""
    x = small_corpus.base
    adj, entry = build_nsg(x, r=12, l_build=24, knn_k=12)
    blocks = bnf_blocks(adj, 16, seed=0)
    return x, adj, entry, blocks


# ---------------------------------------------------------------------------
# RobustPrune: identical edge sets given the same pools
# ---------------------------------------------------------------------------
def test_robust_prune_batch_matches_host_given_same_pools():
    x = _points(400, 24, seed=3)
    knn = knn_graph(x, 12)
    med = medoid(x)
    for p in range(0, 400, 37):
        vis_ids, _ = greedy_search(x, knn, med, x[p], ef=24)
        cand = np.unique(np.concatenate(
            [vis_ids.astype(np.int64),
             knn[p][knn[p] >= 0].astype(np.int64)]))
        cand = cand[cand != p]
        cd = _dists_to(x, cand, x[p])
        for r, alpha in ((8, 1.0), (12, 1.2)):
            host_kept = robust_prune(x, p, cand, cd, r, alpha=alpha)
            batched = robust_prune_batch(
                x, np.array([p]), cand[None, :].astype(np.int32),
                cd[None, :].astype(np.float32), r=r, alpha=alpha)[0]
            batched = batched[batched >= 0]
            assert batched.tolist() == host_kept.tolist(), (p, r, alpha)


def test_robust_prune_batch_handles_pads_self_and_duplicates():
    """Raw candidate rows (pads, self, repeats) reduce to np.unique
    semantics -- each batch row must match the host run on its clean pool."""
    x = _points(120, 8, seed=5)
    rng = np.random.default_rng(7)
    b, c, r = 6, 30, 6
    p_ids = rng.choice(120, size=b, replace=False)
    cand = rng.integers(0, 120, size=(b, c)).astype(np.int32)
    cand[:, -4:] = -1
    cand[:, 0] = p_ids                       # self candidates must drop
    cand[:, 1] = cand[:, 2]                  # duplicate ids collapse
    out = robust_prune_batch(x, p_ids, cand, None, r=r, alpha=1.1)
    for i, p in enumerate(p_ids.tolist()):
        clean = np.unique(cand[i][cand[i] >= 0].astype(np.int64))
        clean = clean[clean != p]
        cd = _dists_to(x, clean, x[p])
        host_kept = robust_prune(x, p, clean, cd, r, alpha=1.1)
        got = out[i][out[i] >= 0]
        assert got.tolist() == host_kept.tolist(), i


# ---------------------------------------------------------------------------
# BAMG refinement: bit-identical adjacency
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("occlusion_ref", ["rule", "alg2"])
@pytest.mark.parametrize("beta", [1.0, 1.05])
def test_refine_bamg_batched_bit_identical(base_nsg, occlusion_ref, beta):
    x, adj, entry, blocks = base_nsg
    host = build_bamg_from(x, adj, entry, blocks, 16, alpha=3, beta=beta,
                           occlusion_ref=occlusion_ref)
    bat = refine_bamg_batched(x, adj, entry, blocks, 16, alpha=3, beta=beta,
                              occlusion_ref=occlusion_ref)
    assert np.array_equal(host.adj, bat.adj)
    assert np.array_equal(host.blocks, bat.blocks)
    assert np.array_equal(host.members, bat.members)


def test_refine_bamg_batched_respects_ablation_flags(base_nsg):
    x, adj, entry, blocks = base_nsg
    host = build_bamg_from(x, adj, entry, blocks, 16, alpha=2, beta=1.0,
                           sibling_edges=False, max_degree=10)
    bat = refine_bamg_batched(x, adj, entry, blocks, 16, alpha=2, beta=1.0,
                              sibling_edges=False, max_degree=10)
    assert np.array_equal(host.adj, bat.adj)


# ---------------------------------------------------------------------------
# Full builds: recall parity under identical search parameters
# ---------------------------------------------------------------------------
def _graph_recall(x, graph, queries, gt, l=64):
    from repro.core.engine import BAMGIndex, BAMGParams
    from repro.core.pq import train_pq
    from repro.core.storage import DecoupledStorage

    codec = train_pq(x, m=8, seed=0)
    idx = BAMGIndex(x, graph, codec, codec.encode(x),
                    DecoupledStorage(x, graph.adj, graph.blocks,
                                     graph.members),
                    None, BAMGParams(r=12, use_nav=False))
    st = idx.search_batch(queries, k=10, l=l, gt=gt)
    return st.recall, st.mean_nio


def test_backend_recall_within_budget(small_corpus):
    ds = small_corpus
    graphs = {}
    for backend in ("host", "batched"):
        gb = GraphBuilder(BuildConfig(backend=backend))
        graphs[backend] = gb.build_bamg(ds.base, 16, alpha=3, beta=1.05,
                                        r=12, l_build=24, knn_k=12,
                                        max_degree=12)
    rec = {}
    for backend, g in graphs.items():
        rec[backend], _ = _graph_recall(ds.base, g, ds.queries, ds.gt)
    # nav-less medoid entry + coarse PQ: ~0.7 absolute here; the assertion
    # that matters is the backend delta (acceptance budget +/-0.01)
    assert rec["host"] >= 0.6, rec
    assert abs(rec["batched"] - rec["host"]) <= 0.01, rec


def test_batched_vamana_reachable_and_degree_bounded():
    x = _points(300, 8, seed=11)
    gb = GraphBuilder(BuildConfig(backend="batched", batch_size=64))
    adj, entry = gb.build_vamana(x, r=12, l_build=24)
    assert adj.shape == (300, 12)
    seen = np.zeros(len(x), bool)
    stack = [entry]
    seen[entry] = True
    while stack:
        v = stack.pop()
        for u in adj[v]:
            if u >= 0 and not seen[u]:
                seen[u] = True
                stack.append(int(u))
    assert seen.mean() > 0.98


# ---------------------------------------------------------------------------
# Subsystem contracts
# ---------------------------------------------------------------------------
def test_frontier_pools_sorted_unique_valid():
    x = _points(200, 8, seed=13)
    knn = knn_graph(x, 8)
    med = medoid(x)
    ids, d = frontier_pools(x, knn, [med], np.arange(40), ef=16, batch=16)
    # output width = visited capacity (hops * width), not the beam ef
    assert ids.shape == d.shape and ids.shape[0] == 40
    assert ids.shape[1] >= 16
    for i in range(40):
        valid = ids[i] >= 0
        dv = d[i][valid]
        assert np.all(np.diff(dv) >= 0), "pool must be ascending"
        assert len(set(ids[i][valid].tolist())) == valid.sum(), "no dups"
        assert ids[i][valid].max() < 200
        assert np.all(np.isinf(d[i][~valid]))


def test_clustered_knn_matches_exact_on_probed_neighbors():
    """On clustered corpora (the paper regimes) the probed top-k recovers
    nearly all exact neighbors; uniform corpora need more probes or
    `knn_mode="exact"` (documented tradeoff)."""
    from repro.data.synthetic import make_vector_dataset

    ds = make_vector_dataset("knn-test", n=2500, d=24, nq=1, k_gt=1,
                             n_clusters=25, seed=17)
    x = ds.base
    approx = clustered_knn_graph(x, 8, seed=0)
    exact = knn_graph(x, 8)
    assert approx.shape == exact.shape and approx.dtype == np.int32
    n = len(x)
    overlap = np.mean([
        len(set(approx[i][approx[i] >= 0].tolist())
            & set(exact[i].tolist())) / 8 for i in range(n)])
    assert overlap >= 0.9, overlap
    for i in range(0, n, 97):
        row = approx[i][approx[i] >= 0]
        assert i not in row.tolist()
        assert len(set(row.tolist())) == len(row)


def test_build_config_rejects_unknown_backend():
    with pytest.raises(ValueError):
        BuildConfig(backend="gpu")


def test_engine_builds_accept_backend_knob(small_corpus):
    from repro.core.engine import BAMGIndex, BAMGParams

    ds = small_corpus
    idx = BAMGIndex.build(ds.base, BAMGParams(
        alpha=3, beta=1.05, r=16, l_build=32, knn_k=16, use_nav=False,
        build_backend="batched"))
    st = idx.search_batch(ds.queries, k=10, l=64, gt=ds.gt)
    assert st.recall >= 0.9, st
