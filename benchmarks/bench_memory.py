"""Paper Fig. 10: in-memory footprint (PQ codes + nav structures)."""
from . import common


def run(regimes=("sift-like",)) -> None:
    for regime in regimes:
        for name, idx in (("bamg", common.default_bamg(regime)),
                          ("starling", common.starling_index(regime)),
                          ("diskann", common.diskann_index(regime))):
            common.emit(f"fig10_mem.{regime}.{name}",
                        round(idx.memory_bytes() / 2 ** 20, 3), "MiB")


if __name__ == "__main__":
    run()
