"""Distributed mesh serving runtime (alpa-style, scaled to ANN serving).

Three layers, each its own module:

- `placement` -- `ShardPlacement` binds shard replica groups onto
  `MeshWorker`s (one per device of a `repro.launch.mesh` host mesh),
  device-putting engine arrays per worker; round-robin replica selection
  with PR 7's `ShardHealth` folded in.
- `instructions` -- the static SCATTER / RUN / GATHER / MERGE program
  compiled once per fleet topology and executed by
  `InstructionInterpreter`; dead shards are instruction *masks*, not
  try/except control flow.
- `scheduler` -- `RequestQueue`/`Scheduler`: open-loop arrivals with
  deadlines, EDF micro-batch formation padded to the engines' fixed
  shapes, and per-query adaptive beam width (shrink `l`/`max_hops` for
  near-deadline queries) to hold a p99 SLO.

`runtime.ServeRuntime` is the facade tying them together; the legacy
`repro.serve.ShardedFrontend` is a thin compatibility shim over it.
"""
from .instructions import (Instruction, InstructionInterpreter,  # noqa: F401
                           Opcode, ServeStatus, compile_program,
                           merge_topk, pad_cols)
from .placement import (MeshWorker, Replica, ShardHealth,  # noqa: F401
                        ShardPlacement)
from .runtime import ServeRuntime, build_shard_fleet  # noqa: F401
from .scheduler import (BeamTier, Completion, Request,  # noqa: F401
                        RequestQueue, Scheduler, SchedulerConfig,
                        make_requests, open_loop_arrivals, summarize)
