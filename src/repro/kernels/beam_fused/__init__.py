from .kernel import (beam_hops_adc_pallas, beam_hops_adc_stream,  # noqa: F401
                     beam_hops_l2_pallas, beam_hops_l2_stream, fits_vmem,
                     stream_vmem_bytes, vmem_budget_bytes, vmem_bytes)
from .ops import BACKENDS, beam_hops  # noqa: F401
from .ref import beam_hops_ref  # noqa: F401
