"""DimeNet: directional message passing with triplet interactions
[arXiv:2003.03123].

Assigned config: 6 blocks, d_hidden=128, n_bilinear=8, n_spherical=7,
n_radial=6.  Messages live on *edges*; each interaction block updates edge
message m_ji from the messages of incoming edges m_kj using a 2D
spherical-radial basis of (angle kji, distance kj):

  a_SBF(kji)[l, n] = j-ish radial basis(d_kj)[n] * P_l(cos angle)[l]
  m_ji <- MLP(m_ji) + sum_k  W_bilinear . (a_SBF(kji), MLP(m_kj))

Triplet index arrays (edge_in = kj, edge_out = ji) are built host-side
(data/synthetic.py + sampler) -- the "triplet gather" kernel regime of
kernel_taxonomy §GNN.  Output: per-node scalar from incoming messages,
summed per graph.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .common import (bessel_rbf, cosine_cutoff, edge_mask, edge_vectors,
                     init_mlp, mlp_apply)


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_species: int = 8


def _legendre(cos_t: jnp.ndarray, n: int) -> jnp.ndarray:
    """P_0..P_{n-1}(cos) -> (..., n) by recursion."""
    p = [jnp.ones_like(cos_t), cos_t]
    for l in range(2, n):
        p.append(((2 * l - 1) * cos_t * p[-1] - (l - 1) * p[-2]) / l)
    return jnp.stack(p[:n], axis=-1)


def init_params(cfg: DimeNetConfig, key: jax.Array) -> dict:
    h = cfg.d_hidden
    ks = jax.random.split(key, 4 + 4 * cfg.n_blocks)
    params = {
        "embed": jax.random.normal(ks[0], (cfg.n_species, h)) * 0.5,
        "rbf_proj": init_mlp(ks[1], [cfg.n_radial, h]),
        "msg_init": init_mlp(ks[2], [3 * h, h, h]),
        "readout": init_mlp(ks[3], [h, h, 1]),
        "blocks": [],
    }
    nb = cfg.n_bilinear
    for i in range(cfg.n_blocks):
        k0, k1, k2, k3 = jax.random.split(ks[4 + i], 4)
        params["blocks"].append({
            "msg_mlp": init_mlp(k0, [h, h, h]),
            "src_proj": init_mlp(k1, [h, h]),
            "sbf_proj": init_mlp(k2, [cfg.n_radial * cfg.n_spherical, nb]),
            "bilinear": jax.random.normal(k3, (nb, h, h)) / np.sqrt(h * nb),
        })
    return params


def forward(params, cfg: DimeNetConfig, batch,
            constrain_fn=None, gather_fn=None,
            scatter_fn=None) -> jnp.ndarray:
    """batch: species (N,), pos (N,3), edge_src/dst (E,),
    tri_in/tri_out (T,) edge-index pairs (kj -> ji).  Per-graph energies.

    constrain_fn(arr, kind): sharding hooks -- "edges"/"triplets" keep the
    per-edge / per-triplet tensors sharded over the mesh (without them the
    triplet gathers and bilinear outputs replicate: measured 418 GiB/device
    on ogb_products).  gather_fn(table, idx): distributed row gather for
    the triplet -> edge-message lookup (ring_gather at scale; plain take
    otherwise -- replicating the (E, h) message tensor costs ~30 GiB x
    live-copies on ogb_products).  scatter_fn(values, idx, rows): the
    mirrored triplet -> edge aggregation (ring_scatter_add at scale --
    segment_sum's *backward* is a full gather with the same blowup)."""
    cst = constrain_fn or (lambda a, kind: a)
    take = gather_fn or (lambda tab, ix: tab[jnp.clip(ix, 0, tab.shape[0] - 1)])

    def default_scatter(vals, ix, rows):
        dump = jnp.where(ix >= 0, ix, rows)
        return jax.ops.segment_sum(vals, dump, num_segments=rows + 1)[:rows]
    scat = scatter_fn or default_scatter
    species, pos = batch["species"], batch["pos"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = species.shape[0]
    e = src.shape[0]
    emask = edge_mask(src)
    unit, r = edge_vectors(pos, src, dst)
    rbf = bessel_rbf(r, cfg.n_radial, cfg.cutoff) * emask[:, None]

    hs = params["embed"][jnp.clip(species, 0, cfg.n_species - 1)]
    s_clip = jnp.clip(src, 0, n - 1)
    d_clip = jnp.clip(dst, 0, n - 1)
    m = mlp_apply(params["msg_init"], jnp.concatenate(
        [hs[s_clip], hs[d_clip], mlp_apply(params["rbf_proj"], rbf)], -1))
    m = cst(m * emask[:, None], "edges")

    # triplet geometry: angle between edge_in (k->j) and edge_out (j->i)
    ti = batch["tri_in"]
    to = batch["tri_out"]
    tmask = (ti >= 0) & (to >= 0)
    ti_c = jnp.clip(ti, 0, e - 1)
    to_c = jnp.clip(to, 0, e - 1)
    # angle at j: between -unit(k->j) (incoming) and unit(j->i) (outgoing)
    cos_t = jnp.sum((-unit[ti_c]) * unit[to_c], axis=-1)
    cos_t = jnp.clip(cos_t, -1.0, 1.0)
    sbf_ang = _legendre(cos_t, cfg.n_spherical)                # (T, n_sph)
    sbf_rad = bessel_rbf(r[ti_c], cfg.n_radial, cfg.cutoff)    # (T, n_rad)
    sbf = cst((sbf_rad[:, :, None] * sbf_ang[:, None, :]).reshape(
        ti.shape[0], -1) * tmask[:, None], "triplets")
    dump_e = jnp.where(tmask, to_c, e)

    def block(m, bp):
        # triplet-level tensors stay triplet-sharded end to end; the
        # edge-message rows arrive via the distributed gather
        mk = cst(take(cst(mlp_apply(bp["src_proj"], m), "edges"), ti_c),
                 "triplets")                                    # (T, h)
        a = cst(mlp_apply(bp["sbf_proj"], sbf), "triplets")     # (T, nb)
        t = jnp.einsum("th,tb,bhd->td", mk, a, bp["bilinear"])
        t = cst(jnp.where(tmask[:, None], t, 0.0), "triplets")
        agg = cst(scat(t, jnp.where(tmask, to_c, -1), e), "edges")
        m = m + mlp_apply(bp["msg_mlp"], m) + agg
        return cst(m * emask[:, None], "edges"), None

    for bp in params["blocks"]:
        m, _ = jax.checkpoint(block)(m, bp)

    dump_n = jnp.where(emask, d_clip, n)
    x = jax.ops.segment_sum(m, dump_n, num_segments=n + 1)[:n]
    e_atom = mlp_apply(params["readout"], x)[:, 0]
    gid = batch.get("graph_ids")
    if gid is None:
        return jnp.sum(e_atom, keepdims=True)
    # n_graphs must be static under jit: taken from the energy target shape
    return jax.ops.segment_sum(e_atom, gid, num_segments=batch["energy"].shape[0])


def loss_fn(params, cfg: DimeNetConfig, batch, constrain_fn=None,
            gather_fn=None, scatter_fn=None) -> jnp.ndarray:
    e = forward(params, cfg, batch, constrain_fn=constrain_fn,
                gather_fn=gather_fn, scatter_fn=scatter_fn)
    return jnp.mean((e - batch["energy"].astype(jnp.float32)) ** 2)


def build_triplets(edge_src: np.ndarray, edge_dst: np.ndarray,
                   max_triplets: int | None = None):
    """Host-side triplet builder: pairs (edge kj, edge ji) sharing node j.
    Returns (tri_in, tri_out) int32 padded with -1."""
    e = len(edge_src)
    by_dst: dict[int, list[int]] = {}
    for idx in range(e):
        if edge_src[idx] < 0:
            continue
        by_dst.setdefault(int(edge_dst[idx]), []).append(idx)
    ti, to = [], []
    for ji in range(e):
        j = int(edge_src[ji])
        if j < 0:
            continue
        for kj in by_dst.get(j, ()):
            if int(edge_src[kj]) == int(edge_dst[ji]):
                continue  # exclude k == i backtrack
            ti.append(kj)
            to.append(ji)
    ti = np.asarray(ti, np.int32)
    to = np.asarray(to, np.int32)
    if max_triplets is not None:
        ti, to = ti[:max_triplets], to[:max_triplets]
        pad = max_triplets - len(ti)
        if pad > 0:
            ti = np.concatenate([ti, -np.ones(pad, np.int32)])
            to = np.concatenate([to, -np.ones(pad, np.int32)])
    return ti, to
