"""Launch layer: production mesh, per-cell step builders, dry-run, drivers."""
