"""Run every benchmark; print ``name,value,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig5,table2] \
      [--json BENCH.json]

One module per paper table/figure (DESIGN.md §6).  REPRO_BENCH_N scales
corpus sizes (default 4000 -- single-core-CPU friendly).  --json writes
every emitted row (tagged with its suite) plus an environment-metadata
block to the given path -- the machine-readable artifact CI uploads, so
runs are diffable across commits without scraping stdout.
"""
import argparse
import json
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="",
                    help="write suite rows + env metadata to this path")
    args = ap.parse_args()

    from . import (bench_ablation, bench_alpha, bench_beta, bench_degrees,
                   bench_fresh, bench_indexing, bench_io_pipeline,
                   bench_kernels, bench_memory, bench_nio_recall,
                   bench_qps_recall, bench_roofline, bench_serve, common)

    suites = [
        ("fig4", bench_qps_recall.run),
        ("fig5", bench_nio_recall.run),
        ("fig6_7", bench_indexing.run),
        ("fig8", bench_alpha.run),
        ("fig9", bench_beta.run),
        ("fig10", bench_memory.run),
        ("table2", bench_degrees.run),
        ("fig11", bench_ablation.run),
        ("io_pipeline", bench_io_pipeline.run),
        ("kernels", bench_kernels.run),
        ("roofline", bench_roofline.run),
        ("serve", bench_serve.run),
        ("fresh", bench_fresh.run),
        # named without "serve" so `--only serve` (substring match) does
        # not double-run the sweep alongside the serve suite
        ("load_sweep", bench_serve.run_load_sweep),
    ]
    only = [s for s in args.only.split(",") if s]
    print("name,value,derived")
    failures = 0
    for name, fn in suites:
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        row0 = len(common.ROWS)
        try:
            fn()
            status = "ok"
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            status = f"FAILED:{type(e).__name__}"
        wall = time.time() - t0
        print(f"bench.{name}.wall_s,{wall:.1f},{status}")
        for row in common.ROWS[row0:]:
            row["suite"] = name
        common.ROWS.append({"name": f"bench.{name}.wall_s",
                            "value": round(wall, 1), "derived": status,
                            "suite": name})
    if args.json:     # written even on failure: partial rows still diff
        d = os.path.dirname(args.json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump({"meta": common.env_metadata(), "rows": common.ROWS},
                      f, indent=1)
        print(f"# wrote {len(common.ROWS)} rows -> {args.json}",
              file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
