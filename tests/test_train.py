"""Training substrate: optimizer, checkpoint/restart, compression, FT."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lm_batch
from repro.models.transformer import LMConfig, ShardCtx, init_lm_params, lm_loss
from repro.train import checkpoint as ckpt
from repro.train.compression import (compress_bf16, dequantize_int8, ef_init,
                                     quantize_int8)
from repro.train.ft import (FTConfig, SimulatedFailure, resume_or_init,
                            run_loop, run_with_recovery)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.train.trainer import init_train_state, make_train_step

CFG = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
               d_head=16, d_ff=64, vocab=64, remat="none", loss_chunks=2,
               dtype="float32")
CTX = ShardCtx(mesh=None)
OPT = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=100)


def loss_fn(params, batch):
    return lm_loss(params, CFG, batch["tokens"], batch["labels"], CTX)


def batch_fn(step):
    t, l = lm_batch(step, 4, 8, CFG.vocab, seed=0)
    return {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}


def fresh_state():
    return init_train_state(init_lm_params(CFG, jax.random.PRNGKey(0)), OPT)


def test_adamw_descends():
    state = fresh_state()
    step = make_train_step(loss_fn, OPT, donate=False)
    losses = []
    for s in range(30):
        state, m = step(state, batch_fn(s % 3))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1


def test_lr_schedule():
    assert float(lr_at(OPT, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr_at(OPT, jnp.asarray(5))) == pytest.approx(OPT.lr)
    assert float(lr_at(OPT, jnp.asarray(100))) == pytest.approx(
        OPT.lr * OPT.min_lr_frac, rel=1e-3)


def test_grad_clip_bounds_update():
    state = fresh_state()
    big = jax.tree.map(lambda p: jnp.full(p.shape, 100.0, jnp.float32),
                       state["params"])
    _, _, m = adamw_update(OPT, big, state["opt"], state["params"])
    assert float(m["grad_norm"]) > OPT.clip_norm


def test_checkpoint_roundtrip(tmp_path):
    state = fresh_state()
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, state)
    assert ckpt.latest_step(d) == 7
    restored, step = ckpt.restore(d, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_structure_mismatch_errors(tmp_path):
    state = fresh_state()
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, state)
    wrong = {"params": state["params"]}
    with pytest.raises(ValueError):
        ckpt.restore(d, wrong)


def test_restart_equivalence(tmp_path):
    """Kill at step k, resume: final state identical to uninterrupted."""
    step = make_train_step(loss_fn, OPT, donate=False)
    d = str(tmp_path / "ft")
    ft = FTConfig(ckpt_dir=d, ckpt_every=4, async_save=False)
    s_a, _ = run_loop(fresh_state(), step, batch_fn, 12, ft)
    shutil.rmtree(d)
    s_b, _, attempts = run_with_recovery(fresh_state, step, batch_fn, 12, ft,
                                         fail_at=7)
    assert attempts == 1
    for a, b in zip(jax.tree.leaves(s_a), jax.tree.leaves(s_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "async")
    saver = ckpt.AsyncCheckpointer(d, keep=2)
    state = fresh_state()
    for s in (1, 2, 3):
        saver.save(s, state)
    saver.wait()
    steps = sorted(int(f[5:13]) for f in os.listdir(d)
                   if f.startswith("ckpt_"))
    assert steps == [2, 3]  # gc keeps last 2


def test_int8_quant_roundtrip_error_bounded():
    g = np.random.default_rng(0).normal(size=(128,)).astype(np.float32)
    q, s = quantize_int8(jnp.asarray(g))
    back = np.asarray(dequantize_int8(q, s))
    assert np.abs(back - g).max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_unbiased():
    """Sum of (dequantized + carried error) equals the true running sum."""
    from repro.train.compression import ef_compress
    rng = np.random.default_rng(1)
    tree = {"w": jnp.zeros((64,), jnp.float32)}
    err = ef_init(tree)
    true_sum = np.zeros(64)
    sent_sum = np.zeros(64)
    for i in range(20):
        g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
        true_sum += np.asarray(g["w"])
        qs, err = ef_compress(g, err)
        q, s = qs["w"]
        sent_sum += np.asarray(dequantize_int8(q, s))
    resid = np.asarray(err["w"])
    np.testing.assert_allclose(sent_sum + resid, true_sum, rtol=1e-4,
                               atol=1e-4)


def test_resume_or_init_fresh_and_restore(tmp_path):
    d = str(tmp_path / "roi")
    ft = FTConfig(ckpt_dir=d)
    s0 = resume_or_init(fresh_state, ft)
    assert int(s0["step"]) == 0
    ckpt.save(d, 9, fresh_state())
    s1 = resume_or_init(fresh_state, ft)
    assert ckpt.latest_step(d) == 9
