"""TPU-native batched serving layer for BAMG (fixed-shape, jit-compiled).

Two pieces:

- `ann_engine.BatchedANNEngine` -- whole-batch beam search over one BAMG
  sub-index: batched ADC entry scoring through the `pq_adc` kernel, a
  `(B, L)` candidate pool maintained by vectorized insert-sort, fixed-hop
  beam expansion with masked gathers over the padded adjacency matrix, and
  exact re-rank through `l2_topk_rowwise`.
- `frontend.ShardedFrontend` -- scatter-gather over S independent
  sub-indexes: one batched engine call per shard, one global top-k merge;
  shards that die are skipped (degraded mode) and tracked by `health()`.
- `deploy.DeploymentManager` / `deploy.BlueGreenEngine` -- versioned
  checksummed index builds with an atomic ACTIVE pointer: publish ->
  verify -> validate (recall smoke) -> promote, plus rollback; the engine
  hot-swaps on `refresh()` without ever serving a partial index.

Everything is fixed-shape so a (batch, k) signature compiles once and is
reused for the lifetime of the server; see `ann_engine` for the shape
contract.
"""
from .ann_engine import BatchedANNEngine, EngineConfig  # noqa: F401
from .deploy import (BlueGreenEngine, DeploymentManager,  # noqa: F401
                     IndexManifest)
from .frontend import ServeStatus, ShardedFrontend, ShardHealth  # noqa: F401
